//! THE core claim of the paper: DRF is *exact* — the distributed,
//! column-partitioned, depth-wise algorithm produces bit-identical
//! trees to the classic in-memory row-partitioning trainer, for every
//! configuration: bagging modes, feature-sampling policies, worker
//! counts, redundancy, storage modes, and mixed column types.

use drf::baselines::classic::ClassicTrainer;
use drf::baselines::sliq::SliqTrainer;
use drf::baselines::sprint::SprintTrainer;
use drf::config::{ForestParams, StorageMode, TrainConfig};
use drf::data::io_stats::IoStats;
use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::data::Dataset;
use drf::forest::RandomForest;
use drf::rng::{BaggingMode, FeatureSampling};
use drf::util::proptest::run_cases;

fn drf_trees(ds: &Dataset, params: &ForestParams, cfg_mut: impl Fn(&mut TrainConfig)) -> Vec<drf::tree::Tree> {
    let mut cfg = TrainConfig {
        forest: *params,
        ..Default::default()
    };
    cfg_mut(&mut cfg);
    let (forest, _) = RandomForest::train_with_config(ds, &cfg).unwrap();
    forest.trees
}

fn assert_exact(ds: &Dataset, params: &ForestParams, cfg_mut: impl Fn(&mut TrainConfig)) {
    let classic = ClassicTrainer::new(ds, params).train_forest();
    let distributed = drf_trees(ds, params, cfg_mut);
    assert_eq!(classic.len(), distributed.len());
    for (t, (c, d)) in classic.iter().zip(&distributed).enumerate() {
        assert_eq!(c, d, "tree {t} differs between classic and DRF");
    }
}

#[test]
fn exact_on_binary_features_per_node_sampling() {
    let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 600, 9, 11).generate();
    let params = ForestParams {
        num_trees: 3,
        max_depth: 8,
        bagging: BaggingMode::Poisson,
        feature_sampling: FeatureSampling::PerNode,
        seed: 1234,
        ..Default::default()
    };
    assert_exact(&ds, &params, |_| {});
}

#[test]
fn exact_on_continuous_features() {
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 4 }, 500, 8, 3).generate();
    let params = ForestParams {
        num_trees: 2,
        max_depth: 10,
        min_records: 5,
        bagging: BaggingMode::Poisson,
        seed: 99,
        ..Default::default()
    };
    assert_exact(&ds, &params, |_| {});
}

#[test]
fn exact_with_usb_sampling() {
    let ds = SyntheticSpec::new(Family::Majority { informative: 5 }, 400, 12, 7).generate();
    let params = ForestParams {
        num_trees: 2,
        max_depth: 6,
        feature_sampling: FeatureSampling::PerDepth,
        bagging: BaggingMode::Poisson,
        seed: 5,
        ..Default::default()
    };
    assert_exact(&ds, &params, |_| {});
}

#[test]
fn exact_on_leo_like_mixed_types() {
    // 3 numerical + 69 categorical with arities up to 10'000.
    let ds = LeoLikeSpec::new(800, 21).generate();
    let params = ForestParams {
        num_trees: 2,
        max_depth: 5,
        min_records: 10,
        bagging: BaggingMode::Poisson,
        seed: 42,
        ..Default::default()
    };
    assert_exact(&ds, &params, |_| {});
}

#[test]
fn exact_with_few_splitters_and_redundancy() {
    let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 300, 10, 2).generate();
    let params = ForestParams {
        num_trees: 2,
        max_depth: 6,
        bagging: BaggingMode::Poisson,
        seed: 8,
        ..Default::default()
    };
    // 3 splitters for 10 columns, each column on 2 replicas.
    assert_exact(&ds, &params, |cfg| {
        cfg.topology.num_splitters = Some(3);
        cfg.topology.redundancy = 2;
    });
}

#[test]
fn exact_with_disk_storage() {
    let ds = LeoLikeSpec::new(300, 5).generate();
    let params = ForestParams {
        num_trees: 1,
        max_depth: 4,
        min_records: 5,
        bagging: BaggingMode::Poisson,
        seed: 13,
        ..Default::default()
    };
    assert_exact(&ds, &params, |cfg| {
        cfg.storage = StorageMode::Disk;
        cfg.topology.num_splitters = Some(5);
    });
}

#[test]
fn exact_with_adaptive_pruning() {
    // SPRINT-style pruning is a performance feature; it must never
    // change the model.
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 600, 6, 17).generate();
    let params = ForestParams {
        num_trees: 1,
        max_depth: 8,
        min_records: 50, // leaves close early -> pruning triggers
        bagging: BaggingMode::Poisson,
        seed: 3,
        ..Default::default()
    };
    assert_exact(&ds, &params, |cfg| {
        cfg.prune = drf::config::PruneMode::Adaptive { threshold: 0.2 };
    });
}

#[test]
fn sliq_and_sprint_also_exact_on_mixed_data() {
    let ds = LeoLikeSpec::new(400, 9).generate();
    let params = ForestParams {
        num_trees: 1,
        max_depth: 4,
        min_records: 10,
        bagging: BaggingMode::Poisson,
        seed: 55,
        ..Default::default()
    };
    let classic = ClassicTrainer::new(&ds, &params).train_tree(0);
    let sliq = SliqTrainer::new(&ds, &params, IoStats::new()).train_tree(0);
    let sprint = SprintTrainer::new(&ds, &params, IoStats::new()).train_tree(0);
    assert_eq!(classic, sliq);
    assert_eq!(classic, sprint);
}

#[test]
fn depth_next_budgets_exact_across_storage_and_scan_threads() {
    // The hybrid breadth-first/depth-next schedule is a data-residency
    // optimisation: whatever the switch threshold — never (0), so
    // small that only deep nodes detach, or the default where the
    // whole tree goes resident at the root — the forest must stay
    // bit-identical to the classic trainer, on every storage backend
    // and scan-thread count.
    let ds = LeoLikeSpec::new(500, 31).generate();
    let params = ForestParams {
        num_trees: 2,
        max_depth: 7,
        min_records: 5,
        bagging: BaggingMode::Poisson,
        feature_sampling: FeatureSampling::PerNode,
        seed: 4242,
        ..Default::default()
    };
    let classic = ClassicTrainer::new(&ds, &params).train_forest();
    for budget in [0u64, 40, 200, 65_536] {
        for (storage, scan_threads) in [
            (StorageMode::Memory, 1),
            (StorageMode::Memory, 3),
            (StorageMode::Disk, 1),
            (StorageMode::DiskV2, 2),
            (StorageMode::Mmap, 2),
        ] {
            let trees = drf_trees(&ds, &params, |cfg| {
                cfg.depth_next_rows = budget;
                cfg.storage = storage;
                cfg.scan_threads = scan_threads;
            });
            assert_eq!(
                classic, trees,
                "budget {budget} / {storage:?} / {scan_threads} scan threads diverged"
            );
        }
    }
}

#[test]
fn mab_split_search_trains_a_sane_forest() {
    // MABSplit is the one opt-in that may change the model (the sampled
    // elimination pass decides which candidates reach the exact final
    // scan). It must still produce a well-formed forest that actually
    // learns the task; on small data every arm survives to the exact
    // pass, so here it even matches the exhaustive scan.
    let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 700, 8, 23).generate();
    let params = ForestParams {
        num_trees: 3,
        max_depth: 8,
        bagging: BaggingMode::Poisson,
        seed: 7,
        ..Default::default()
    };
    let trees = drf_trees(&ds, &params, |cfg| {
        cfg.split_search = drf::config::SplitSearch::Mab;
    });
    assert_eq!(trees.len(), 3);
    let forest = RandomForest { trees, num_classes: 2 };
    let auc = drf::metrics::auc(&forest.predict_scores(&ds), ds.labels());
    assert!(auc > 0.9, "MAB forest failed to learn XOR: AUC {auc}");
}

#[test]
fn property_exactness_over_random_configs() {
    // Property test: random schema/seed/worker-count configurations all
    // preserve exactness.
    run_cases(0xE8AC7, 12, |rng| {
        let informative = rng.usize(2, 4);
        let features = informative + rng.usize(0, 4);
        let family = *rng.choose(&[
            Family::Xor { informative },
            Family::Majority { informative },
            Family::LinearCont { informative },
        ]);
        let n = rng.usize(50, 400);
        let ds = SyntheticSpec::new(family, n, features, rng.u64(1 << 40)).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: rng.usize(2, 6) as u32,
            min_records: rng.usize(1, 20) as u64,
            bagging: *rng.choose(&[BaggingMode::None, BaggingMode::Poisson]),
            feature_sampling: *rng.choose(&[
                FeatureSampling::PerNode,
                FeatureSampling::PerDepth,
                FeatureSampling::All,
            ]),
            seed: rng.u64(1 << 40),
            ..Default::default()
        };
        let splitters = rng.usize(1, features);
        let redundancy = rng.usize(1, 2);
        assert_exact(&ds, &params, |cfg| {
            cfg.topology.num_splitters = Some(splitters);
            cfg.topology.redundancy = redundancy;
        });
    });
}
