//! Serving-engine integration tests.
//!
//! Two claims are enforced here. **Exactness:** the flattened
//! inference engine must be bit-identical to the reference
//! `Tree::leaf_for` traversal — leaf-for-leaf and score-bit-for-
//! score-bit — across every synthetic family, tree depth, and the
//! Leo-like mixed numerical/categorical schema. **Fidelity over TCP:**
//! scores fetched through the prediction server must equal in-process
//! flat scores exactly, malformed frames must be rejected cleanly, and
//! hot reload must swap models without dropping the connection.

use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::data::Dataset;
use drf::forest::{ForestParams, RandomForest};
use drf::serve::wire::{decode_response, read_frame, write_frame};
use drf::serve::{BatchOptions, FlatForest, PredictClient, PredictionServer, RowsBatch, ServeResponse};

fn train(ds: &Dataset, trees: usize, depth: u32, seed: u64) -> RandomForest {
    let params = ForestParams {
        num_trees: trees,
        max_depth: depth,
        seed,
        ..Default::default()
    };
    RandomForest::train(ds, &params).unwrap()
}

/// The tentpole property: flat routing ≡ reference routing, on every
/// family, at shallow and deep settings.
#[test]
fn flat_forest_is_bit_identical_to_reference_traversal() {
    let families = [
        Family::Xor { informative: 3 },
        Family::Majority { informative: 5 },
        Family::Needle { informative: 3 },
        Family::LinearCont { informative: 4 },
    ];
    for (fi, family) in families.into_iter().enumerate() {
        for depth in [2u32, 6, 12] {
            let seed = 100 + fi as u64 * 10 + depth as u64;
            let ds = SyntheticSpec::new(family, 400, 8, seed).generate();
            let forest = train(&ds, 3, depth, seed);
            assert_flat_matches(&forest, &ds, &format!("{family:?} depth {depth}"));
        }
    }
}

/// Same property on the Leo-like schema: mixed numerical + categorical
/// columns. A trained forest covers whatever splits training picked; a
/// hand-built forest guarantees `CatIn` conditions (and the bitset
/// arena) are exercised regardless of what the trainer chose.
#[test]
fn flat_forest_matches_reference_on_leo_categoricals() {
    let spec = LeoLikeSpec::new(700, 3);
    let ds = spec.generate();
    let forest = train(&ds, 2, 8, 17);
    assert_flat_matches(&forest, &ds, "leo-trained");

    // Deterministic categorical coverage: split on two categorical
    // columns and one numerical column, whatever the trainer did.
    use drf::tree::{CategorySet, Condition, Tree};
    let cat_feature = |c: usize| LeoLikeSpec::NUM_NUMERICAL + c;
    let mut tree = Tree::new_root(vec![350, 350]);
    tree.split_node(
        0,
        Condition::CatIn {
            feature: cat_feature(0),
            set: CategorySet::from_values(spec.arity_at(0), [0]),
        },
        0.1,
        vec![200, 150],
        vec![150, 200],
    );
    tree.split_node(
        1,
        Condition::NumLe {
            feature: 0,
            threshold: 0.25,
        },
        0.05,
        vec![120, 80],
        vec![80, 70],
    );
    tree.split_node(
        2,
        Condition::CatIn {
            feature: cat_feature(10),
            set: CategorySet::from_values(spec.arity_at(10), [1]),
        },
        0.05,
        vec![60, 90],
        vec![90, 110],
    );
    let handmade = RandomForest {
        trees: vec![tree],
        num_classes: 2,
    };
    assert_flat_matches(&handmade, &ds, "leo-handmade");
}

fn assert_flat_matches(forest: &RandomForest, ds: &Dataset, label: &str) {
    let flat = FlatForest::compile(forest);
    assert_eq!(flat.num_trees(), forest.num_trees(), "{label}");
    assert_eq!(flat.num_nodes(), forest.num_nodes(), "{label}");
    // Leaf-for-leaf routing agreement with the reference traversal.
    for (t, tree) in forest.trees.iter().enumerate() {
        for i in 0..ds.num_rows() {
            let row = ds.row(i);
            assert_eq!(
                flat.leaf_for(t, &row),
                tree.leaf_for(&row),
                "{label}: tree {t} row {i} routed differently"
            );
        }
    }
    // Bit-identical scores, at several block/thread shapes.
    let reference = forest.predict_scores_reference(ds);
    for opts in [
        BatchOptions::single_thread(),
        BatchOptions {
            block_rows: 37,
            threads: 4,
        },
    ] {
        let batched = flat.predict_scores_batch(ds, &opts);
        for (i, (a, b)) in batched.iter().zip(&reference).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label}: score differs at row {i} with {opts:?}"
            );
        }
    }
    // Identical class votes.
    assert_eq!(
        flat.predict_classes_batch(ds, &BatchOptions::default()),
        forest.predict_classes_reference(ds),
        "{label}: classes differ"
    );
}

#[test]
fn tcp_round_trip_matches_in_process_scores() {
    let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 500, 6, 5).generate();
    let forest = train(&ds, 4, 8, 21);
    let flat = FlatForest::compile(&forest);

    let server = PredictionServer::spawn(&forest, "127.0.0.1:0", None).unwrap();
    let mut client = PredictClient::connect(server.addr()).unwrap();

    let info = client.model_info().unwrap();
    assert_eq!(info.num_trees as usize, forest.num_trees());
    assert_eq!(info.num_classes, forest.num_classes);
    assert_eq!(info.num_nodes as usize, forest.num_nodes());

    // Scores over TCP == in-process flat scores, bit for bit.
    let remote = client.score_dataset(&ds).unwrap();
    let local = flat.predict_scores_batch(&ds, &BatchOptions::default());
    assert_eq!(remote.len(), local.len());
    for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
        assert_eq!(r.to_bits(), l.to_bits(), "row {i} differs over TCP");
    }
    assert_eq!(
        client.classify_dataset(&ds).unwrap(),
        flat.predict_classes_batch(&ds, &BatchOptions::default())
    );

    // A mistyped batch is rejected with a clean error…
    let bad = RowsBatch {
        columns: vec![drf::data::column::Column::Categorical {
            values: vec![0, 1],
            arity: 2,
        }],
    };
    let err = client.score(bad).unwrap_err();
    assert!(format!("{err}").contains("server error"), "{err}");
    // …and the connection stays usable afterwards.
    let again = client.score_dataset(&ds).unwrap();
    assert_eq!(again.len(), ds.num_rows());
}

#[test]
fn malformed_frames_are_rejected() {
    let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 4, 5).generate();
    let forest = train(&ds, 1, 4, 3);
    let server = PredictionServer::spawn(&forest, "127.0.0.1:0", None).unwrap();

    // Speak raw bytes: a well-framed body that is not a serving request.
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    write_frame(&mut stream, b"this is not a DRFS frame").unwrap();
    let resp_frame = read_frame(&mut stream).unwrap();
    let (id, resp) = decode_response(&resp_frame).unwrap();
    assert_eq!(id, 0, "unparseable requests are answered with id 0");
    match resp {
        ServeResponse::Err(msg) => assert!(msg.contains("bad request frame"), "{msg}"),
        r => panic!("expected Err response, got {r:?}"),
    }
    // The server closes the connection after a malformed frame.
    assert!(read_frame(&mut stream).is_err());

    // A fresh, well-spoken connection still works.
    let mut client = PredictClient::connect(server.addr()).unwrap();
    assert_eq!(client.model_info().unwrap().num_trees, 1);
}

#[test]
fn hot_reload_swaps_the_served_model() {
    let dir = drf::util::tempdir().unwrap();
    let path = dir.path().join("forest.json");
    let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 300, 6, 8).generate();

    let first = train(&ds, 2, 6, 1);
    first.save(&path).unwrap();
    let server = PredictionServer::spawn(&first, "127.0.0.1:0", Some(path.clone())).unwrap();
    let mut client = PredictClient::connect(server.addr()).unwrap();
    assert_eq!(client.model_info().unwrap().num_trees, 2);

    // Retrain with more trees, overwrite the file, reload in place.
    let second = train(&ds, 5, 6, 2);
    second.save(&path).unwrap();
    assert_eq!(client.reload(None).unwrap(), 5);
    assert_eq!(client.model_info().unwrap().num_trees, 5);
    let remote = client.score_dataset(&ds).unwrap();
    let local = FlatForest::compile(&second).predict_scores_batch(&ds, &BatchOptions::default());
    assert_eq!(remote, local, "post-reload scores must come from the new model");

    // Remote path overrides are refused (arbitrary-file read oracle)
    // and the server keeps serving the current model.
    let other = dir.path().join("other.json").display().to_string();
    let err = client.reload(Some(&other)).unwrap_err();
    assert!(
        format!("{err}").contains("not permitted"),
        "path override must be refused: {err}"
    );
    assert_eq!(client.model_info().unwrap().num_trees, 5);

    // Reload when the startup file has gone missing is a clean error
    // that also keeps the old model serving.
    std::fs::remove_file(&path).unwrap();
    assert!(client.reload(None).is_err());
    assert_eq!(client.model_info().unwrap().num_trees, 5);
}
