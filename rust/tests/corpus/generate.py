#!/usr/bin/env python3
"""Offline mirror of the `drf` encoders for the checked-in fuzz corpus.

The authoritative generator is the Rust test
`drf::fuzz::corpus::tests::golden_corpus_files_match_builtin_seeds`
run with `DRF_UPDATE_CORPUS=1 cargo test` — it writes these files from
the real encoders. This script reproduces the exact same bytes without
a Rust toolchain (useful for bootstrapping the corpus and for auditing
a diff by eye); the golden test remains the arbiter. Byte layouts are
mirrored from:

  * rust/src/util/wire.rs          (scalars, strings, frames, trailer)
  * rust/src/coordinator/wire.rs   (request/response bodies)
  * rust/src/serve/wire.rs         (DRFS header + bodies)
  * rust/src/data/objserve.rs      (DRFO header + bodies)
  * rust/src/util/json.rs          (compact, sorted-key JSON)
  * rust/src/fuzz/corpus.rs        (the sample messages themselves)

Run from anywhere: files land next to this script.
"""

import struct
from pathlib import Path

ROOT = Path(__file__).resolve().parent


def u8(v):
    return struct.pack("<B", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f32(v):
    return struct.pack("<f", v)


def f64(v):
    return struct.pack("<d", v)


def wire_str(s):
    b = s.encode("utf-8")
    return u32(len(b)) + b


def u64_slice(values):
    return u32(len(values)) + b"".join(u64(v) for v in values)


def boolean(v):
    return u8(1 if v else 0)


TRACE_CTX = u64(0x1122_3344_5566_7788) + u64(0x99AA_BBCC_DDEE_FF00)


def bitmap(length, set_bits):
    # put_bitmap: u32 len, then 8 bits per byte, LSB-first.
    out = u32(length)
    byte = 0
    for i in range(length):
        if i in set_bits:
            byte |= 1 << (i % 8)
        if i % 8 == 7:
            out += u8(byte)
            byte = 0
    if length % 8 != 0:
        out += u8(byte)
    return out


def condition_num_le(feature, threshold):
    return u8(0) + u32(feature) + f32(threshold)


def condition_cat_in(feature, arity, values):
    # CategorySet::iter yields members in ascending order.
    vs = sorted(values)
    return u8(1) + u32(feature) + u32(arity) + u32(len(vs)) + b"".join(
        u32(v) for v in vs
    )


def time_sync_reply():
    # sample_time_sync(): role "worker", shard Some(1), pid 4242,
    # t_us 1_234_567.
    return wire_str("worker") + boolean(True) + u64(1) + u64(4242) + u64(1_234_567)


def sample_candidate():
    return (
        condition_cat_in(3, 6, [1, 4])
        + f64(0.25)
        + u64_slice([3, 1])
        + u64_slice([2, 4])
    )


SAMPLE_BITMAP = bitmap(10, {0, 3, 4, 9})


# ---------------- coordinator requests ----------------

def coord_requests():
    hello = (
        u8(7)
        + u32(4)  # PROTOCOL_VERSION
        + u32(0)
        + u32(2)
        + u32(1)
        + u64(42)
        + wire_str("poisson")
        + wire_str("sqrt")
        + u32(8)
        + wire_str("gini")
        + boolean(True)
        + f64(0.01)
        + wire_str("exact")
        + u64(65_536)
        + u64(3)
    )
    find_splits = (
        u8(2)
        + u32(1)
        + u32(2)
        + u32(2)
        + u32(1) + boolean(False) + u64_slice([5, 3])
        + u32(2) + boolean(True) + u64_slice([2, 2])
        + u32(2) + u32(0) + u32(2)
    )
    eval_conditions = (
        u8(3)
        + u32(1)
        + u32(2)
        + u32(2)
        + u32(1) + condition_num_le(0, 0.5)
        + u32(2) + condition_cat_in(3, 6, [1, 4])
    )
    level_update = (
        u8(4)
        + u32(1)
        + u32(2)
        + u32(3)
        + u8(0)  # Closed
        + u8(1) + SAMPLE_BITMAP + boolean(True) + boolean(False)  # Split
        + u8(2)  # Detached
    )
    materialize = (
        u8(8)
        + u32(1)
        + u32(3)
        + boolean(True)  # want_meta (written before ranks/columns)
        + u32(2) + u32(1) + u32(2)
        + u32(2) + u32(0) + u32(1)
    )
    seeds = {
        "start_tree": u8(0) + u32(1),
        "root_stats": u8(1) + u32(1),
        "find_splits": find_splits,
        "eval_conditions": eval_conditions,
        "level_update": level_update,
        "finish_tree": u8(5) + u32(1),
        "shutdown": u8(6),
        "hello": hello,
        "materialize": materialize,
        "subtree_done": u8(9) + u32(1) + u32(5) + u64(100) + u32(7),
        "time_sync": u8(10),
    }
    seeds["hello_traced"] = hello + TRACE_CTX
    return seeds


def coord_responses():
    materialized = (
        u8(6)
        + u32(1)  # one leaf
        + u64(3)
        + u32(3) + u32(0) + u32(1) + u32(1)  # labels
        + u32(3) + u8(1) + u8(1) + u8(2)  # bags
        + u32(2)  # columns
        + u8(0) + u32(3) + f32(0.5) + f32(1.5) + f32(2.5)
        + u8(1) + u32(4) + u32(3) + u32(0) + u32(3) + u32(1)
    )
    return {
        "ok": u8(0),
        "root_stats": u8(1) + u64_slice([60, 40]),
        "splits": u8(2) + u32(2) + u8(0) + u8(1) + sample_candidate(),
        "evals": u8(3) + u32(1) + u32(1) + SAMPLE_BITMAP,
        "err": u8(4) + wire_str("boom"),
        "hello": u8(5) + u32(4) + u32(0) + u64(120) + u32(2)
        + u32(3) + u32(0) + u32(2) + u32(4),
        "materialized": materialized,
        "time_sync": u8(7) + time_sync_reply(),
    }


# ---------------- serving ----------------

def serve_header(request_id=7):
    return b"DRFS" + u8(1) + u64(request_id)


def sample_batch_columns():
    return (
        u32(2)
        + u8(0) + u32(3) + f32(0.1) + f32(0.2) + f32(0.3)
        + u8(1) + u32(3) + u32(3) + u32(0) + u32(2) + u32(1)
    )


def serve_requests():
    score = serve_header() + u8(0) + sample_batch_columns()
    seeds = {
        "score": score,
        "classify": serve_header() + u8(1) + sample_batch_columns(),
        "model_info": serve_header() + u8(2),
        "reload": serve_header() + u8(3) + boolean(True) + wire_str("model.json"),
        "time_sync": serve_header() + u8(4),
    }
    seeds["score_traced"] = score + TRACE_CTX
    return seeds


def serve_responses():
    return {
        "scores": serve_header() + u8(0) + u32(3) + f64(0.25) + f64(0.75) + f64(0.5),
        "classes": serve_header() + u8(1) + u32(3) + u32(0) + u32(1) + u32(1),
        "info": serve_header() + u8(2) + u32(10) + u32(2) + u64(321),
        "reloaded": serve_header() + u8(3) + u32(10),
        "err": serve_header() + u8(4) + wire_str("nope"),
        "time_sync": serve_header() + u8(5) + time_sync_reply(),
    }


# ---------------- objstore ----------------

OBJ_HEADER = b"DRFO" + u32(1)


def obj_requests():
    read = (
        OBJ_HEADER + u8(2) + wire_str("shard_0/col_0.drfc") + u64(20) + u32(4096)
    )
    return {
        "stat": OBJ_HEADER + u8(1) + wire_str("shard_0/col_0.drfc"),
        "read": read,
        "time_sync": OBJ_HEADER + u8(3),
        "read_traced": read + TRACE_CTX,
    }


def obj_responses():
    return {
        "stat": OBJ_HEADER + u8(1) + u64(81_920),
        "data": OBJ_HEADER + u8(2) + u32(32) + b"\xab" * 32,
        "time_sync": OBJ_HEADER + u8(3) + time_sync_reply(),
        "err": OBJ_HEADER + u8(0xFF) + wire_str("no such object"),
    }


# ---------------- manifests (sorted-key compact JSON) ----------------

SHARD_MANIFEST = (
    '{"columns":['
    '{"checksum":"123456789abcdef0","file":"col_0.drfc","index":0,'
    '"sorted_checksum":"0fedcba987654321","sorted_file":"col_0.sorted.drfc"},'
    '{"checksum":"1111222233334444","file":"col_1.drfc","index":1}],'
    '"format":"drf-shard-v1",'
    '"labels_checksum":"5555666677778888",'
    '"labels_file":"labels.drfc",'
    '"num_splitters":2,'
    '"protocol":4,'
    '"redundancy":1,'
    '"schema":{"columns":[{"name":"f0","type":"numerical"},'
    '{"arity":5,"name":"f1","type":"categorical"}],"num_classes":2,"rows":120},'
    '"shard":0}'
).encode()

CLUSTER_MANIFEST = (
    '{"format":"drf-cluster-v1",'
    '"num_classes":2,'
    '"num_features":2,'
    '"num_splitters":2,'
    '"objstores":["127.0.0.1:9001"],'
    '"protocol":4,'
    '"redundancy":1,'
    '"rows":120,'
    '"shards":[{"columns":[0],"dir":"shard_0","shard":0},'
    '{"columns":[1],"dir":"shard_1","shard":1}],'
    '"version":1,'
    '"workers":["127.0.0.1:7001","127.0.0.1:7002"]}'
).encode()


# ---------------- assembly ----------------

def frame(body):
    return u32(len(body)) + body


CORPUS = {
    "frame": {"short": frame(b"hello frame body"), "empty": frame(b"")},
    "coord-request": coord_requests(),
    "coord-response": coord_responses(),
    "serve-request": serve_requests(),
    "serve-response": serve_responses(),
    "obj-request": obj_requests(),
    "obj-response": obj_responses(),
    "json": {
        "nested": b'{"name":"drf","nums":[1,2.5,-3e-2],"flags":{"a":true,"b":null},'
        b'"deep":[[1],[2,[3]]]}',
        "escapes": '{"s":"he\\"llo\\nA wörld\\\\"}'.encode("utf-8"),
        "scalar": b"1234567890.5",
    },
    "shard-manifest": {"shard_manifest": SHARD_MANIFEST},
    "cluster-manifest": {"cluster_manifest": CLUSTER_MANIFEST},
    "drfc-header": {
        "v1_numerical": b"DRFC" + u32(1) + u32(1) + u64(12) + b"\x00" * 48,
        "v2_sorted_chunked": b"DRFC" + u32(2) + u32(3) + u64(10)
        + u32(2) + u32(6) + u32(4) + b"\x00" * 80,
    },
}


def main():
    for target, seeds in CORPUS.items():
        directory = ROOT / target
        directory.mkdir(parents=True, exist_ok=True)
        for name, data in seeds.items():
            (directory / f"{name}.bin").write_bytes(data)
            print(f"{target}/{name}.bin: {len(data)} bytes")


if __name__ == "__main__":
    main()
