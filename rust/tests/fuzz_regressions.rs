//! Regression tests for decoder bugs found by the in-tree fuzzer
//! (`drf fuzz`, see `drf::fuzz` and docs/fuzzing.md) plus the
//! negative-path manifest cases. Every test pins the fixed behaviour:
//! a descriptive `Err` — never a panic, never an unbounded allocation.
//!
//! Each forged frame is built inline from the documented wire layout
//! (it *is* the checked-in repro, in constructor form), and every case
//! is additionally pushed through `drf::fuzz::run_one`, which asserts
//! the full invariant: no panic under `catch_unwind`, peak live heap
//! within `alloc_cap`.

use drf::cluster::manifest::{ClusterManifest, ShardColumn, ShardEntry, ShardManifest};
use drf::coordinator::wire as coord;
use drf::data::disk::Header;
use drf::data::schema::{ColumnSpec, Schema};
use drf::fuzz::{alloc_cap, measure, run_one, Target};
use drf::util::json::Json;
use drf::util::wire::{read_frame, Reader, Writer};

/// The invariant every fixed bug must now satisfy on its repro input.
fn assert_clean(target: Target, input: &[u8]) {
    if let Err(kind) = run_one(target, input) {
        panic!("{} violated the invariant on a repro input: {kind:?}", target.name());
    }
}

/// Corrupt a serialized manifest by exact-text substitution. Asserts
/// the needle is present so schema drift fails loudly instead of
/// silently testing nothing.
fn corrupt(text: &str, needle: &str, replacement: &str) -> String {
    assert!(
        text.contains(needle),
        "serialized manifest no longer contains {needle:?}: {text}"
    );
    text.replace(needle, replacement)
}

fn sample_shard_manifest() -> ShardManifest {
    ShardManifest {
        shard: 0,
        num_splitters: 2,
        redundancy: 1,
        rows: 120,
        schema: Schema::new(
            vec![ColumnSpec::numerical("f0"), ColumnSpec::categorical("f1", 5)],
            2,
        ),
        columns: vec![
            ShardColumn {
                index: 0,
                file: "col_0.drfc".into(),
                checksum: 0x1234_5678_9ABC_DEF0,
                sorted_file: Some("col_0.sorted.drfc".into()),
                sorted_checksum: Some(0x0FED_CBA9_8765_4321),
            },
            ShardColumn {
                index: 1,
                file: "col_1.drfc".into(),
                checksum: 0x1111_2222_3333_4444,
                sorted_file: None,
                sorted_checksum: None,
            },
        ],
        labels_file: "labels.drfc".into(),
        labels_checksum: 0x5555_6666_7777_8888,
    }
}

fn sample_cluster_manifest() -> ClusterManifest {
    ClusterManifest {
        num_splitters: 2,
        redundancy: 1,
        rows: 120,
        num_features: 2,
        num_classes: 2,
        shards: vec![
            ShardEntry {
                shard: 0,
                dir: "shard_0".into(),
                columns: vec![0],
            },
            ShardEntry {
                shard: 1,
                dir: "shard_1".into(),
                columns: vec![1],
            },
        ],
        workers: vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()],
        version: 1,
        objstores: vec!["127.0.0.1:9001".into()],
    }
}

fn parse_shard(text: &str) -> drf::Result<ShardManifest> {
    ShardManifest::from_json(&Json::parse(text)?)
}

fn parse_cluster(text: &str) -> drf::Result<ClusterManifest> {
    ClusterManifest::from_json(&Json::parse(text)?)
}

// ---------------------------------------------------------------------
// JSON parser
// ---------------------------------------------------------------------

/// Fuzzer finding: unbounded recursion in `Json::parse` — a few KB of
/// `[[[[…` blew the stack, which is an uncatchable process abort, not
/// a panic a server can survive. Fixed with an explicit depth cap.
#[test]
fn json_deep_nesting_is_err_not_stack_overflow() {
    let bomb = "[".repeat(4000);
    assert!(Json::parse(&bomb).is_err());
    assert_clean(Target::Json, bomb.as_bytes());

    // The cap is generous: a hundred levels of real nesting still parse.
    let deep_ok = format!("{}{}", "[".repeat(100), "]".repeat(100));
    assert!(Json::parse(&deep_ok).is_ok());
    let too_deep = format!("{}{}", "[".repeat(200), "]".repeat(200));
    assert!(Json::parse(&too_deep).is_err());
}

/// Fuzzer finding: `1e999` parsed to `f64::INFINITY`, which the writer
/// then serialized as `null` — silent data corruption on roundtrip.
/// Non-finite numbers are now a parse error.
#[test]
fn json_non_finite_number_is_rejected() {
    assert!(Json::parse("1e999").is_err());
    assert!(Json::parse("[1e999]").is_err());
    assert!(Json::parse("-1e999").is_err());
    // Large-but-finite still parses.
    assert!(Json::parse("1e300").is_ok());
    assert_clean(Target::Json, b"[1e999]");
}

// ---------------------------------------------------------------------
// Coordinator wire codec
// ---------------------------------------------------------------------

/// Fuzzer finding: a `CatIn` condition whose wire member value is >=
/// its declared arity was handed to `CategorySet::insert`, which
/// indexes its word array unchecked — an out-of-bounds write target in
/// release builds. The decoder now validates members against the arity.
#[test]
fn catin_value_past_arity_is_err() {
    let mut w = Writer::new();
    w.u8(3); // EvalConditions
    w.u32(1); // tree
    w.u32(0); // depth
    w.u32(1); // one condition
    w.u32(1); // rank
    w.u8(1); // CatIn
    w.u32(0); // feature
    w.u32(4); // arity
    w.u32(1); // one member
    w.u32(9); // 9 >= arity 4
    let frame = w.into_bytes();
    let err = coord::decode_request_traced(&frame).unwrap_err();
    assert!(err.to_string().contains("arity"), "{err}");
    assert_clean(Target::CoordRequest, &frame);
}

/// Fuzzer finding: `CategorySet::empty(arity)` allocates `⌈arity/64⌉`
/// words up front, so a 30-byte frame forging `arity = u32::MAX` cost
/// 512 MiB. Dense-set allocations are now charged to a per-frame
/// budget that scales with the frame length.
#[test]
fn catin_forged_arity_allocation_bounded() {
    let mut w = Writer::new();
    w.u8(3); // EvalConditions
    w.u32(1); // tree
    w.u32(0); // depth
    w.u32(1); // one condition
    w.u32(1); // rank
    w.u8(1); // CatIn
    w.u32(0); // feature
    w.u32(u32::MAX); // forged arity: wants 512 MiB of set words
    w.u32(0); // no members
    let frame = w.into_bytes();
    let (res, peak) = measure(|| coord::decode_request_traced(&frame).map(|_| ()));
    let err = res.unwrap_err();
    assert!(err.to_string().contains("budget"), "{err}");
    assert!(
        peak <= alloc_cap(frame.len()),
        "rejecting the frame still allocated {peak} bytes"
    );
    assert_clean(Target::CoordRequest, &frame);
}

/// Fuzzer finding: collection sites trusted their `u32` length prefix
/// under the loose "one byte per element" bound, so a tiny frame
/// claiming 2^31 leaves reserved gigabytes before the first element
/// read failed. Every site now bounds the count by its minimum
/// per-element wire size.
#[test]
fn forged_length_prefix_is_err_not_huge_reserve() {
    // FindSplits claiming 2^31 leaves in a 13-byte frame.
    let mut w = Writer::new();
    w.u8(2);
    w.u32(1);
    w.u32(0);
    w.u32(0x7FFF_FFFF);
    let frame = w.into_bytes();
    let (res, peak) = measure(|| coord::decode_request_traced(&frame).map(|_| ()));
    assert!(res.is_err());
    assert!(peak <= alloc_cap(frame.len()), "peak {peak}");
    assert_clean(Target::CoordRequest, &frame);

    // Materialized response claiming 2^31 leaves.
    let mut w = Writer::new();
    w.u8(6);
    w.u32(0x7FFF_FFFF);
    let frame = w.into_bytes();
    let (res, peak) = measure(|| coord::decode_response(&frame).map(|_| ()));
    assert!(res.is_err());
    assert!(peak <= alloc_cap(frame.len()), "peak {peak}");
    assert_clean(Target::CoordResponse, &frame);
}

/// Fuzzer finding: `Reader::u64_vec` used the loose length bound (8
/// declared bytes per element admitted), so a forged count reserved 8×
/// the frame size. Now bounded by the strict 8-bytes-per-element rule.
#[test]
fn u64_vec_forged_count_is_err() {
    let mut w = Writer::new();
    w.u32(0xFFFF_FFFF); // count
    w.u64(7); // only one element present
    let bytes = w.into_bytes();
    let mut r = Reader::new(&bytes);
    let (res, peak) = measure(|| r.u64_vec().map(|_| ()));
    assert!(res.is_err());
    assert!(peak <= alloc_cap(bytes.len()), "peak {peak}");
}

// ---------------------------------------------------------------------
// Frame reader and DRFC headers
// ---------------------------------------------------------------------

/// A length prefix beyond `MAX_FRAME_BYTES`, and one promising more
/// body than the stream holds, must both fail without the reader
/// allocating anything near the declared length.
#[test]
fn frame_forged_length_prefix_is_bounded() {
    let oversize = 0xFFFF_FFFFu32.to_le_bytes().to_vec();
    let (res, peak) = measure(|| read_frame(&mut std::io::Cursor::new(&oversize)).map(|_| ()));
    assert!(res.is_err());
    assert!(peak <= alloc_cap(oversize.len()), "peak {peak}");
    assert_clean(Target::Frame, &oversize);

    let mut truncated = 1_000_000u32.to_le_bytes().to_vec();
    truncated.extend_from_slice(b"short body");
    let (res, peak) = measure(|| read_frame(&mut std::io::Cursor::new(&truncated)).map(|_| ()));
    assert!(res.is_err());
    assert!(peak <= alloc_cap(truncated.len()), "peak {peak}");
    assert_clean(Target::Frame, &truncated);
}

/// Fuzzer finding: a DRFC v2 header forging `rows = u64::MAX` slips
/// past the `chunks <= rows` sanity bound, and the forged chunk count
/// then drove a multi-GiB `Vec::with_capacity` before the first chunk
/// read could fail. The reserve is now clamped.
#[test]
fn drfc_forged_rows_chunk_table_bounded() {
    let mut b = Vec::new();
    b.extend_from_slice(b"DRFC");
    b.extend_from_slice(&2u32.to_le_bytes()); // v2
    b.extend_from_slice(&1u32.to_le_bytes()); // kind Numerical
    b.extend_from_slice(&u64::MAX.to_le_bytes()); // forged rows
    b.extend_from_slice(&0x4000_0000u32.to_le_bytes()); // 2^30 chunks, none present
    let (res, peak) = measure(|| Header::parse(&b).map(|_| ()));
    assert!(res.is_err());
    assert!(peak <= alloc_cap(b.len()), "peak {peak}");
    assert_clean(Target::DrfcHeader, &b);
}

// ---------------------------------------------------------------------
// Manifest negative paths (ShardManifest / cluster.json)
// ---------------------------------------------------------------------

#[test]
fn shard_manifest_truncated_json_is_err() {
    let text = sample_shard_manifest().to_json().to_string();
    let cut = &text[..text.len() / 2];
    assert!(Json::parse(cut).is_err());
    assert_clean(Target::ShardManifest, cut.as_bytes());
}

#[test]
fn shard_manifest_wrong_version_type_is_err() {
    let text = sample_shard_manifest().to_json().to_string();
    let bad = corrupt(&text, "\"protocol\":4", "\"protocol\":\"4\"");
    assert!(parse_shard(&bad).is_err());
    assert_clean(Target::ShardManifest, bad.as_bytes());
}

/// Fuzzer finding: checksum strings were parsed at any width, so a
/// truncated hex string silently became a different checksum (and
/// re-encoded differently). Exactly 16 hex digits are now required.
#[test]
fn shard_manifest_wrong_width_checksum_is_err() {
    let text = sample_shard_manifest().to_json().to_string();
    let bad = corrupt(
        &text,
        "\"labels_checksum\":\"5555666677778888\"",
        "\"labels_checksum\":\"5555\"",
    );
    let err = parse_shard(&bad).unwrap_err();
    assert!(err.to_string().contains("16"), "{err}");
    assert_clean(Target::ShardManifest, bad.as_bytes());
}

/// Fuzzer finding: `sorted_file` and `sorted_checksum` were read
/// independently, so half a pair decoded to a manifest the encoder
/// cannot reproduce (to_json drops a half pair) — a roundtrip
/// divergence. Both-or-neither is now enforced.
#[test]
fn shard_manifest_half_sorted_pair_is_err() {
    let text = sample_shard_manifest().to_json().to_string();
    let bad = corrupt(&text, "\"sorted_checksum\":\"0fedcba987654321\",", "");
    let err = parse_shard(&bad).unwrap_err();
    assert!(err.to_string().contains("sorted"), "{err}");
    assert_clean(Target::ShardManifest, bad.as_bytes());
}

#[test]
fn shard_manifest_duplicate_column_index_is_err() {
    let mut m = sample_shard_manifest();
    m.columns[1].index = 0; // duplicates column 0
    let text = m.to_json().to_string();
    let err = parse_shard(&text).unwrap_err();
    assert!(err.to_string().contains("ascending"), "{err}");
    assert_clean(Target::ShardManifest, text.as_bytes());
}

#[test]
fn shard_manifest_bad_schema_is_err() {
    let text = sample_shard_manifest().to_json().to_string();
    // num_classes < 2 previously hit Schema::new's assert (panic).
    let bad = corrupt(&text, "\"num_classes\":2", "\"num_classes\":0");
    assert!(parse_shard(&bad).is_err());
    assert_clean(Target::ShardManifest, bad.as_bytes());
    // Zero-arity categorical columns are unusable downstream.
    let bad = corrupt(&text, "\"arity\":5", "\"arity\":0");
    assert!(parse_shard(&bad).is_err());
    assert_clean(Target::ShardManifest, bad.as_bytes());
}

#[test]
fn cluster_manifest_duplicate_shard_ids_is_err() {
    let text = sample_cluster_manifest().to_json().to_string();
    let bad = corrupt(&text, "\"shard\":1", "\"shard\":0");
    let err = parse_cluster(&bad).unwrap_err();
    assert!(err.to_string().contains("shard"), "{err}");
    assert_clean(Target::ClusterManifest, bad.as_bytes());
}

#[test]
fn cluster_manifest_wrong_version_type_is_err() {
    let text = sample_cluster_manifest().to_json().to_string();
    let bad = corrupt(&text, "\"version\":1", "\"version\":\"1\"");
    assert!(parse_cluster(&bad).is_err());
    assert_clean(Target::ClusterManifest, bad.as_bytes());
}

#[test]
fn cluster_manifest_truncated_json_is_err() {
    let text = sample_cluster_manifest().to_json().to_string();
    let cut = &text[..text.len() - 3];
    assert!(Json::parse(cut).is_err());
    assert_clean(Target::ClusterManifest, cut.as_bytes());
}
