//! The ColumnStore data-plane contract: every storage backend
//! (Memory, DRFC v1 disk, chunked DRFC v2 disk) and every
//! `scan_threads` setting produces **bit-identical forests**, and
//! within a backend the `IoStats` byte/pass accounting is invariant to
//! the thread count (parallel scans charge exactly what sequential
//! scans charge).

use drf::config::{ForestParams, PruneMode, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::data::Dataset;
use drf::forest::RandomForest;
use drf::rng::BaggingMode;
use drf::tree::Tree;
use drf::util::proptest::run_cases;

const BACKENDS: [StorageMode; 3] = [StorageMode::Memory, StorageMode::Disk, StorageMode::DiskV2];

fn config(storage: StorageMode, scan_threads: usize, splitters: usize, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.forest = ForestParams {
        num_trees: 2,
        max_depth: 5,
        min_records: 4,
        bagging: BaggingMode::Poisson,
        seed,
        ..Default::default()
    };
    // Few splitters for many columns: each owns several, so the scan
    // pool has real work to parallelize.
    cfg.topology.num_splitters = Some(splitters);
    cfg.storage = storage;
    cfg.scan_threads = scan_threads;
    cfg
}

fn families() -> Vec<(&'static str, Dataset)> {
    vec![
        (
            "xor",
            SyntheticSpec::new(Family::Xor { informative: 3 }, 400, 8, 11).generate(),
        ),
        (
            "majority",
            SyntheticSpec::new(Family::Majority { informative: 3 }, 400, 6, 7).generate(),
        ),
        (
            "linear",
            SyntheticSpec::new(Family::LinearCont { informative: 3 }, 350, 6, 5).generate(),
        ),
        // Mixed numerical + high-arity categorical columns.
        ("leo", LeoLikeSpec::new(300, 13).generate()),
    ]
}

/// Per-splitter disk accounting in comparable form.
fn io_fingerprint(report: &drf::coordinator::TrainReport) -> Vec<(u64, u64, u64, u64)> {
    report
        .splitter_io
        .iter()
        .map(|s| {
            (
                s.disk_read_bytes,
                s.disk_write_bytes,
                s.disk_read_passes,
                s.disk_write_passes,
            )
        })
        .collect()
}

#[test]
fn backends_and_scan_threads_are_bit_identical() {
    for (name, ds) in families() {
        let mut reference: Option<Vec<Tree>> = None;
        for storage in BACKENDS {
            let mut io_reference: Option<Vec<(u64, u64, u64, u64)>> = None;
            for scan_threads in [1usize, 4] {
                let cfg = config(storage, scan_threads, 3, 0x51D0 + name.len() as u64);
                let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
                match &reference {
                    None => reference = Some(forest.trees),
                    Some(r) => assert_eq!(
                        r, &forest.trees,
                        "{name}: {storage:?} x scan_threads={scan_threads} \
                         must match the reference forest bit for bit"
                    ),
                }
                let io = io_fingerprint(&report);
                if storage != StorageMode::Memory {
                    assert!(
                        io.iter().any(|x| x.0 > 0),
                        "{name}/{storage:?}: disk backend never read from disk"
                    );
                }
                match &io_reference {
                    None => io_reference = Some(io),
                    Some(r) => assert_eq!(
                        r, &io,
                        "{name}/{storage:?}: IoStats accounting must be \
                         invariant to scan_threads"
                    ),
                }
            }
        }
    }
}

#[test]
fn sprint_pruning_is_backend_invariant() {
    // The SPRINT rebuild is a storage scan site too: adaptive pruning
    // across every backend and thread count must not move a single bit.
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 500, 6, 23).generate();
    let mut reference: Option<Vec<Tree>> = None;
    for storage in BACKENDS {
        for scan_threads in [1usize, 4] {
            let mut cfg = config(storage, scan_threads, 2, 99);
            cfg.forest.min_records = 40; // leaves close early -> pruning fires
            cfg.prune = PruneMode::Adaptive { threshold: 0.2 };
            let (forest, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
            match &reference {
                None => reference = Some(forest.trees),
                Some(r) => assert_eq!(r, &forest.trees, "{storage:?}/t{scan_threads}"),
            }
        }
    }
}

#[test]
fn property_backend_invariance_over_random_configs() {
    run_cases(0xC0_57_0E, 6, |rng| {
        let informative = rng.usize(2, 4);
        let features = informative + rng.usize(1, 4);
        let family = *rng.choose(&[
            Family::Xor { informative },
            Family::Majority { informative },
            Family::LinearCont { informative },
        ]);
        let ds = SyntheticSpec::new(family, rng.usize(80, 300), features, rng.u64(1 << 40))
            .generate();
        let splitters = rng.usize(1, features.min(3));
        let seed = rng.u64(1 << 40);
        let max_depth = rng.usize(2, 5) as u32;
        let threads = rng.usize(2, 5);
        let mut reference: Option<Vec<Tree>> = None;
        for storage in BACKENDS {
            for scan_threads in [1usize, threads] {
                let mut cfg = config(storage, scan_threads, splitters, seed);
                cfg.forest.num_trees = 1;
                cfg.forest.max_depth = max_depth;
                let (forest, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
                match &reference {
                    None => reference = Some(forest.trees),
                    Some(r) => {
                        assert_eq!(r, &forest.trees, "{storage:?}/t{scan_threads}")
                    }
                }
            }
        }
    });
}
