//! The ColumnStore data-plane contract: every storage backend
//! (Memory, DRFC v1 disk, chunked DRFC v2 disk, mmap, remote
//! object-store) × every `scan_threads` setting × every
//! `prefetch_chunks` depth produces **bit-identical forests**, and
//! within a backend the `IoStats` byte/pass accounting is invariant to
//! the thread count and prefetch depth (parallel and pipelined scans
//! charge exactly what sequential scans charge). Also home of the mmap
//! open-rejection matrix (truncated files, forged headers and chunk
//! tables) and of the remote-backend crash drill: training through a
//! real `drf objstore` OS process that dies mid-pass and is restarted
//! must retry, resume at the chunk boundary, and still produce the
//! `--storage mmap` forest bit for bit.

use drf::config::{ForestParams, PruneMode, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::data::Dataset;
use drf::forest::RandomForest;
use drf::rng::BaggingMode;
use drf::tree::Tree;
use drf::util::proptest::run_cases;

const BACKENDS: [StorageMode; 5] = [
    StorageMode::Memory,
    StorageMode::Disk,
    StorageMode::DiskV2,
    StorageMode::Mmap,
    // Loopback mode: the manager spills v2 files and self-hosts an
    // objstore; every scan still crosses a real TCP socket.
    StorageMode::Remote,
];

fn config(storage: StorageMode, scan_threads: usize, splitters: usize, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.forest = ForestParams {
        num_trees: 2,
        max_depth: 5,
        min_records: 4,
        bagging: BaggingMode::Poisson,
        seed,
        ..Default::default()
    };
    // Few splitters for many columns: each owns several, so the scan
    // pool has real work to parallelize.
    cfg.topology.num_splitters = Some(splitters);
    cfg.storage = storage;
    cfg.scan_threads = scan_threads;
    cfg
}

/// Prefetch depths worth exercising for a backend: prefetching only
/// exists on the streaming scans — disk reads and remote range reads
/// (Memory and Mmap scans never copy, so there is nothing to
/// pipeline).
fn prefetch_depths(storage: StorageMode) -> &'static [usize] {
    match storage {
        StorageMode::Disk | StorageMode::DiskV2 | StorageMode::Remote => &[0, 2],
        StorageMode::Memory | StorageMode::Mmap => &[0],
    }
}

fn families() -> Vec<(&'static str, Dataset)> {
    vec![
        (
            "xor",
            SyntheticSpec::new(Family::Xor { informative: 3 }, 400, 8, 11).generate(),
        ),
        (
            "majority",
            SyntheticSpec::new(Family::Majority { informative: 3 }, 400, 6, 7).generate(),
        ),
        (
            "linear",
            SyntheticSpec::new(Family::LinearCont { informative: 3 }, 350, 6, 5).generate(),
        ),
        // Mixed numerical + high-arity categorical columns.
        ("leo", LeoLikeSpec::new(300, 13).generate()),
    ]
}

/// Per-splitter disk accounting in comparable form.
fn io_fingerprint(report: &drf::coordinator::TrainReport) -> Vec<(u64, u64, u64, u64)> {
    report
        .splitter_io
        .iter()
        .map(|s| {
            (
                s.disk_read_bytes,
                s.disk_write_bytes,
                s.disk_read_passes,
                s.disk_write_passes,
            )
        })
        .collect()
}

#[test]
fn backends_scan_threads_and_prefetch_are_bit_identical() {
    for (name, ds) in families() {
        let mut reference: Option<Vec<Tree>> = None;
        for storage in BACKENDS {
            let mut io_reference: Option<Vec<(u64, u64, u64, u64)>> = None;
            for scan_threads in [1usize, 4] {
                for &prefetch in prefetch_depths(storage) {
                    let mut cfg = config(storage, scan_threads, 3, 0x51D0 + name.len() as u64);
                    cfg.prefetch_chunks = prefetch;
                    let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
                    match &reference {
                        None => reference = Some(forest.trees),
                        Some(r) => assert_eq!(
                            r, &forest.trees,
                            "{name}: {storage:?} x scan_threads={scan_threads} \
                             x prefetch={prefetch} must match the reference \
                             forest bit for bit"
                        ),
                    }
                    let io = io_fingerprint(&report);
                    if storage != StorageMode::Memory {
                        assert!(
                            io.iter().any(|x| x.0 > 0),
                            "{name}/{storage:?}: disk backend never read from disk"
                        );
                    }
                    match &io_reference {
                        None => io_reference = Some(io),
                        Some(r) => assert_eq!(
                            r, &io,
                            "{name}/{storage:?}: IoStats accounting must be \
                             invariant to scan_threads and prefetch_chunks"
                        ),
                    }
                }
            }
        }
    }
}

/// The mmap backend refuses broken files at open — truncated payloads,
/// forged magic/version/kind, and inconsistent v2 chunk tables — with
/// errors, never faults mid-scan.
#[test]
fn mmap_open_rejections() {
    use drf::data::disk::{self, Layout};
    use drf::data::io_stats::IoStats;
    use drf::data::store::ColumnFiles;
    use drf::data::{ColumnType, MmapStore};
    use std::collections::BTreeMap;

    let dir = drf::util::tempdir().unwrap();
    let vals: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let write_v2 = |name: &str| {
        let p = dir.path().join(name);
        disk::write_numerical_with(&p, &vals, Layout::V2 { chunk_rows: 16 }, IoStats::new())
            .unwrap();
        p
    };
    let open = |path: std::path::PathBuf, ctype: ColumnType| {
        let mut files = BTreeMap::new();
        files.insert(
            0usize,
            ColumnFiles {
                raw: path,
                sorted: None,
                ctype,
            },
        );
        MmapStore::open(files, IoStats::new())
    };
    let corrupt = |path: &std::path::Path, f: &dyn Fn(&mut Vec<u8>)| {
        let mut bytes = std::fs::read(path).unwrap();
        f(&mut bytes);
        std::fs::write(path, &bytes).unwrap();
    };

    // Intact file opens.
    let ok = write_v2("ok.drfc");
    open(ok, ColumnType::Numerical).expect("valid v2 file must map");

    // Truncated payload (header still declares 64 records).
    let p = write_v2("trunc.drfc");
    corrupt(&p, &|b| b.truncate(b.len() - 12));
    let err = open(p, ColumnType::Numerical).unwrap_err();
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");

    // Forged magic.
    let p = write_v2("magic.drfc");
    corrupt(&p, &|b| b[0] = b'Z');
    assert!(open(p, ColumnType::Numerical).is_err());

    // Forged version.
    let p = write_v2("version.drfc");
    corrupt(&p, &|b| b[4] = 99);
    assert!(open(p, ColumnType::Numerical).is_err());

    // Kind that contradicts the declared column type.
    let p = write_v2("kind.drfc");
    assert!(open(p, ColumnType::Categorical { arity: 4 }).is_err());

    // Chunk table that no longer sums to the row count.
    let p = write_v2("table.drfc");
    corrupt(&p, &|b| b[24] = 63); // first chunk 16 -> 63
    assert!(open(p, ColumnType::Numerical).is_err());

    // Chunk-table length forged huge (allocation guard).
    let p = write_v2("nchunks.drfc");
    corrupt(&p, &|b| {
        b[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
    });
    assert!(open(p, ColumnType::Numerical).is_err());
}

/// Spawn a real `drf objstore` OS process over `dir` and parse the
/// bound address from its ready line. `extra` appends flags
/// (`--fail-after N`); `addr` pins the listen address (empty =
/// ephemeral). Returns `None` if the process failed to come up (e.g. a
/// bind race on a pinned address) — the caller retries.
fn try_spawn_objstore(
    dir: &std::path::Path,
    addr: &str,
    extra: &[&str],
) -> Option<(std::process::Child, String)> {
    use std::io::BufRead;
    let bind = if addr.is_empty() { "127.0.0.1:0" } else { addr };
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_drf"))
        .args(["objstore", "--dir", dir.to_str().unwrap(), "--addr", bind])
        .args(extra)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning drf objstore");
    let stdout = child.stdout.take().expect("objstore stdout piped");
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .expect("reading objstore ready line");
    if !line.contains("serving") {
        let _ = child.kill();
        let _ = child.wait();
        return None;
    }
    let bound = line.trim().rsplit(' ').next().expect("address token").to_string();
    Some((child, bound))
}

/// The acceptance drill: train `--storage remote` through a real
/// `drf objstore` process that **exits mid-pass** (`--fail-after`) and
/// is restarted on the same address by a supervisor thread. The
/// client's bounded-backoff retry reconnects and resumes the
/// interrupted pass at the chunk boundary it had reached; the forest
/// must still be bit-identical to `--storage mmap`.
#[test]
fn remote_training_through_real_objstore_survives_crash_and_restart() {
    use drf::data::io_stats::IoStats;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};

    let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 500, 6, 21).generate();
    let dir = drf::util::tempdir().unwrap();
    // Small chunks so every pass is many range reads (the interruption
    // lands mid-column, between chunk boundaries of the v2 table).
    drf::data::store::save_dataset_with(
        &ds,
        dir.path(),
        drf::data::disk::Layout::V2 { chunk_rows: 64 },
        IoStats::new(),
    )
    .unwrap();

    // Reference forest from the mmap backend.
    let (reference, _) =
        RandomForest::train_with_config(&ds, &config(StorageMode::Mmap, 1, 2, 77)).unwrap();

    // An objstore that dies right before its 40th range read — past
    // the header fetches, in the middle of an early training pass.
    let (victim, addr) =
        try_spawn_objstore(dir.path(), "", &["--fail-after", "40"]).expect("first objstore up");
    let replacement: Arc<Mutex<Option<std::process::Child>>> = Arc::new(Mutex::new(None));
    let restarted = Arc::new(AtomicBool::new(false));

    // The supervisor: wait for the crash, restart on the SAME address
    // (retrying the bind — the dead listener's socket may linger for a
    // moment) so the training client's retry loop finds it again.
    let supervisor = {
        let (replacement, restarted, addr, dir) = (
            replacement.clone(),
            restarted.clone(),
            addr.clone(),
            dir.path().to_path_buf(),
        );
        let mut victim = victim;
        std::thread::spawn(move || {
            let status = victim.wait().expect("waiting for objstore crash");
            assert!(status.success(), "--fail-after exits cleanly, got {status}");
            for _ in 0..100 {
                if let Some((child, _)) = try_spawn_objstore(&dir, &addr, &[]) {
                    *replacement.lock().unwrap() = Some(child);
                    restarted.store(true, Ordering::SeqCst);
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            panic!("objstore could not be restarted on {addr}");
        })
    };

    // Train through the dying-and-restarted store. The prefetch
    // pipeline is on, so the crash also exercises the background
    // fetcher's error path.
    let mut cfg = config(StorageMode::Remote, 1, 2, 77);
    cfg.prefetch_chunks = 2;
    cfg.object_store = Some(addr);
    let (remote, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();

    supervisor.join().expect("supervisor thread");
    assert!(
        restarted.load(Ordering::SeqCst),
        "the objstore crash must actually have fired mid-training"
    );
    assert_eq!(
        reference.trees, remote.trees,
        "a mid-pass objstore crash + restart must not change the forest"
    );
    let net: u64 = report.splitter_io.iter().map(|s| s.net_bytes).sum();
    assert!(net > 0, "remote scans must have crossed the wire");

    if let Some(mut child) = replacement.lock().unwrap().take() {
        let _ = child.kill();
        let _ = child.wait();
    }
}

/// Telemetry is observation-only: with the span trace sink streaming
/// and an in-process `/metrics` listener live, every storage backend
/// still produces the telemetry-off forest bit for bit — and a scrape
/// over the real socket returns the phase histograms the runs just
/// recorded.
#[test]
fn telemetry_is_observation_only_across_backends() {
    let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 300, 6, 31).generate();

    // Reference forests, telemetry off.
    let mut reference: Vec<Vec<Tree>> = Vec::new();
    for storage in BACKENDS {
        let (forest, _) =
            RandomForest::train_with_config(&ds, &config(storage, 2, 2, 13)).unwrap();
        reference.push(forest.trees);
    }

    // Same runs with tracing on and the metrics endpoint up.
    let dir = drf::util::tempdir().unwrap();
    let trace = dir.path().join("trace.jsonl");
    drf::telemetry::set_trace_out(&trace).unwrap();
    let server = drf::telemetry::MetricsServer::spawn("127.0.0.1:0").unwrap();
    for (storage, expect) in BACKENDS.into_iter().zip(&reference) {
        let (forest, _) =
            RandomForest::train_with_config(&ds, &config(storage, 2, 2, 13)).unwrap();
        assert_eq!(
            expect, &forest.trees,
            "{storage:?}: telemetry must not change the forest"
        );
    }
    let scraped = drf::telemetry::scrape(&server.addr().to_string()).unwrap();
    drf::telemetry::clear_trace_out();

    assert!(
        scraped.contains("drf_phase_us_bucket"),
        "scrape missing phase histograms:\n{scraped}"
    );
    assert!(
        scraped.contains("drf_trees_total") && scraped.contains("drf_levels_total"),
        "scrape missing training counters:\n{scraped}"
    );
    let lines = std::fs::read_to_string(&trace).unwrap();
    assert!(
        lines.lines().count() > 0,
        "trace sink stayed empty across five training runs"
    );
    // Span events carry the distributed-tracing fields: ids, process
    // identity, and a per-process monotone non-decreasing timestamp.
    let mut span_events = 0usize;
    let mut last_t = 0u64;
    for line in lines.lines() {
        let j = drf::util::Json::parse(line).expect("trace line parses");
        let t = j.get("t_us").unwrap().as_u64().unwrap();
        assert!(t >= last_t, "t_us went backwards: {t} < {last_t}");
        last_t = t;
        if j.get("event").unwrap().as_str().unwrap() != "span" {
            continue;
        }
        span_events += 1;
        assert!(j.get("trace_id").unwrap().as_u64().is_ok());
        assert!(j.get("span_id").unwrap().as_u64().unwrap() > 0);
        assert!(j.get("parent_id").unwrap().as_u64().is_ok());
        let proc = j.get("proc").unwrap();
        assert!(proc.get("pid").unwrap().as_u64().unwrap() > 0);
        assert!(proc.get("role").unwrap().as_str().is_ok());
    }
    assert!(span_events > 0, "no span events across five backends");
}

#[test]
fn sprint_pruning_is_backend_invariant() {
    // The SPRINT rebuild is a storage scan site too: adaptive pruning
    // across every backend and thread count must not move a single bit.
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 500, 6, 23).generate();
    let mut reference: Option<Vec<Tree>> = None;
    for storage in BACKENDS {
        for scan_threads in [1usize, 4] {
            let mut cfg = config(storage, scan_threads, 2, 99);
            cfg.forest.min_records = 40; // leaves close early -> pruning fires
            cfg.prune = PruneMode::Adaptive { threshold: 0.2 };
            let (forest, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
            match &reference {
                None => reference = Some(forest.trees),
                Some(r) => assert_eq!(r, &forest.trees, "{storage:?}/t{scan_threads}"),
            }
        }
    }
}

#[test]
fn property_backend_invariance_over_random_configs() {
    run_cases(0xC0_57_0E, 6, |rng| {
        let informative = rng.usize(2, 4);
        let features = informative + rng.usize(1, 4);
        let family = *rng.choose(&[
            Family::Xor { informative },
            Family::Majority { informative },
            Family::LinearCont { informative },
        ]);
        let ds = SyntheticSpec::new(family, rng.usize(80, 300), features, rng.u64(1 << 40))
            .generate();
        let splitters = rng.usize(1, features.min(3));
        let seed = rng.u64(1 << 40);
        let max_depth = rng.usize(2, 5) as u32;
        let threads = rng.usize(2, 5);
        let mut reference: Option<Vec<Tree>> = None;
        for storage in BACKENDS {
            for scan_threads in [1usize, threads] {
                let mut cfg = config(storage, scan_threads, splitters, seed);
                cfg.forest.num_trees = 1;
                cfg.forest.max_depth = max_depth;
                let (forest, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
                match &reference {
                    None => reference = Some(forest.trees),
                    Some(r) => {
                        assert_eq!(r, &forest.trees, "{storage:?}/t{scan_threads}")
                    }
                }
            }
        }
    });
}
