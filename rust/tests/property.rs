//! Property-based tests over substrate invariants (seeded in-tree
//! harness, see util::proptest): class-list transitions, bitmaps,
//! external sort, JSON, AUC, and the classlist/bitmap interplay that
//! the coordinator depends on.

use drf::classlist::{width_for, ClassList};
use drf::coordinator::messages::{Bitmap, LeafOutcome, LevelUpdate};
use drf::coordinator::splitter::apply_update_to_class_list;
use drf::data::column::{Column, SortedEntry};
use drf::data::io_stats::IoStats;
use drf::data::sort::ExternalSorter;
use drf::metrics::auc;
use drf::util::json::Json;
use drf::util::proptest::run_cases;

#[test]
fn classlist_set_get_random() {
    run_cases(1, 30, |rng| {
        let n = rng.usize(1, 300);
        let num_open = rng.usize(1, 5000) as u32;
        let mut cl = ClassList::with_open(n, num_open);
        let mut shadow = vec![0u32; n];
        for _ in 0..n * 2 {
            let i = rng.usize(0, n - 1);
            let code = rng.u64(num_open as u64 + 1) as u32;
            cl.set(i, code);
            shadow[i] = code;
        }
        for i in 0..n {
            assert_eq!(cl.get(i), shadow[i]);
        }
        // Width matches the paper's formula.
        assert_eq!(cl.width(), width_for(num_open));
    });
}

#[test]
fn classlist_level_transition_matches_naive_model() {
    // Build a random class list, a random outcome per open leaf, and
    // check apply_update_to_class_list against a naive per-sample
    // simulation.
    run_cases(2, 25, |rng| {
        let n = rng.usize(1, 200);
        let num_open = rng.usize(1, 6) as u32;
        let mut cl = ClassList::with_open(n, num_open);
        let mut codes = vec![0u32; n];
        for i in 0..n {
            let c = rng.u64(num_open as u64 + 1) as u32;
            cl.set(i, c);
            codes[i] = c;
        }
        // Random outcomes with correctly-sized bitmaps.
        let mut per_leaf_count = vec![0usize; num_open as usize];
        for &c in &codes {
            if c > 0 {
                per_leaf_count[(c - 1) as usize] += 1;
            }
        }
        let mut outcomes = Vec::new();
        let mut bits: Vec<Vec<bool>> = Vec::new();
        for r in 0..num_open as usize {
            if rng.bool(0.3) {
                outcomes.push(LeafOutcome::Closed);
                bits.push(vec![]);
            } else {
                let b: Vec<bool> = (0..per_leaf_count[r]).map(|_| rng.bool(0.5)).collect();
                let mut bm = Bitmap::with_len(b.len());
                for (k, &v) in b.iter().enumerate() {
                    bm.set(k, v);
                }
                outcomes.push(LeafOutcome::Split {
                    bitmap: bm,
                    left_open: rng.bool(0.8),
                    right_open: rng.bool(0.8),
                });
                bits.push(b);
            }
        }
        let update = LevelUpdate {
            tree: 0,
            depth: 0,
            outcomes: outcomes.clone(),
        };
        let got = apply_update_to_class_list(&cl, &update).unwrap();

        // Naive model: assign new ranks in outcome order.
        let mut left_rank = vec![0u32; num_open as usize];
        let mut right_rank = vec![0u32; num_open as usize];
        let mut next = 0u32;
        for (r, o) in outcomes.iter().enumerate() {
            if let LeafOutcome::Split {
                left_open,
                right_open,
                ..
            } = o
            {
                if *left_open {
                    next += 1;
                    left_rank[r] = next;
                }
                if *right_open {
                    next += 1;
                    right_rank[r] = next;
                }
            }
        }
        let mut pos = vec![0usize; num_open as usize];
        for i in 0..n {
            let c = codes[i];
            let expect = if c == 0 {
                0
            } else {
                let r = (c - 1) as usize;
                match &outcomes[r] {
                    LeafOutcome::Closed => 0,
                    LeafOutcome::Split { .. } => {
                        let p = pos[r];
                        pos[r] += 1;
                        if bits[r][p] {
                            left_rank[r]
                        } else {
                            right_rank[r]
                        }
                    }
                }
            };
            assert_eq!(got.get(i), expect, "sample {i}");
        }
        assert_eq!(got.num_open(), next);
    });
}

#[test]
fn external_sort_equals_std_sort() {
    run_cases(3, 15, |rng| {
        let n = rng.usize(0, 3000);
        let values: Vec<f32> = (0..n).map(|_| (rng.f32() * 100.0).round() / 10.0).collect();
        let dir = drf::util::tempdir().unwrap();
        let sorter = ExternalSorter::new(dir.path(), rng.usize(2, 257), IoStats::new());
        let out = dir.path().join("out.drfc");
        sorter.sort_column(&values, &out).unwrap();
        let got = drf::data::disk::ColumnReader::open(&out, IoStats::new())
            .unwrap()
            .read_all_sorted()
            .unwrap();
        let want: Vec<SortedEntry> = Column::Numerical(values).presort();
        assert_eq!(got, want);
    });
}

#[test]
fn json_roundtrip_random_values() {
    fn gen(rng: &mut drf::util::proptest::CaseRng, depth: usize) -> Json {
        if depth == 0 || rng.bool(0.4) {
            match rng.usize(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.f64() * 1e6).floor() / 8.0),
                _ => Json::Str(
                    (0..rng.usize(0, 12))
                        .map(|_| char::from_u32(rng.u64(0x250) as u32 + 32).unwrap_or('x'))
                        .collect(),
                ),
            }
        } else if rng.bool(0.5) {
            Json::Arr((0..rng.usize(0, 5)).map(|_| gen(rng, depth - 1)).collect())
        } else {
            let mut o = Json::object();
            for k in 0..rng.usize(0, 5) {
                o.set(&format!("k{k}"), gen(rng, depth - 1));
            }
            o
        }
    }
    run_cases(4, 50, |rng| {
        let v = gen(rng, 4);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back, "roundtrip of {text}");
    });
}

#[test]
fn auc_matches_brute_force_pair_counting() {
    run_cases(5, 25, |rng| {
        let n = rng.usize(2, 120);
        let labels: Vec<u32> = (0..n).map(|_| rng.bool(0.4) as u32).collect();
        // Coarse scores force plenty of ties.
        let scores: Vec<f64> = (0..n).map(|_| rng.usize(0, 5) as f64 / 5.0).collect();
        let fast = auc(&scores, &labels);
        // Brute force: P(score_pos > score_neg) + 0.5 P(tie).
        let (mut wins, mut ties, mut pairs) = (0f64, 0f64, 0f64);
        for i in 0..n {
            for j in 0..n {
                if labels[i] == 1 && labels[j] == 0 {
                    pairs += 1.0;
                    if scores[i] > scores[j] {
                        wins += 1.0;
                    } else if scores[i] == scores[j] {
                        ties += 1.0;
                    }
                }
            }
        }
        let want = if pairs == 0.0 {
            0.5
        } else {
            (wins + 0.5 * ties) / pairs
        };
        assert!((fast - want).abs() < 1e-9, "auc {fast} vs brute {want}");
    });
}

#[test]
fn bitmap_roundtrip_random() {
    run_cases(6, 30, |rng| {
        let n = rng.usize(0, 500);
        let mut bm = Bitmap::with_len(n);
        let bits: Vec<bool> = (0..n).map(|_| rng.bool(0.5)).collect();
        for (i, &b) in bits.iter().enumerate() {
            bm.set(i, b);
        }
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(bm.get(i), b);
        }
        assert_eq!(bm.count_ones(), bits.iter().filter(|&&b| b).count());
        assert_eq!(bm.wire_bytes(), (n as u64).div_ceil(8));
    });
}

#[test]
fn classlist_rewrite_histogram_conservation() {
    // Splitting never loses samples: histogram mass before == after
    // (closed samples move to code 0).
    run_cases(7, 20, |rng| {
        let n = rng.usize(1, 400);
        let num_open = rng.usize(1, 9) as u32;
        let mut cl = ClassList::with_open(n, num_open);
        for i in 0..n {
            cl.set(i, rng.u64(num_open as u64 + 1) as u32);
        }
        let before: u64 = cl.histogram().iter().sum();
        let new_open = rng.usize(0, 2 * num_open as usize) as u32;
        let got = cl.rewrite(new_open, |_, old| {
            if old == 0 {
                0
            } else {
                rng_free_map(old, new_open)
            }
        });
        let after: u64 = got.histogram().iter().sum();
        assert_eq!(before, after, "sample conservation");
    });

    fn rng_free_map(old: u32, new_open: u32) -> u32 {
        if new_open == 0 {
            0
        } else {
            old % (new_open + 1)
        }
    }
}
