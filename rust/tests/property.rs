//! Property-based tests over substrate invariants (seeded in-tree
//! harness, see util::proptest): class-list transitions, bitmaps,
//! external sort, JSON, AUC, and the classlist/bitmap interplay that
//! the coordinator depends on.

use drf::classlist::{width_for, ClassList};
use drf::coordinator::messages::{
    Bitmap, EvalQuery, EvalResult, LeafInfo, LeafOutcome, LevelUpdate, MaterializeQuery,
    MaterializedColumn, MaterializedLeaf, MaterializedLeaves, PartialSupersplit, SubtreeDone,
    SupersplitQuery,
};
use drf::coordinator::splitter::apply_update_to_class_list;
use drf::coordinator::wire as coord;
use drf::data::column::{Column, SortedEntry};
use drf::data::io_stats::IoStats;
use drf::data::objserve as obj;
use drf::data::sort::ExternalSorter;
use drf::metrics::auc;
use drf::serve::wire as serve;
use drf::splits::SplitCandidate;
use drf::telemetry::{TimeSyncReply, TraceContext};
use drf::tree::{CategorySet, Condition};
use drf::util::json::Json;
use drf::util::proptest::{run_cases, CaseRng};

#[test]
fn classlist_set_get_random() {
    run_cases(1, 30, |rng| {
        let n = rng.usize(1, 300);
        let num_open = rng.usize(1, 5000) as u32;
        let mut cl = ClassList::with_open(n, num_open);
        let mut shadow = vec![0u32; n];
        for _ in 0..n * 2 {
            let i = rng.usize(0, n - 1);
            let code = rng.u64(num_open as u64 + 1) as u32;
            cl.set(i, code);
            shadow[i] = code;
        }
        for i in 0..n {
            assert_eq!(cl.get(i), shadow[i]);
        }
        // Width matches the paper's formula.
        assert_eq!(cl.width(), width_for(num_open));
    });
}

#[test]
fn classlist_level_transition_matches_naive_model() {
    // Build a random class list, a random outcome per open leaf, and
    // check apply_update_to_class_list against a naive per-sample
    // simulation.
    run_cases(2, 25, |rng| {
        let n = rng.usize(1, 200);
        let num_open = rng.usize(1, 6) as u32;
        let mut cl = ClassList::with_open(n, num_open);
        let mut codes = vec![0u32; n];
        for i in 0..n {
            let c = rng.u64(num_open as u64 + 1) as u32;
            cl.set(i, c);
            codes[i] = c;
        }
        // Random outcomes with correctly-sized bitmaps.
        let mut per_leaf_count = vec![0usize; num_open as usize];
        for &c in &codes {
            if c > 0 {
                per_leaf_count[(c - 1) as usize] += 1;
            }
        }
        let mut outcomes = Vec::new();
        let mut bits: Vec<Vec<bool>> = Vec::new();
        for r in 0..num_open as usize {
            if rng.bool(0.3) {
                outcomes.push(LeafOutcome::Closed);
                bits.push(vec![]);
            } else {
                let b: Vec<bool> = (0..per_leaf_count[r]).map(|_| rng.bool(0.5)).collect();
                let mut bm = Bitmap::with_len(b.len());
                for (k, &v) in b.iter().enumerate() {
                    bm.set(k, v);
                }
                outcomes.push(LeafOutcome::Split {
                    bitmap: bm,
                    left_open: rng.bool(0.8),
                    right_open: rng.bool(0.8),
                });
                bits.push(b);
            }
        }
        let update = LevelUpdate {
            tree: 0,
            depth: 0,
            outcomes: outcomes.clone(),
        };
        let got = apply_update_to_class_list(&cl, &update).unwrap();

        // Naive model: assign new ranks in outcome order.
        let mut left_rank = vec![0u32; num_open as usize];
        let mut right_rank = vec![0u32; num_open as usize];
        let mut next = 0u32;
        for (r, o) in outcomes.iter().enumerate() {
            if let LeafOutcome::Split {
                left_open,
                right_open,
                ..
            } = o
            {
                if *left_open {
                    next += 1;
                    left_rank[r] = next;
                }
                if *right_open {
                    next += 1;
                    right_rank[r] = next;
                }
            }
        }
        let mut pos = vec![0usize; num_open as usize];
        for i in 0..n {
            let c = codes[i];
            let expect = if c == 0 {
                0
            } else {
                let r = (c - 1) as usize;
                match &outcomes[r] {
                    LeafOutcome::Closed => 0,
                    LeafOutcome::Split { .. } => {
                        let p = pos[r];
                        pos[r] += 1;
                        if bits[r][p] {
                            left_rank[r]
                        } else {
                            right_rank[r]
                        }
                    }
                }
            };
            assert_eq!(got.get(i), expect, "sample {i}");
        }
        assert_eq!(got.num_open(), next);
    });
}

#[test]
fn external_sort_equals_std_sort() {
    run_cases(3, 15, |rng| {
        let n = rng.usize(0, 3000);
        let values: Vec<f32> = (0..n).map(|_| (rng.f32() * 100.0).round() / 10.0).collect();
        let dir = drf::util::tempdir().unwrap();
        let sorter = ExternalSorter::new(dir.path(), rng.usize(2, 257), IoStats::new());
        let out = dir.path().join("out.drfc");
        sorter.sort_column(&values, &out).unwrap();
        let got = drf::data::disk::ColumnReader::open(&out, IoStats::new())
            .unwrap()
            .read_all_sorted()
            .unwrap();
        let want: Vec<SortedEntry> = Column::Numerical(values).presort();
        assert_eq!(got, want);
    });
}

#[test]
fn json_roundtrip_random_values() {
    fn gen(rng: &mut drf::util::proptest::CaseRng, depth: usize) -> Json {
        if depth == 0 || rng.bool(0.4) {
            match rng.usize(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.f64() * 1e6).floor() / 8.0),
                _ => Json::Str(
                    (0..rng.usize(0, 12))
                        .map(|_| char::from_u32(rng.u64(0x250) as u32 + 32).unwrap_or('x'))
                        .collect(),
                ),
            }
        } else if rng.bool(0.5) {
            Json::Arr((0..rng.usize(0, 5)).map(|_| gen(rng, depth - 1)).collect())
        } else {
            let mut o = Json::object();
            for k in 0..rng.usize(0, 5) {
                o.set(&format!("k{k}"), gen(rng, depth - 1));
            }
            o
        }
    }
    run_cases(4, 50, |rng| {
        let v = gen(rng, 4);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back, "roundtrip of {text}");
    });
}

#[test]
fn auc_matches_brute_force_pair_counting() {
    run_cases(5, 25, |rng| {
        let n = rng.usize(2, 120);
        let labels: Vec<u32> = (0..n).map(|_| rng.bool(0.4) as u32).collect();
        // Coarse scores force plenty of ties.
        let scores: Vec<f64> = (0..n).map(|_| rng.usize(0, 5) as f64 / 5.0).collect();
        let fast = auc(&scores, &labels);
        // Brute force: P(score_pos > score_neg) + 0.5 P(tie).
        let (mut wins, mut ties, mut pairs) = (0f64, 0f64, 0f64);
        for i in 0..n {
            for j in 0..n {
                if labels[i] == 1 && labels[j] == 0 {
                    pairs += 1.0;
                    if scores[i] > scores[j] {
                        wins += 1.0;
                    } else if scores[i] == scores[j] {
                        ties += 1.0;
                    }
                }
            }
        }
        let want = if pairs == 0.0 {
            0.5
        } else {
            (wins + 0.5 * ties) / pairs
        };
        assert!((fast - want).abs() < 1e-9, "auc {fast} vs brute {want}");
    });
}

#[test]
fn bitmap_roundtrip_random() {
    run_cases(6, 30, |rng| {
        let n = rng.usize(0, 500);
        let mut bm = Bitmap::with_len(n);
        let bits: Vec<bool> = (0..n).map(|_| rng.bool(0.5)).collect();
        for (i, &b) in bits.iter().enumerate() {
            bm.set(i, b);
        }
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(bm.get(i), b);
        }
        assert_eq!(bm.count_ones(), bits.iter().filter(|&&b| b).count());
        assert_eq!(bm.wire_bytes(), (n as u64).div_ceil(8));
    });
}

#[test]
fn classlist_rewrite_histogram_conservation() {
    // Splitting never loses samples: histogram mass before == after
    // (closed samples move to code 0).
    run_cases(7, 20, |rng| {
        let n = rng.usize(1, 400);
        let num_open = rng.usize(1, 9) as u32;
        let mut cl = ClassList::with_open(n, num_open);
        for i in 0..n {
            cl.set(i, rng.u64(num_open as u64 + 1) as u32);
        }
        let before: u64 = cl.histogram().iter().sum();
        let new_open = rng.usize(0, 2 * num_open as usize) as u32;
        let got = cl.rewrite(new_open, |_, old| {
            if old == 0 {
                0
            } else {
                rng_free_map(old, new_open)
            }
        });
        let after: u64 = got.histogram().iter().sum();
        assert_eq!(before, after, "sample conservation");
    });

    fn rng_free_map(old: u32, new_open: u32) -> u32 {
        if new_open == 0 {
            0
        } else {
            old % (new_open + 1)
        }
    }
}

// ---------------------------------------------------------------------
// Wire codecs: random messages across all three protocols
// ---------------------------------------------------------------------

fn random_ctx(rng: &mut CaseRng) -> Option<TraceContext> {
    rng.bool(0.5).then(|| TraceContext {
        trace_id: rng.raw_u64(),
        parent_span: rng.raw_u64(),
    })
}

fn random_bitmap(rng: &mut CaseRng) -> Bitmap {
    let n = rng.usize(0, 40);
    let mut b = Bitmap::with_len(n);
    for i in 0..n {
        b.set(i, rng.bool(0.5));
    }
    b
}

fn random_condition(rng: &mut CaseRng) -> Condition {
    if rng.bool(0.5) {
        Condition::NumLe {
            feature: rng.usize(0, 1000),
            threshold: rng.f32(),
        }
    } else {
        let arity = rng.usize(1, 90) as u32;
        let values: Vec<u32> = rng.vec(0, 8, |r| r.u64(arity as u64) as u32);
        Condition::CatIn {
            feature: rng.usize(0, 1000),
            set: CategorySet::from_values(arity, values),
        }
    }
}

fn random_candidate(rng: &mut CaseRng) -> SplitCandidate {
    SplitCandidate {
        condition: random_condition(rng),
        gain: rng.f64(),
        left_counts: rng.vec(0, 4, |r| r.raw_u64()),
        right_counts: rng.vec(0, 4, |r| r.raw_u64()),
    }
}

fn random_time_sync(rng: &mut CaseRng) -> TimeSyncReply {
    TimeSyncReply {
        role: rng.string(0, 8),
        shard: rng.bool(0.5).then(|| rng.raw_u64()),
        pid: rng.raw_u64(),
        t_us: rng.raw_u64(),
    }
}

fn random_coord_request(rng: &mut CaseRng) -> coord::Request {
    match rng.usize(0, 10) {
        0 => coord::Request::StartTree(rng.u64(1 << 20) as u32),
        1 => coord::Request::RootStats(rng.u64(1 << 20) as u32),
        2 => coord::Request::FindSplits(SupersplitQuery {
            tree: rng.u64(100) as u32,
            depth: rng.u64(30) as u32,
            leaves: rng.vec(0, 4, |r| LeafInfo {
                node_id: r.u64(1 << 20) as u32,
                totals: r.vec(0, 4, |r| r.raw_u64()),
                detached: r.bool(0.3),
            }),
            assigned_columns: rng.vec(0, 5, |r| r.usize(0, 500)),
        }),
        3 => coord::Request::EvalConditions(EvalQuery {
            tree: rng.u64(100) as u32,
            depth: rng.u64(30) as u32,
            conditions: rng.vec(0, 4, |r| (r.u64(1 << 16) as u32, random_condition(r))),
        }),
        4 => coord::Request::LevelUpdate(LevelUpdate {
            tree: rng.u64(100) as u32,
            depth: rng.u64(30) as u32,
            outcomes: rng.vec(0, 4, |r| match r.usize(0, 2) {
                0 => LeafOutcome::Closed,
                1 => LeafOutcome::Split {
                    bitmap: random_bitmap(r),
                    left_open: r.bool(0.5),
                    right_open: r.bool(0.5),
                },
                _ => LeafOutcome::Detached,
            }),
        }),
        5 => coord::Request::FinishTree(rng.u64(1 << 20) as u32),
        6 => coord::Request::Shutdown,
        7 => coord::Request::Hello(coord::HelloConfig {
            protocol: rng.u64(u32::MAX as u64 + 1) as u32,
            shard: rng.u64(64) as u32,
            num_splitters: rng.u64(64) as u32,
            redundancy: rng.u64(8) as u32,
            seed: rng.raw_u64(),
            bagging: rng.string(0, 10),
            sampling: rng.string(0, 10),
            num_candidates: rng.u64(1 << 16) as u32,
            score_kind: rng.string(0, 10),
            prune_threshold: rng.bool(0.5).then(|| rng.f64()),
            split_search: rng.string(0, 10),
            depth_next_rows: rng.raw_u64(),
            topology_version: rng.raw_u64(),
        }),
        8 => coord::Request::Materialize(MaterializeQuery {
            tree: rng.u64(100) as u32,
            depth: rng.u64(30) as u32,
            ranks: rng.vec(0, 4, |r| r.u64(1 << 16) as u32),
            columns: rng.vec(0, 4, |r| r.usize(0, 500)),
            want_meta: rng.bool(0.5),
        }),
        9 => coord::Request::SubtreeDone(SubtreeDone {
            tree: rng.u64(100) as u32,
            root: rng.u64(1 << 20) as u32,
            rows: rng.raw_u64(),
            nodes: rng.u64(1 << 20) as u32,
        }),
        _ => coord::Request::TimeSync,
    }
}

fn random_coord_response(rng: &mut CaseRng) -> coord::Response {
    match rng.usize(0, 7) {
        0 => coord::Response::Ok,
        1 => coord::Response::RootStats(rng.vec(0, 5, |r| r.raw_u64())),
        2 => coord::Response::Splits(PartialSupersplit {
            splits: rng.vec(0, 4, |r| r.bool(0.6).then(|| random_candidate(r))),
        }),
        3 => coord::Response::Evals(EvalResult {
            bitmaps: rng.vec(0, 4, |r| (r.u64(1 << 16) as u32, random_bitmap(r))),
        }),
        4 => coord::Response::Err(rng.string(0, 20)),
        5 => coord::Response::Hello(coord::HelloInfo {
            protocol: rng.u64(u32::MAX as u64 + 1) as u32,
            shard: rng.u64(64) as u32,
            rows: rng.raw_u64(),
            num_classes: rng.u64(1 << 10) as u32,
            columns: rng.vec(0, 5, |r| r.u64(500) as u32),
        }),
        6 => coord::Response::Materialized(MaterializedLeaves {
            leaves: rng.vec(0, 3, |r| MaterializedLeaf {
                rows: r.raw_u64(),
                labels: r.vec(0, 4, |r| r.u64(1 << 10) as u32),
                bags: r.vec(0, 4, |r| r.u64(256) as u8),
                columns: r.vec(0, 3, |r| {
                    if r.bool(0.5) {
                        MaterializedColumn::Num(r.vec(0, 4, |r| r.f32()))
                    } else {
                        MaterializedColumn::Cat {
                            arity: r.usize(1, 50) as u32,
                            values: r.vec(0, 4, |r| r.u64(50) as u32),
                        }
                    }
                }),
            }),
        }),
        _ => coord::Response::TimeSync(random_time_sync(rng)),
    }
}

fn random_batch(rng: &mut CaseRng) -> serve::RowsBatch {
    serve::RowsBatch {
        columns: rng.vec(0, 3, |r| {
            if r.bool(0.5) {
                Column::Numerical(r.vec(0, 5, |r| r.f32()))
            } else {
                let arity = r.usize(1, 20) as u32;
                Column::Categorical {
                    values: r.vec(0, 5, |r| r.u64(arity as u64) as u32),
                    arity,
                }
            }
        }),
    }
}

fn random_serve_request(rng: &mut CaseRng) -> serve::ServeRequest {
    match rng.usize(0, 4) {
        0 => serve::ServeRequest::Score(random_batch(rng)),
        1 => serve::ServeRequest::Classify(random_batch(rng)),
        2 => serve::ServeRequest::ModelInfo,
        3 => serve::ServeRequest::Reload {
            path: rng.bool(0.5).then(|| rng.string(0, 12)),
        },
        _ => serve::ServeRequest::TimeSync,
    }
}

fn random_serve_response(rng: &mut CaseRng) -> serve::ServeResponse {
    match rng.usize(0, 5) {
        0 => serve::ServeResponse::Scores(rng.vec(0, 5, |r| r.f64())),
        1 => serve::ServeResponse::Classes(rng.vec(0, 5, |r| r.u64(1 << 10) as u32)),
        2 => serve::ServeResponse::Info(serve::ModelInfo {
            num_trees: rng.u64(1 << 16) as u32,
            num_classes: rng.u64(1 << 10) as u32,
            num_nodes: rng.raw_u64(),
        }),
        3 => serve::ServeResponse::Reloaded {
            num_trees: rng.u64(1 << 16) as u32,
        },
        4 => serve::ServeResponse::Err(rng.string(0, 20)),
        _ => serve::ServeResponse::TimeSync(random_time_sync(rng)),
    }
}

fn random_obj_request(rng: &mut CaseRng) -> obj::ObjRequest {
    match rng.usize(0, 2) {
        0 => obj::ObjRequest::Stat {
            path: rng.string(0, 16),
        },
        1 => obj::ObjRequest::Read {
            path: rng.string(0, 16),
            offset: rng.raw_u64(),
            len: rng.u64(1 << 20) as u32,
        },
        _ => obj::ObjRequest::TimeSync,
    }
}

fn random_obj_response(rng: &mut CaseRng) -> obj::ObjResponse {
    match rng.usize(0, 3) {
        0 => obj::ObjResponse::Stat { len: rng.raw_u64() },
        1 => obj::ObjResponse::Data(rng.vec(0, 16, |r| r.u64(256) as u8)),
        2 => obj::ObjResponse::TimeSync(random_time_sync(rng)),
        _ => obj::ObjResponse::Err(rng.string(0, 20)),
    }
}

/// The optional trace-context trailer must roundtrip — including its
/// absence — on every protocol that carries one, for arbitrary
/// messages.
#[test]
fn wire_trace_context_trailer_roundtrips_all_protocols() {
    run_cases(8, 60, |rng| {
        let ctx = random_ctx(rng);

        let req = random_coord_request(rng);
        let bytes = coord::encode_request_traced(&req, ctx.as_ref());
        let (back, got) = coord::decode_request_traced(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(got, ctx, "coordinator trailer");

        let id = rng.raw_u64();
        let sreq = random_serve_request(rng);
        let bytes = serve::encode_request_traced(id, &sreq, ctx.as_ref());
        let (gid, sback, sgot) = serve::decode_request_traced(&bytes).unwrap();
        assert_eq!((gid, sback), (id, sreq));
        assert_eq!(sgot, ctx, "serve trailer");

        let oreq = random_obj_request(rng);
        let bytes = obj::encode_request_traced(&oreq, ctx.as_ref());
        let (oback, ogot) = obj::decode_request_traced(&bytes).unwrap();
        assert_eq!(oback, oreq);
        assert_eq!(ogot, ctx, "objstore trailer");
    });
}

/// A context-free frame must be byte-identical to the pre-tracing
/// encoding on all three protocols (the compatibility promise the
/// protocol docs make), for arbitrary messages.
#[test]
fn wire_context_free_encoding_is_byte_identical() {
    run_cases(9, 60, |rng| {
        let req = random_coord_request(rng);
        assert_eq!(
            coord::encode_request(&req),
            coord::encode_request_traced(&req, None),
            "coordinator"
        );
        let id = rng.raw_u64();
        let sreq = random_serve_request(rng);
        assert_eq!(
            serve::encode_request(id, &sreq),
            serve::encode_request_traced(id, &sreq, None),
            "serve"
        );
        let oreq = random_obj_request(rng);
        assert_eq!(
            obj::encode_request(&oreq),
            obj::encode_request_traced(&oreq, None),
            "objstore"
        );
    });
}

/// Responses (which never carry trailers) roundtrip for arbitrary
/// messages on all three protocols.
#[test]
fn wire_response_roundtrip_random_messages() {
    run_cases(10, 60, |rng| {
        let resp = random_coord_response(rng);
        let back = coord::decode_response(&coord::encode_response(&resp)).unwrap();
        assert_eq!(back, resp);

        let id = rng.raw_u64();
        let sresp = random_serve_response(rng);
        let back = serve::decode_response(&serve::encode_response(id, &sresp)).unwrap();
        assert_eq!(back, (id, sresp));

        let oresp = random_obj_response(rng);
        let back = obj::decode_response(&obj::encode_response(&oresp)).unwrap();
        assert_eq!(back, oresp);
    });
}
