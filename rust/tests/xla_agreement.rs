//! Rust ⇄ XLA artifact integration: the AOT-compiled Pallas split
//! scorer must agree with the exact scalar scorer. Requires
//! `make artifacts`; tests skip (with a loud message) if artifacts are
//! missing.

use drf::config::{ForestParams, ScorerBackend, TrainConfig};
use drf::data::column::Column;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::rng::BaggingMode;
use drf::runtime::XlaRuntime;
use drf::splits::histogram::Histogram;
use drf::splits::numerical::best_numerical_supersplit;
use drf::splits::scorer::ScoreKind;
use drf::splits::xla_scorer::{
    best_numerical_supersplit_xla, ScoreTask, ScoreTasks, XlaScorer,
};
use drf::util::proptest::run_cases;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join(XlaScorer::artifact_name(4, 64)).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        None
    }
}

#[test]
fn scorer_loads_and_scores_simple_case() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::cpu().unwrap();
    let scorer = XlaScorer::load(&rt, &dir, 4, 64).unwrap();
    // labels 0,0,0,1,1,1 at distinct values: best boundary idx 2, gain 0.5.
    let task = ScoreTask {
        pos_prefix: vec![0.0, 0.0, 0.0, 1.0, 2.0],
        tot_prefix: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        parent_pos: 3.0,
        parent_tot: 6.0,
    };
    let out = scorer.score_tasks(&[task]).unwrap();
    let (idx, gain) = out[0].unwrap();
    assert_eq!(idx, 2);
    assert!((gain - 0.5).abs() < 1e-6);
}

#[test]
fn xla_matches_native_scorer_on_random_tasks() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::cpu().unwrap();
    let scorer = XlaScorer::load(&rt, &dir, 4, 64).unwrap();

    run_cases(0xA9, 10, |rng| {
        // Random sorted column + labels, single leaf.
        let n = rng.usize(5, 200);
        let values: Vec<f32> = (0..n).map(|_| (rng.usize(0, 30) as f32) * 0.5).collect();
        let labels: Vec<u32> = (0..n).map(|_| rng.bool(0.4) as u32).collect();
        let col = Column::Numerical(values);
        let q = col.presort();
        let mut total = Histogram::new(2);
        for &y in &labels {
            total.add(y, 1);
        }
        let totals = vec![total];

        let native = best_numerical_supersplit(
            0, &q, &labels, 2, &totals, ScoreKind::Gini, |_| 1, |_| true, |_| 1,
        );
        let xla = best_numerical_supersplit_xla(
            &scorer, 0, &q, &labels, &totals, |_| 1, |_| true, |_| 1,
        )
        .unwrap();
        match (&native[0], &xla[0]) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                // f32 vs f64 rounding: gains agree to ~1e-5; on exact
                // ties the argmax may pick a different boundary, so
                // compare gains, not thresholds.
                assert!(
                    (a.gain - b.gain).abs() < 1e-4 * a.gain.max(1e-3),
                    "gain mismatch: native {} vs xla {}",
                    a.gain,
                    b.gain
                );
            }
            (a, b) => panic!("split presence mismatch: native {a:?} vs xla {b:?}"),
        }
    });
}

#[test]
fn xla_chunking_handles_more_boundaries_than_t() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::cpu().unwrap();
    let scorer = XlaScorer::load(&rt, &dir, 4, 64).unwrap();
    // 300 boundaries > T=64: forces multi-chunk reduction. Put the best
    // boundary deep in the 3rd chunk.
    let n = 300usize;
    let mut pos = Vec::new();
    let mut tot = Vec::new();
    let (mut p, mut t) = (0f32, 0f32);
    for i in 0..n {
        t += 1.0;
        if i >= 200 {
            p += 1.0;
        }
        pos.push(p);
        tot.push(t);
    }
    let task = ScoreTask {
        pos_prefix: pos,
        tot_prefix: tot,
        parent_pos: 101.0,
        parent_tot: 301.0,
    };
    let out = scorer.score_tasks(&[task]).unwrap();
    let (idx, gain) = out[0].unwrap();
    assert!(gain > 0.0);
    // Boundary i has the first i+1 records on the left; all 200
    // negatives are left of boundary 199.
    assert_eq!(idx, 199);
}

#[test]
fn full_training_with_xla_backend_matches_native_auc() {
    let Some(dir) = artifacts_dir() else { return };
    // End-to-end: train with the XLA scorer backend. The model may not
    // be bit-identical (f32 scoring) but must have statistically
    // indistinguishable quality and identical structure on well-
    // separated data.
    let train = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 800, 6, 31).generate();
    let test = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 500, 6, 32).generate();
    let params = ForestParams {
        num_trees: 3,
        max_depth: 6,
        bagging: BaggingMode::Poisson,
        seed: 7,
        ..Default::default()
    };
    let native_cfg = TrainConfig {
        forest: params,
        ..Default::default()
    };
    let (native, _) = RandomForest::train_with_config(&train, &native_cfg).unwrap();
    let xla_cfg = TrainConfig {
        forest: params,
        scorer: ScorerBackend::Xla,
        artifacts_dir: Some(dir),
        ..Default::default()
    };
    let (xla, _) = RandomForest::train_with_config(&train, &xla_cfg).unwrap();
    let auc_native = drf::metrics::auc(&native.predict_scores(&test), test.labels());
    let auc_xla = drf::metrics::auc(&xla.predict_scores(&test), test.labels());
    assert!(
        (auc_native - auc_xla).abs() < 0.05,
        "AUC drift: native {auc_native} vs xla {auc_xla}"
    );
    assert!(auc_xla > 0.8, "xla-backed forest should learn, AUC {auc_xla}");
}
