//! Multiclass coverage: the paper's experiments are binary, but the
//! algorithm (and this implementation) is generic over the number of
//! classes — histograms, Gini/entropy, numerical supersplits, and the
//! one-vs-rest categorical fallback. Exactness must hold here too.

use drf::baselines::classic::ClassicTrainer;
use drf::config::{ForestParams, TrainConfig};
use drf::data::column::Column;
use drf::data::schema::{ColumnSpec, Schema};
use drf::data::Dataset;
use drf::forest::RandomForest;
use drf::metrics::accuracy;
use drf::rng::{BaggingMode, SplitMix64};
use drf::splits::ScoreKind;

/// 3-class dataset: class = which of three intervals x falls in, plus a
/// categorical feature whose value leaks the class for half the rows.
fn three_class(n: usize, seed: u64) -> Dataset {
    let u = |tag: u64, i: usize| {
        (SplitMix64::hash_key(&[seed, tag, i as u64]) >> 11) as f64 / (1u64 << 53) as f64
    };
    let xs: Vec<f32> = (0..n).map(|i| u(1, i) as f32).collect();
    let labels: Vec<u32> = xs
        .iter()
        .map(|&x| {
            if x < 0.33 {
                0
            } else if x < 0.66 {
                1
            } else {
                2
            }
        })
        .collect();
    let cats: Vec<u32> = (0..n)
        .map(|i| {
            if u(2, i) < 0.5 {
                labels[i] + 3 // leaky values 3,4,5
            } else {
                (u(3, i) * 3.0) as u32 // noise values 0,1,2
            }
        })
        .collect();
    let noise: Vec<f32> = (0..n).map(|i| u(4, i) as f32).collect();
    Dataset::new(
        Schema::new(
            vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("c", 6),
                ColumnSpec::numerical("noise"),
            ],
            3,
        ),
        vec![
            Column::Numerical(xs),
            Column::Categorical {
                values: cats,
                arity: 6,
            },
            Column::Numerical(noise),
        ],
        labels,
    )
}

#[test]
fn multiclass_forest_learns() {
    let train = three_class(3000, 1);
    let test = three_class(1000, 2);
    let params = ForestParams {
        num_trees: 10,
        max_depth: 8,
        seed: 5,
        ..Default::default()
    };
    let forest = RandomForest::train(&train, &params).unwrap();
    let acc = accuracy(&forest.predict_classes(&test), test.labels());
    assert!(acc > 0.9, "3-class accuracy {acc}");
}

#[test]
fn multiclass_exactness() {
    let ds = three_class(700, 3);
    for score_kind in [ScoreKind::Gini, ScoreKind::Entropy] {
        let params = ForestParams {
            num_trees: 2,
            max_depth: 6,
            min_records: 5,
            bagging: BaggingMode::Poisson,
            score_kind,
            seed: 77,
            ..Default::default()
        };
        let classic = ClassicTrainer::new(&ds, &params).train_forest();
        let cfg = TrainConfig {
            forest: params,
            ..Default::default()
        };
        let (drf, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        assert_eq!(classic, drf.trees, "multiclass exactness ({score_kind:?})");
    }
}

#[test]
fn entropy_vs_gini_differ_but_both_learn() {
    let train = three_class(2000, 4);
    let test = three_class(800, 5);
    let mk = |kind| {
        let params = ForestParams {
            num_trees: 5,
            max_depth: 8,
            score_kind: kind,
            seed: 6,
            ..Default::default()
        };
        RandomForest::train(&train, &params).unwrap()
    };
    let gini = mk(ScoreKind::Gini);
    let entropy = mk(ScoreKind::Entropy);
    assert!(accuracy(&gini.predict_classes(&test), test.labels()) > 0.85);
    assert!(accuracy(&entropy.predict_classes(&test), test.labels()) > 0.85);
}
