//! Distributed-runtime invariants: network accounting matches the
//! paper's claims (Dn-bit broadcasts, no index shipping), class-list
//! memory follows the n·⌈log2(ℓ+1)⌉ formula, latency insensitivity,
//! and engine/storage equivalence.

use drf::classlist::width_for;
use drf::config::{Engine, ForestParams, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::forest::RandomForest;
use drf::rng::BaggingMode;

fn base_cfg(trees: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        forest: ForestParams {
            num_trees: trees,
            max_depth: 6,
            bagging: BaggingMode::Poisson,
            seed,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn network_scales_with_levels_not_nodes() {
    // DRF's broadcast volume is ~ (levels x n bits x splitters), NOT
    // per-node. Compare a deep tree against the level count.
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 2000, 6, 1).generate();
    let cfg = base_cfg(1, 9);
    let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    let levels = report.per_tree[0].levels.len() as u64;
    assert!(levels >= 3);
    let w = report.num_splitters as u64;
    let n = ds.num_rows() as u64;
    // Upper bound: every level broadcasts at most ~n/8 bytes (1 bit per
    // live sample) to w splitters, plus queries/answers overhead that is
    // O(leaves x classes), far below n for this dataset.
    let broadcast_bound = levels * (n / 8 + 64) * w;
    let total = report.net.net_bytes;
    assert!(
        total < broadcast_bound * 3,
        "net {total} should be O(levels*n*w) = {broadcast_bound}"
    );
    // And the model actually has many more nodes than levels (so
    // per-node broadcasting would have cost much more).
    assert!(forest.trees[0].num_nodes() as u64 > levels * 2);
}

#[test]
fn no_bagging_indices_on_the_wire() {
    // Seeded bagging (§2.2): network bytes must NOT grow with the
    // number of bagged records beyond the 1-bit-per-sample updates.
    // Train on n and 2n rows with 1 splitter; the ratio of net bytes
    // must be ~2 (bitmaps scale) not ~2x8 bytes/index.
    let mk = |n: usize| {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, n, 4, 3).generate();
        let mut cfg = base_cfg(1, 4);
        cfg.forest.max_depth = 3;
        cfg.topology.num_splitters = Some(1);
        let (_, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        report.net.net_bytes as f64
    };
    let b1 = mk(2000);
    let b2 = mk(4000);
    let ratio = b2 / b1;
    assert!(
        ratio < 2.6,
        "net bytes ratio {ratio} suggests per-index shipping"
    );
}

#[test]
fn class_list_width_is_logarithmic() {
    // Indirect check through the formula + a training run that reaches
    // many leaves: width_for matches ⌈log2(ℓ+1)⌉ and the level stats
    // report hundreds of leaves.
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 4 }, 4000, 6, 8).generate();
    let mut cfg = base_cfg(1, 5);
    cfg.forest.max_depth = 10;
    cfg.forest.min_records = 2;
    let (_, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    let max_open = report.per_tree[0]
        .levels
        .iter()
        .map(|l| l.open_after)
        .max()
        .unwrap();
    assert!(max_open > 20, "expected a bushy tree, got {max_open} leaves");
    assert_eq!(width_for(1), 1);
    assert_eq!(width_for(max_open), (max_open as u64 + 1).next_power_of_two().trailing_zeros().max(1));
}

#[test]
fn latency_insensitivity_messages_scale_with_depth() {
    // DRF is "relatively insensitive to the latency of communication"
    // (§2) because the message COUNT is O(splitters x depth), not O(n).
    let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 3000, 6, 3).generate();
    let cfg = base_cfg(1, 4);
    let (_, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    let levels = report.per_tree[0].levels.len() as u64;
    let w = report.num_splitters as u64;
    let msgs = report.net.net_messages;
    // Per level: <= w find queries+answers, <= w eval pairs, w broadcast,
    // plus constant tree start/finish traffic.
    let bound = levels * w * 6 + 4 * w + 10;
    assert!(
        msgs <= bound,
        "messages {msgs} exceed O(w x depth) bound {bound} — latency sensitivity"
    );
}

#[test]
fn report_levels_are_consistent() {
    let ds = LeoLikeSpec::new(1500, 3).generate();
    let mut cfg = base_cfg(2, 6);
    cfg.forest.min_records = 10;
    let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    for (t, tr) in report.per_tree.iter().enumerate() {
        // open_after of level k == open_before of level k+1.
        for w in tr.levels.windows(2) {
            assert_eq!(w[0].open_after, w[1].open_before);
        }
        // splits + closed == open_before
        for l in &tr.levels {
            assert_eq!(l.num_splits + l.num_closed, l.open_before);
            assert!(l.z_max_load >= 1);
            assert!(l.m_double_prime >= 1);
        }
        // Tree depth equals number of levels with splits.
        let levels_with_splits = tr.levels.iter().filter(|l| l.num_splits > 0).count() as u32;
        assert_eq!(forest.trees[t].depth(), levels_with_splits);
    }
}

#[test]
fn threaded_parallel_trees_identical_to_direct() {
    let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 800, 6, 12).generate();
    let cfg = base_cfg(4, 77);
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    let mut cfg2 = base_cfg(4, 77);
    cfg2.engine = Engine::Threaded;
    cfg2.topology.tree_builders = 3;
    let (threaded, _) = RandomForest::train_with_config(&ds, &cfg2).unwrap();
    assert_eq!(direct, threaded);
}

#[test]
fn tcp_engine_identical_to_direct() {
    let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 400, 6, 8).generate();
    let cfg = base_cfg(2, 55);
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    let mut cfg2 = base_cfg(2, 55);
    cfg2.engine = Engine::Tcp;
    let (tcp, report) = RandomForest::train_with_config(&ds, &cfg2).unwrap();
    assert_eq!(direct, tcp, "TCP engine must not change the model");
    assert!(report.net.net_bytes > 0, "real bytes over real sockets");
}

#[test]
fn disk_mode_reads_are_sequential_passes() {
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 2 }, 500, 4, 3).generate();
    let mut cfg = base_cfg(1, 3);
    cfg.storage = StorageMode::Disk;
    cfg.forest.max_depth = 3;
    let (_, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    let total_passes: u64 = report
        .splitter_io
        .iter()
        .map(|s| s.disk_read_passes)
        .sum();
    let total_read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
    assert!(total_passes > 0 && total_read > 0);
    // Reads per pass ~ column size: bytes/passes should be less than
    // around one full column (sorted entries are 8B/row + header).
    let per_pass = total_read / total_passes;
    assert!(
        per_pass <= 8 * 500 + 200,
        "per-pass bytes {per_pass} exceeds one sequential column scan"
    );
}

#[test]
fn feature_importance_finds_planted_signal_distributed() {
    let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 2500, 10, 6).generate();
    let mut cfg = base_cfg(8, 15);
    cfg.forest.max_depth = 8;
    let (forest, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
    let imp = drf::forest::importance::mdi_importance(&forest, 10);
    let ranks = drf::forest::importance::rank_features(&imp);
    let top: std::collections::HashSet<usize> = ranks[..3].iter().copied().collect();
    assert_eq!(top, [0usize, 1, 2].into_iter().collect());
}
