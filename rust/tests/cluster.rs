//! End-to-end cluster plane: `drf shard` + real `drf worker` OS
//! processes + `--engine cluster` training must produce forests
//! bit-identical to `--engine direct` — including across one injected
//! worker kill + restart mid-training (replay recovery), and under a
//! real `drf supervise` control process with chaos kills and an
//! elastic drain mid-run.

use drf::cluster::{ClusterOptions, ClusterPool};
use drf::config::{Engine, TopologyParams, TrainConfig};
use drf::coordinator::messages::{
    EvalQuery, EvalResult, LevelUpdate, MaterializeQuery, MaterializedLeaves, PartialSupersplit,
    SubtreeDone, SupersplitQuery,
};
use drf::coordinator::recovery::RecoveringPool;
use drf::coordinator::topology::Topology;
use drf::coordinator::transport::SplitterPool;
use drf::coordinator::tree_builder::TreeBuilderCore;
use drf::coordinator::wire::{HelloConfig, PROTOCOL_VERSION};
use drf::data::io_stats::IoStats;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use std::io::BufRead;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

const DRF_BIN: &str = env!("CARGO_BIN_EXE_drf");
const ROWS: usize = 400;
const FEATURES: usize = 6;
const SEED: u64 = 41;

/// The trace sink is process-global; tests that redirect it must not
/// overlap (cargo runs tests in this binary on parallel threads).
static TRACE_SINK_LOCK: Mutex<()> = Mutex::new(());

/// Kills the worker process when dropped (panic-safe cleanup).
struct ChildGuard(Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// The dataset the shard CLI invocation below generates (synthetic
/// generation is deterministic in its spec, so the in-process copy is
/// byte-identical to what the packs were cut from).
fn dataset() -> drf::data::Dataset {
    SyntheticSpec::new(Family::Xor { informative: 3 }, ROWS, FEATURES, SEED).generate()
}

/// Run `drf shard` (the real CLI) into `dir` for `splitters` shards.
fn shard_via_cli(dir: &Path, splitters: usize) {
    let status = Command::new(DRF_BIN)
        .args([
            "shard",
            "--family",
            "xor",
            "--informative",
            "3",
            "--rows",
            &ROWS.to_string(),
            "--features",
            &FEATURES.to_string(),
            "--seed",
            &SEED.to_string(),
            "--splitters",
            &splitters.to_string(),
            "--chunk-rows",
            "128",
            "--out-dir",
            dir.to_str().unwrap(),
        ])
        .status()
        .expect("running drf shard");
    assert!(status.success(), "drf shard failed: {status}");
}

/// Spawn a real `drf worker` process on an ephemeral port and parse
/// the bound address from its ready line.
fn spawn_worker(shard_dir: &Path) -> (ChildGuard, String) {
    spawn_worker_args(shard_dir, &[])
}

/// `spawn_worker` plus extra CLI flags (e.g. `--trace-out FILE`).
fn spawn_worker_args(shard_dir: &Path, extra: &[&str]) -> (ChildGuard, String) {
    let mut child = Command::new(DRF_BIN)
        .args([
            "worker",
            "--shard",
            shard_dir.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
        ])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawning drf worker");
    let stdout = child.stdout.take().expect("worker stdout piped");
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .expect("reading worker ready line");
    assert!(
        line.contains("listening on"),
        "unexpected worker output: {line:?}"
    );
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .expect("address token")
        .to_string();
    (ChildGuard(child), addr)
}

/// Spawn a worker that also exposes `GET /metrics`, returning its RPC
/// address and its metrics address (both ephemeral, from ready lines).
fn spawn_worker_with_metrics(shard_dir: &Path) -> (ChildGuard, String, String) {
    let mut child = Command::new(DRF_BIN)
        .args([
            "worker",
            "--shard",
            shard_dir.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--metrics-addr",
            "127.0.0.1:0",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawning drf worker");
    let stdout = child.stdout.take().expect("worker stdout piped");
    let mut reader = std::io::BufReader::new(stdout);
    let mut ready = String::new();
    reader.read_line(&mut ready).expect("reading worker ready line");
    assert!(
        ready.contains("listening on"),
        "unexpected worker output: {ready:?}"
    );
    let addr = ready.trim().rsplit(' ').next().unwrap().to_string();
    let mut metrics = String::new();
    reader
        .read_line(&mut metrics)
        .expect("reading worker metrics ready line");
    assert!(
        metrics.contains("metrics on"),
        "unexpected worker output: {metrics:?}"
    );
    let maddr = metrics.trim().rsplit(' ').next().unwrap().to_string();
    (ChildGuard(child), addr, maddr)
}

/// The value of an unlabelled series in a Prometheus text body.
fn series_value(body: &str, series: &str) -> Option<u64> {
    body.lines().find_map(|l| {
        let rest = l.strip_prefix(series)?;
        rest.strip_prefix(' ')?.trim().parse().ok()
    })
}

fn forest_cfg(splitters: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.forest.num_trees = 2;
    cfg.forest.max_depth = 6;
    cfg.forest.seed = SEED;
    cfg.topology.num_splitters = Some(splitters);
    cfg
}

#[test]
fn cluster_worker_processes_match_direct_engine() {
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 2);
    let ds = dataset();

    let (_g0, addr0) = spawn_worker(&tmp.path().join("shard_0"));
    let (_g1, addr1) = spawn_worker(&tmp.path().join("shard_1"));

    // Reference: the plain in-process engine, same seed and topology.
    let cfg = forest_cfg(2);
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();

    // Cluster: train against the two worker processes.
    let mut ccfg = cfg.clone();
    ccfg.engine = Engine::Cluster;
    ccfg.cluster_manifest = Some(tmp.path().join("cluster.json"));
    ccfg.cluster_workers = vec![addr0, addr1];
    let (clustered, report) = RandomForest::train_with_config(&ds, &ccfg).unwrap();

    assert_eq!(
        direct.trees, clustered.trees,
        "cluster engine must be bit-identical to direct"
    );
    assert!(report.net.net_bytes > 0, "bytes actually crossed sockets");
    assert_eq!(report.num_splitters, 2);
}

#[test]
fn cluster_telemetry_scrapes_and_forests_stay_bit_identical() {
    let _trace_lock = TRACE_SINK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 2);
    let ds = dataset();

    // Reference: telemetry plays no part in the in-process engine run.
    let cfg = forest_cfg(2);
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();

    let (_g0, addr0, maddr0) = spawn_worker_with_metrics(&tmp.path().join("shard_0"));
    let (_g1, addr1, _maddr1) = spawn_worker_with_metrics(&tmp.path().join("shard_1"));

    // Train the cluster engine with the span trace sink on. Counters
    // are process-global and tests share the process, so compare
    // against a snapshot instead of asserting absolute values.
    let trace_path = tmp.path().join("trace.jsonl");
    drf::telemetry::set_trace_out(&trace_path).unwrap();
    let rounds_before =
        series_value(&drf::telemetry::render(), "drf_cluster_rounds_total").unwrap_or(0);

    let mut ccfg = cfg.clone();
    ccfg.engine = Engine::Cluster;
    ccfg.cluster_manifest = Some(tmp.path().join("cluster.json"));
    ccfg.cluster_workers = vec![addr0, addr1];
    let (clustered, _) = RandomForest::train_with_config(&ds, &ccfg).unwrap();
    drf::telemetry::clear_trace_out();

    assert_eq!(
        direct.trees, clustered.trees,
        "tracing + metrics must not change the forest"
    );

    // The leader-side registry recorded the level-update rounds.
    let body = drf::telemetry::render();
    let rounds = series_value(&body, "drf_cluster_rounds_total").expect("rounds counter");
    assert!(rounds > rounds_before, "no cluster rounds recorded:\n{body}");
    assert!(
        body.contains("drf_cluster_rpc_us_bucket"),
        "no per-worker RPC latency histogram:\n{body}"
    );

    // A live worker answers the `drf metrics ADDR` CLI with its own
    // registry: shard gauge plus the IoStats the scans charged.
    let out = Command::new(DRF_BIN)
        .args(["metrics", &maddr0])
        .output()
        .expect("running drf metrics");
    assert!(out.status.success(), "drf metrics failed: {out:?}");
    let scraped = String::from_utf8(out.stdout).unwrap();
    assert!(
        scraped.contains("drf_worker_shard"),
        "worker scrape missing shard gauge:\n{scraped}"
    );
    let net = series_value(&scraped, "drf_worker_io_net_bytes").expect("worker net gauge");
    assert!(net > 0, "worker served a training run but reports no net bytes");

    // The trace sink got well-formed JSONL events, including span
    // events for the per-level scan phase.
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    let mut spans = 0usize;
    let mut saw_level_scan = false;
    for line in trace.lines() {
        let j = drf::util::Json::parse(line).expect("trace line parses as JSON");
        match j.get("event").unwrap().as_str().unwrap() {
            "span" => {
                assert!(j.get("dur_us").unwrap().as_u64().is_ok());
                assert!(j.get("span_id").unwrap().as_u64().unwrap() > 0);
                if j.get("phase").unwrap().as_str().unwrap() == "level_scan" {
                    saw_level_scan = true;
                }
                spans += 1;
            }
            // The stream also carries `proc` identity and `clock_sync`
            // offset events — the inputs `drf trace merge` aligns on.
            "proc" | "clock_sync" => {}
            other => panic!("unexpected trace event type {other:?}"),
        }
    }
    assert!(spans > 0, "no span events in the trace");
    assert!(saw_level_scan, "trace missing level_scan spans");
}

#[test]
fn merged_trace_parents_worker_spans_under_leader_rounds() {
    let _trace_lock = TRACE_SINK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 2);
    let ds = dataset();
    let cfg = forest_cfg(2);

    // Two real worker processes, each streaming its own trace file.
    let w0_trace = tmp.path().join("w0.jsonl");
    let w1_trace = tmp.path().join("w1.jsonl");
    let (_g0, addr0) = spawn_worker_args(
        &tmp.path().join("shard_0"),
        &["--trace-out", w0_trace.to_str().unwrap()],
    );
    let (_g1, addr1) = spawn_worker_args(
        &tmp.path().join("shard_1"),
        &["--trace-out", w1_trace.to_str().unwrap()],
    );

    // This test process is the leader.
    let leader_trace = tmp.path().join("leader.jsonl");
    drf::telemetry::set_proc_identity("leader", None);
    drf::telemetry::set_trace_out(&leader_trace).unwrap();

    let mut ccfg = cfg.clone();
    ccfg.engine = Engine::Cluster;
    ccfg.cluster_manifest = Some(tmp.path().join("cluster.json"));
    ccfg.cluster_workers = vec![addr0, addr1];
    let (_forest, _) = RandomForest::train_with_config(&ds, &ccfg).unwrap();
    drf::telemetry::clear_trace_out();

    // Worker span events are written before the RPC response frame, so
    // once training returned the files are complete.
    let files = [leader_trace, w0_trace, w1_trace];
    let merged = drf::telemetry::trace::merge_files(&files).unwrap();

    // One trace: every process that recorded an id recorded the same
    // one (workers adopt the leader's id from the wire context).
    let ids: Vec<u64> = merged
        .files
        .iter()
        .map(|f| f.trace_id)
        .filter(|&i| i != 0)
        .collect();
    assert_eq!(ids.len(), 3, "some process never saw the trace id: {ids:?}");
    assert!(ids.windows(2).all(|w| w[0] == w[1]), "trace ids differ: {ids:?}");

    // The leader roots the clock alignment and the handshake
    // clock-sync reached both workers.
    assert_eq!(merged.files[merged.root].role, "leader");
    assert!(
        merged.unaligned.is_empty(),
        "worker clocks not aligned: {:?}",
        merged.unaligned
    );

    // Every worker find_splits span parents under the leader's
    // level_scan span for the same (tree, depth) — the cross-process
    // context actually propagated.
    let leader = &merged.files[merged.root];
    let scan_rounds: std::collections::HashMap<u64, (f64, f64)> = leader
        .spans
        .iter()
        .filter(|s| s.phase == "level_scan")
        .map(|s| {
            (
                s.span_id,
                (s.field("tree").unwrap(), s.field("depth").unwrap()),
            )
        })
        .collect();
    let mut parented = 0usize;
    for f in merged.files.iter().filter(|f| f.role == "worker") {
        for s in f.spans.iter().filter(|s| s.phase == "find_splits") {
            let (tree, depth) = scan_rounds.get(&s.parent_id).copied().unwrap_or_else(|| {
                panic!("find_splits span {s:?} does not parent under a leader level_scan span")
            });
            assert_eq!(s.field("tree"), Some(tree));
            assert_eq!(s.field("depth"), Some(depth));
            parented += 1;
        }
    }
    assert!(parented > 0, "no worker find_splits spans were recorded");

    // The merged Chrome JSON round-trips and holds every span.
    let out_json = tmp.path().join("merged.json");
    drf::telemetry::trace::merge_to_file(&files, &out_json).unwrap();
    let chrome = drf::util::Json::parse(&std::fs::read_to_string(&out_json).unwrap()).unwrap();
    let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    let total_spans: usize = merged.files.iter().map(|f| f.spans.len()).sum();
    // One metadata event per process plus one X event per span.
    assert_eq!(events.len(), merged.files.len() + total_spans);

    // The report names a straggler worker and its dominant phase for
    // every round.
    let rows = merged.round_rows();
    assert!(!rows.is_empty(), "report found no rounds");
    for r in &rows {
        assert!(
            r.straggler.starts_with("worker/"),
            "straggler is not a worker: {r:?}"
        );
        assert!(!r.dominant_phase.is_empty(), "no dominant phase: {r:?}");
        assert!(r.straggler_us >= r.median_us, "{r:?}");
    }
    let report = merged.report();
    assert!(report.contains("worker/"), "{report}");
    assert!(report.contains("busy time by process and phase"), "{report}");
}

/// Delegating pool that kills + restarts one worker process the first
/// time a supersplit query for `trigger_depth` comes through — i.e.
/// deterministically mid-tree, after the replay log has real entries.
struct KillOnce<'a> {
    inner: &'a ClusterPool,
    kill: Box<dyn Fn() + Send + Sync + 'a>,
    fired: AtomicBool,
    trigger_depth: u32,
}

impl SplitterPool for KillOnce<'_> {
    fn num_splitters(&self) -> usize {
        self.inner.num_splitters()
    }

    fn columns_of(&self, splitter: usize) -> Vec<usize> {
        self.inner.columns_of(splitter)
    }

    fn start_tree(&self, tree: u32) -> anyhow::Result<()> {
        self.inner.start_tree(tree)
    }

    fn root_stats(&self, splitter: usize, tree: u32) -> anyhow::Result<Vec<u64>> {
        self.inner.root_stats(splitter, tree)
    }

    fn find_splits(
        &self,
        splitter: usize,
        q: &SupersplitQuery,
    ) -> anyhow::Result<PartialSupersplit> {
        if q.depth == self.trigger_depth && !self.fired.swap(true, Ordering::SeqCst) {
            (self.kill)();
        }
        self.inner.find_splits(splitter, q)
    }

    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> anyhow::Result<EvalResult> {
        self.inner.eval_conditions(splitter, q)
    }

    fn broadcast_level_update(&self, u: &LevelUpdate) -> anyhow::Result<()> {
        self.inner.broadcast_level_update(u)
    }

    fn materialize(
        &self,
        splitter: usize,
        q: &MaterializeQuery,
    ) -> anyhow::Result<MaterializedLeaves> {
        self.inner.materialize(splitter, q)
    }

    fn broadcast_subtree_done(&self, d: &SubtreeDone) -> anyhow::Result<()> {
        self.inner.broadcast_subtree_done(d)
    }

    fn broadcast_subtree_done_on(&self, splitter: usize, d: &SubtreeDone) -> anyhow::Result<()> {
        self.inner.broadcast_subtree_done_on(splitter, d)
    }

    fn finish_tree(&self, tree: u32) -> anyhow::Result<()> {
        self.inner.finish_tree(tree)
    }

    fn net_stats(&self) -> IoStats {
        self.inner.net_stats()
    }

    fn start_tree_on(&self, splitter: usize, tree: u32) -> anyhow::Result<()> {
        self.inner.start_tree_on(splitter, tree)
    }

    fn apply_level_update_on(&self, splitter: usize, u: &LevelUpdate) -> anyhow::Result<()> {
        self.inner.apply_level_update_on(splitter, u)
    }

    fn finish_tree_on(&self, splitter: usize, tree: u32) -> anyhow::Result<()> {
        self.inner.finish_tree_on(splitter, tree)
    }
}

#[test]
fn training_survives_worker_kill_and_restart() {
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 2);
    let ds = dataset();
    let cfg = forest_cfg(2);
    let topo = Topology::new(
        ds.num_features(),
        &TopologyParams {
            num_splitters: Some(2),
            ..Default::default()
        },
    );

    // Reference forest from the in-process engine.
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();

    let (_keep0, addr0) = spawn_worker(&tmp.path().join("shard_0"));
    let (g1, addr1) = spawn_worker(&tmp.path().join("shard_1"));
    let victim = Mutex::new(g1);

    let hello = HelloConfig {
        protocol: PROTOCOL_VERSION,
        shard: 0,
        num_splitters: 2,
        redundancy: 1,
        seed: cfg.forest.seed,
        bagging: cfg.forest.bagging.as_str().into(),
        sampling: cfg.forest.feature_sampling.as_str().into(),
        num_candidates: cfg.forest.candidates_for(FEATURES) as u32,
        score_kind: cfg.forest.score_kind.as_str().into(),
        prune_threshold: None,
        split_search: "exact".into(),
        depth_next_rows: 0,
        topology_version: 0,
    };
    let pool = ClusterPool::connect(
        &[addr0, addr1],
        &topo,
        hello,
        ROWS as u64,
        ds.num_classes(),
        ClusterOptions::default(),
    )
    .unwrap();

    // Kill worker 1 mid-tree and restart it from the same shard pack
    // on a fresh ephemeral port (a same-port rebind would trip over
    // the dead process's lingering sockets), redirecting the leader
    // like a supervisor would. The restarted worker has no tree state
    // — the recovery layer must replay the level-update log.
    let shard1_dir = tmp.path().join("shard_1");
    let kill = || {
        let mut guard = victim.lock().unwrap();
        let _ = guard.0.kill();
        let _ = guard.0.wait();
        let (fresh, new_addr) = spawn_worker(&shard1_dir);
        pool.set_worker_addr(1, &new_addr).unwrap();
        *guard = fresh;
    };
    let killer = KillOnce {
        inner: &pool,
        kill: Box::new(kill),
        fired: AtomicBool::new(false),
        trigger_depth: 2,
    };
    let recovering = RecoveringPool::new(killer);
    let builder = TreeBuilderCore::new(&recovering, &topo, &cfg.forest, ds.num_features());
    let trees: Vec<_> = (0..cfg.forest.num_trees as u32)
        .map(|t| builder.build_tree(t).unwrap().0)
        .collect();

    assert!(
        recovering.inner().fired.load(Ordering::SeqCst),
        "the kill must actually have fired (tree never reached depth 2?)"
    );
    assert!(
        recovering.recoveries() >= 1,
        "the restarted worker must have been rebuilt by replay"
    );
    assert_eq!(
        direct.trees, trees,
        "a worker kill + restart mid-training must not change the forest"
    );
}

#[test]
fn depth_next_training_survives_worker_kill_and_restart() {
    // Same drill with the hybrid schedule engaged: a 40-row switch
    // threshold keeps the first levels breadth-first (so the depth-2
    // kill fires while the replay log matters), then detaches the
    // frontier — the restarted worker must serve Materialize extracts
    // and accept SubtreeDone notices it has no memory of.
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 2);
    let ds = dataset();
    let mut cfg = forest_cfg(2);
    cfg.depth_next_rows = 40;
    let topo = Topology::new(
        ds.num_features(),
        &TopologyParams {
            num_splitters: Some(2),
            ..Default::default()
        },
    );

    // Reference forest from the in-process engine, same switch budget.
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();

    let (_keep0, addr0) = spawn_worker(&tmp.path().join("shard_0"));
    let (g1, addr1) = spawn_worker(&tmp.path().join("shard_1"));
    let victim = Mutex::new(g1);

    let hello = HelloConfig {
        protocol: PROTOCOL_VERSION,
        shard: 0,
        num_splitters: 2,
        redundancy: 1,
        seed: cfg.forest.seed,
        bagging: cfg.forest.bagging.as_str().into(),
        sampling: cfg.forest.feature_sampling.as_str().into(),
        num_candidates: cfg.forest.candidates_for(FEATURES) as u32,
        score_kind: cfg.forest.score_kind.as_str().into(),
        prune_threshold: None,
        split_search: "exact".into(),
        depth_next_rows: cfg.depth_next_rows,
        topology_version: 0,
    };
    let pool = ClusterPool::connect(
        &[addr0, addr1],
        &topo,
        hello,
        ROWS as u64,
        ds.num_classes(),
        ClusterOptions::default(),
    )
    .unwrap();

    let shard1_dir = tmp.path().join("shard_1");
    let kill = || {
        let mut guard = victim.lock().unwrap();
        let _ = guard.0.kill();
        let _ = guard.0.wait();
        let (fresh, new_addr) = spawn_worker(&shard1_dir);
        pool.set_worker_addr(1, &new_addr).unwrap();
        *guard = fresh;
    };
    let killer = KillOnce {
        inner: &pool,
        kill: Box::new(kill),
        fired: AtomicBool::new(false),
        trigger_depth: 2,
    };
    let recovering = RecoveringPool::new(killer);
    let subtrees_before =
        series_value(&drf::telemetry::render(), "drf_subtrees_total").unwrap_or(0);
    let builder = TreeBuilderCore::new(&recovering, &topo, &cfg.forest, ds.num_features())
        .with_depth_next(cfg.depth_next_rows);
    let trees: Vec<_> = (0..cfg.forest.num_trees as u32)
        .map(|t| builder.build_tree(t).unwrap().0)
        .collect();

    assert!(
        recovering.inner().fired.load(Ordering::SeqCst),
        "the kill must actually have fired (tree never reached depth 2?)"
    );
    assert!(
        recovering.recoveries() >= 1,
        "the restarted worker must have been rebuilt by replay"
    );
    let subtrees_after =
        series_value(&drf::telemetry::render(), "drf_subtrees_total").unwrap_or(0);
    assert!(
        subtrees_after > subtrees_before,
        "no subtree ever detached — the drill did not exercise depth-next"
    );
    assert_eq!(
        direct.trees, trees,
        "a worker kill + restart must not change the depth-next forest"
    );
}

/// Plain HTTP/1.0 GET against a metrics/healthz port; returns the
/// whole response (status line + headers + body).
fn http_get(addr: &str, path: &str) -> String {
    use std::io::{Read as _, Write as _};
    let mut s = std::net::TcpStream::connect(addr).expect("connecting for GET");
    s.set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    write!(s, "GET {path} HTTP/1.0\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).expect("reading GET response");
    body
}

#[test]
fn worker_healthz_survives_leader_disconnect_and_rehandshake() {
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 2);
    let ds = dataset();
    let cfg = forest_cfg(2);

    let (_g0, addr0, maddr0) = spawn_worker_with_metrics(&tmp.path().join("shard_0"));
    let (_g1, addr1, _maddr1) = spawn_worker_with_metrics(&tmp.path().join("shard_1"));

    let manifest = drf::cluster::ClusterManifest::load(&tmp.path().join("cluster.json")).unwrap();
    let topo = manifest.topology().unwrap();
    let hello = drf::cluster::hello_template(&cfg, &manifest);
    let addrs = vec![addr0, addr1];
    let pool = ClusterPool::connect(
        &addrs,
        &topo,
        hello.clone(),
        ROWS as u64,
        ds.num_classes(),
        ClusterOptions::default(),
    )
    .unwrap();
    // The first leader goes away without ceremony — its connections
    // just close under the workers.
    drop(pool);

    // The worker must keep serving its liveness endpoint...
    let health = http_get(&maddr0, "/healthz");
    assert!(
        health.starts_with("HTTP/1.0 200"),
        "healthz not 200 after leader drop: {health:?}"
    );
    assert!(
        health.contains("\"ok\":true"),
        "healthz body not ok after leader drop: {health:?}"
    );

    // ...and accept a brand-new leader's re-handshake (same topology
    // version; the full Hello inventory validation runs in connect).
    let pool = ClusterPool::connect(
        &addrs,
        &topo,
        hello,
        ROWS as u64,
        ds.num_classes(),
        ClusterOptions::default(),
    )
    .unwrap();
    drop(pool);
}

/// Send one line to the supervisor's control channel and return its
/// `ok ...` / `err ...` reply.
fn control(addr: &str, cmd: &str) -> String {
    use std::io::Write as _;
    let mut s = std::net::TcpStream::connect(addr).expect("connecting to control channel");
    s.set_read_timeout(Some(std::time::Duration::from_secs(30)))
        .unwrap();
    writeln!(s, "{cmd}").expect("sending control command");
    let mut reply = String::new();
    std::io::BufReader::new(s)
        .read_line(&mut reply)
        .expect("reading control reply");
    reply.trim().to_string()
}

/// Tears the whole supervised fleet down on drop: a graceful `quit`
/// (the supervisor kills its children on the way out), falling back to
/// SIGKILL of the supervisor if the control round-trip fails.
struct SuperviseGuard {
    child: Child,
    control_addr: String,
}

impl Drop for SuperviseGuard {
    fn drop(&mut self) {
        if let Ok(mut s) = std::net::TcpStream::connect(&self.control_addr) {
            use std::io::Write as _;
            let _ = s.set_read_timeout(Some(std::time::Duration::from_secs(10)));
            let _ = writeln!(s, "quit");
            let mut reply = String::new();
            let _ = std::io::BufReader::new(s).read_line(&mut reply);
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while std::time::Instant::now() < deadline {
            match self.child.try_wait() {
                Ok(Some(_)) => return,
                Ok(None) => std::thread::sleep(std::time::Duration::from_millis(50)),
                Err(_) => break,
            }
        }
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn `drf supervise` over a shard tree and parse the control (and,
/// when `--metrics-addr` is among `extra`, metrics) addresses from its
/// ready lines.
fn spawn_supervise(dir: &Path, extra: &[&str]) -> (SuperviseGuard, String, Option<String>) {
    let mut child = Command::new(DRF_BIN)
        .args([
            "supervise",
            "--dir",
            dir.to_str().unwrap(),
            "--control-addr",
            "127.0.0.1:0",
        ])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawning drf supervise");
    let stdout = child.stdout.take().expect("supervise stdout piped");
    let mut reader = std::io::BufReader::new(stdout);
    let mut metrics = None;
    loop {
        let mut line = String::new();
        let n = reader
            .read_line(&mut line)
            .expect("reading supervise ready line");
        assert!(n > 0, "supervise exited before printing its control address");
        let addr = line.trim().rsplit(' ').next().unwrap().to_string();
        if line.contains("metrics on") {
            metrics = Some(addr);
        } else if line.contains("control on") {
            let guard = SuperviseGuard {
                child,
                control_addr: addr.clone(),
            };
            return (guard, addr, metrics);
        }
    }
}

/// Delegating pool that fires a scheduled chaos event — a supervisor
/// control command — the first time a supersplit query for that
/// (tree, depth) comes through, then blocks until the supervisor has
/// committed the resulting manifest rewrite (so the leader's address
/// refresh finds the respawn within its reconnect budget).
struct ChaosAt<'a> {
    inner: &'a ClusterPool,
    control_addr: String,
    manifest_path: std::path::PathBuf,
    events: Mutex<Vec<(u32, u32, &'static str)>>,
}

impl ChaosAt<'_> {
    fn fire(&self, cmd: &str) {
        let before = drf::cluster::ClusterManifest::load(&self.manifest_path)
            .expect("reading manifest before chaos")
            .version;
        let reply = control(&self.control_addr, cmd);
        assert!(reply.starts_with("ok"), "control {cmd:?} failed: {reply}");
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        loop {
            // Reads race the supervisor's atomic rename; a transient
            // failure is just "not committed yet".
            let v = drf::cluster::ClusterManifest::load(&self.manifest_path)
                .map(|m| m.version)
                .unwrap_or(before);
            if v > before {
                return;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "supervisor never committed a respawn after {cmd:?}"
            );
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }
}

impl SplitterPool for ChaosAt<'_> {
    fn num_splitters(&self) -> usize {
        self.inner.num_splitters()
    }

    fn columns_of(&self, splitter: usize) -> Vec<usize> {
        self.inner.columns_of(splitter)
    }

    fn start_tree(&self, tree: u32) -> anyhow::Result<()> {
        self.inner.start_tree(tree)
    }

    fn root_stats(&self, splitter: usize, tree: u32) -> anyhow::Result<Vec<u64>> {
        self.inner.root_stats(splitter, tree)
    }

    fn find_splits(
        &self,
        splitter: usize,
        q: &SupersplitQuery,
    ) -> anyhow::Result<PartialSupersplit> {
        let cmd = {
            let mut events = self.events.lock().unwrap();
            events
                .iter()
                .position(|&(t, d, _)| t == q.tree && d == q.depth)
                .map(|i| events.remove(i).2)
        };
        if let Some(cmd) = cmd {
            self.fire(cmd);
        }
        self.inner.find_splits(splitter, q)
    }

    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> anyhow::Result<EvalResult> {
        self.inner.eval_conditions(splitter, q)
    }

    fn broadcast_level_update(&self, u: &LevelUpdate) -> anyhow::Result<()> {
        self.inner.broadcast_level_update(u)
    }

    fn materialize(
        &self,
        splitter: usize,
        q: &MaterializeQuery,
    ) -> anyhow::Result<MaterializedLeaves> {
        self.inner.materialize(splitter, q)
    }

    fn broadcast_subtree_done(&self, d: &SubtreeDone) -> anyhow::Result<()> {
        self.inner.broadcast_subtree_done(d)
    }

    fn broadcast_subtree_done_on(&self, splitter: usize, d: &SubtreeDone) -> anyhow::Result<()> {
        self.inner.broadcast_subtree_done_on(splitter, d)
    }

    fn finish_tree(&self, tree: u32) -> anyhow::Result<()> {
        self.inner.finish_tree(tree)
    }

    fn net_stats(&self) -> IoStats {
        self.inner.net_stats()
    }

    fn start_tree_on(&self, splitter: usize, tree: u32) -> anyhow::Result<()> {
        self.inner.start_tree_on(splitter, tree)
    }

    fn apply_level_update_on(&self, splitter: usize, u: &LevelUpdate) -> anyhow::Result<()> {
        self.inner.apply_level_update_on(splitter, u)
    }

    fn finish_tree_on(&self, splitter: usize, tree: u32) -> anyhow::Result<()> {
        self.inner.finish_tree_on(splitter, tree)
    }
}

#[test]
fn supervised_fleet_survives_chaos_kills_bit_identically() {
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 3);
    let ds = dataset();
    let mut cfg = forest_cfg(3);
    cfg.forest.num_trees = 3;

    // Reference forest from the in-process engine.
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();

    // The supervisor boots the whole fleet: two objstore replicas
    // serving the shard tree plus one remote-streaming worker per pack,
    // publishing every address in cluster.json. Aggressive probing so
    // kills are detected within a couple hundred milliseconds.
    let log_path = tmp.path().join("actions.jsonl");
    let (_guard, control_addr, maddr) = spawn_supervise(
        tmp.path(),
        &[
            "--objstore-replicas",
            "2",
            "--interval-ms",
            "100",
            "--fail-threshold",
            "1",
            "--log",
            log_path.to_str().unwrap(),
            "--metrics-addr",
            "127.0.0.1:0",
        ],
    );

    let mpath = tmp.path().join("cluster.json");
    let manifest = drf::cluster::ClusterManifest::load(&mpath).unwrap();
    assert_eq!(manifest.workers.len(), 3, "supervisor did not publish worker addresses");
    assert_eq!(manifest.objstores.len(), 2, "supervisor did not publish objstore replicas");

    // This test process is the leader, wired exactly like the manager:
    // manifest addresses, manifest watching, replay recovery.
    let topo = manifest.topology().unwrap();
    let pool = ClusterPool::connect(
        &manifest.workers,
        &topo,
        drf::cluster::hello_template(&cfg, &manifest),
        ROWS as u64,
        ds.num_classes(),
        ClusterOptions::default(),
    )
    .unwrap();
    pool.watch_manifest(mpath.clone());

    // Two workers and one objstore replica die at scattered points
    // mid-training; every event must fire (asserted below).
    let chaos = ChaosAt {
        inner: &pool,
        control_addr: control_addr.clone(),
        manifest_path: mpath.clone(),
        events: Mutex::new(vec![
            (0, 2, "kill 0"),
            (0, 3, "kill objstore 0"),
            (1, 2, "kill 1"),
        ]),
    };
    let recovering = RecoveringPool::new(chaos);
    let mut trees = Vec::new();
    for t in 0..cfg.forest.num_trees as u32 {
        recovering.inner().inner.poll_topology().unwrap();
        let topo = recovering.inner().inner.topology();
        let builder = TreeBuilderCore::new(&recovering, &topo, &cfg.forest, ds.num_features());
        trees.push(builder.build_tree(t).unwrap().0);
    }

    let leftover = recovering.inner().events.lock().unwrap().clone();
    assert!(
        leftover.is_empty(),
        "some chaos events never fired (trees too shallow?): {leftover:?}"
    );
    assert!(
        recovering.recoveries() >= 2,
        "both killed workers must have been rebuilt by replay"
    );
    assert_eq!(
        direct.trees, trees,
        "chaos kills under the supervisor must not change the forest"
    );

    // The action log holds the whole story: spawns, kills, restarts.
    let log = std::fs::read_to_string(&log_path).unwrap();
    assert!(!log.trim().is_empty(), "supervisor action log is empty");
    let actions: Vec<String> = log
        .lines()
        .map(|l| {
            let j = drf::util::Json::parse(l).expect("action log line parses as JSON");
            j.get("action").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    assert!(actions.iter().any(|a| a == "kill"), "no kill logged: {actions:?}");
    assert!(actions.iter().any(|a| a == "restart"), "no restart logged: {actions:?}");

    // `drf metrics` scrapes the supervisor's own registry (`--watch`
    // is the same scrape in a loop).
    let out = Command::new(DRF_BIN)
        .args(["metrics", &maddr.expect("supervisor metrics address")])
        .output()
        .expect("running drf metrics against the supervisor");
    assert!(out.status.success(), "drf metrics failed: {out:?}");
    let scraped = String::from_utf8(out.stdout).unwrap();
    let restarts = series_value(&scraped, "drf_supervisor_restarts_total").unwrap_or(0);
    assert!(
        restarts >= 2,
        "supervisor registry missing restarts:\n{scraped}"
    );
}

#[test]
fn supervised_drain_reshards_mid_run_bit_identically() {
    let tmp = drf::util::tempdir().unwrap();
    shard_via_cli(tmp.path(), 3);
    let ds = dataset();
    let mut cfg = forest_cfg(3);
    cfg.forest.num_trees = 3;

    // Reference forest from the in-process engine.
    let (direct, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();

    // Local-pack fleet under the supervisor (the drain rewrites packs
    // on disk; workers reload them at the next handshake).
    let (_guard, control_addr, _maddr) =
        spawn_supervise(tmp.path(), &["--interval-ms", "100"]);

    let mpath = tmp.path().join("cluster.json");
    let manifest = drf::cluster::ClusterManifest::load(&mpath).unwrap();
    let topo = manifest.topology().unwrap();
    let pool = ClusterPool::connect(
        &manifest.workers,
        &topo,
        drf::cluster::hello_template(&cfg, &manifest),
        ROWS as u64,
        ds.num_classes(),
        ClusterOptions::default(),
    )
    .unwrap();
    pool.watch_manifest(mpath.clone());
    let recovering = RecoveringPool::new(pool);
    let v0 = recovering.inner().topology_version();

    let mut trees = Vec::new();
    for t in 0..cfg.forest.num_trees as u32 {
        if t == 1 {
            // Between trees: re-shard worker 2's columns onto the rest
            // of the fleet. The leader adopts the new ownership map at
            // its next between-trees poll, right below.
            let reply = control(&control_addr, "drain 2");
            assert!(
                reply.starts_with("ok drained worker 2"),
                "drain failed: {reply}"
            );
        }
        recovering.inner().poll_topology().unwrap();
        let topo = recovering.inner().topology();
        let builder = TreeBuilderCore::new(&recovering, &topo, &cfg.forest, ds.num_features());
        trees.push(builder.build_tree(t).unwrap().0);
    }

    assert!(
        recovering.inner().topology_version() > v0,
        "the drain was never adopted by the leader"
    );
    assert_eq!(
        recovering.inner().active_count(),
        2,
        "drained worker still active in the leader"
    );
    assert_eq!(
        direct.trees, trees,
        "a mid-run drain + re-shard must not change the forest"
    );
}
