//! Training throughput across the ColumnStore data plane:
//! rows/s per storage backend × scan_threads × prefetch depth on the
//! synthetic families.
//!
//! This is the perf trajectory's first *training* datapoint (the serve
//! bench covers inference). The interesting comparisons:
//!
//! * Memory vs Disk (v1) vs DiskV2 vs Mmap vs Remote — the cost of
//!   streaming every pass through read(2) + bounded buffers, what the
//!   zero-copy mapping buys back once the page cache is warm (the
//!   repeated-training loop below is exactly the warm-cache regime;
//!   the acceptance bar is mmap rows/s >= DiskStore rows/s), and what
//!   fetching every chunk over a real TCP objstore costs — the
//!   network column of the paper's complexity table as an empirical
//!   row (per-config `net_bytes` lands in the JSON);
//! * `prefetch_chunks` 0 vs 2 on the streaming backends (disk reads
//!   and remote range reads) — the double-buffered reader pipeline;
//! * `scan_threads` 1 vs N — the intra-splitter scan pool. The
//!   topology deliberately uses **few splitters for many columns** so
//!   each splitter owns several columns and the pool has real work
//!   (with one splitter per column there is nothing to parallelize).
//!
//! Exactness first: before timing, every configuration's forest is
//! checked bit-identical to the reference. Results go to
//! `BENCH_train.json` in the working directory; `DRF_BENCH_SMOKE=1`
//! shrinks the inputs for CI. Each configuration also reports where
//! the forest time went — per-phase scan/eval/update seconds read
//! from the telemetry span histograms (`drf_phase_us`), so a
//! regression in one phase is visible without re-profiling.

use drf::config::{ForestParams, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::data::Dataset;
use drf::forest::RandomForest;
use drf::rng::BaggingMode;
use drf::util::bench::{bench, fmt_count, sized, smoke_mode, write_bench_json, Table};
use drf::util::Json;

const FEATURES: usize = 12;
const TREES: usize = 2;
const SPLITTERS: usize = 2; // 6 columns per splitter -> the pool matters
const THREAD_SETTINGS: [usize; 2] = [1, 4];

fn backend_name(mode: StorageMode) -> &'static str {
    match mode {
        StorageMode::Memory => "memory",
        StorageMode::Disk => "disk",
        StorageMode::DiskV2 => "disk_v2",
        StorageMode::Mmap => "mmap",
        // Loopback objstore self-hosted by the manager: real TCP range
        // reads with zero external setup.
        StorageMode::Remote => "remote",
    }
}

/// Prefetch depths worth timing per backend (prefetching only exists
/// on the streaming scans — disk reads and remote range reads).
fn prefetch_depths(mode: StorageMode) -> &'static [usize] {
    match mode {
        StorageMode::Disk | StorageMode::DiskV2 | StorageMode::Remote => &[0, 2],
        StorageMode::Memory | StorageMode::Mmap => &[0],
    }
}

fn config(storage: StorageMode, scan_threads: usize, prefetch: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.forest = ForestParams {
        num_trees: TREES,
        max_depth: 8,
        bagging: BaggingMode::Poisson,
        seed: 17,
        ..Default::default()
    };
    cfg.topology.num_splitters = Some(SPLITTERS);
    cfg.storage = storage;
    cfg.scan_threads = scan_threads;
    cfg.prefetch_chunks = prefetch;
    // The backend matrix measures the distributed per-level scan plane;
    // keep the hybrid schedule out of it (the depth-next comparison has
    // its own section below).
    cfg.depth_next_rows = 0;
    cfg
}

/// Deep-tree section: the same forest grown pure breadth-first vs with
/// the hybrid depth-next schedule — the rows/s delta is what the
/// resident subtree growth buys on per-level pass costs.
fn depth_next_section(rows: usize) -> Json {
    let ds =
        SyntheticSpec::new(Family::LinearCont { informative: 5 }, rows, FEATURES, 3).generate();
    let mut deep = config(StorageMode::Memory, 1, 0);
    deep.forest.max_depth = 14;
    deep.forest.min_records = 2;
    let mut dn = deep.clone();
    dn.depth_next_rows = TrainConfig::default().depth_next_rows;
    let bf_forest = RandomForest::train_with_config(&ds, &deep).unwrap().0;
    let dn_forest = RandomForest::train_with_config(&ds, &dn).unwrap().0;
    assert_eq!(
        bf_forest.trees, dn_forest.trees,
        "depth-next: exactness before speed"
    );
    let bf = bench(3, 15.0, || {
        std::hint::black_box(RandomForest::train_with_config(&ds, &deep).unwrap());
    });
    let dnt = bench(3, 15.0, || {
        std::hint::black_box(RandomForest::train_with_config(&ds, &dn).unwrap());
    });
    let bf_rps = (rows * TREES) as f64 / bf.mean_s;
    let dn_rps = (rows * TREES) as f64 / dnt.mean_s;
    println!(
        "\ndeep trees (depth 14): breadth-first {} rows/s, depth-next {} rows/s ({:.2}x)",
        fmt_count(bf_rps),
        fmt_count(dn_rps),
        dn_rps / bf_rps
    );
    if dn_rps < bf_rps {
        println!("WARNING: depth-next slower than breadth-first on deep trees");
    }
    let mut o = Json::object();
    o.set("max_depth", Json::from_u64(14))
        .set("bf_rows_per_s", Json::Num(bf_rps))
        .set("depth_next_rows_per_s", Json::Num(dn_rps))
        .set("speedup", Json::Num(dn_rps / bf_rps));
    o
}

/// Tracing must be observation-only in cost as well as output: the
/// same in-memory training loop with the JSONL span sink off vs on.
/// Spans are per-phase (tens of events per tree), not per-row, so the
/// sink should be noise; the smoke run enforces a 5% overhead budget.
fn tracing_overhead_section(rows: usize) -> Json {
    let ds =
        SyntheticSpec::new(Family::Majority { informative: 5 }, rows, FEATURES, 4).generate();
    let cfg = config(StorageMode::Memory, 1, 0);
    let off = bench(3, 12.0, || {
        std::hint::black_box(RandomForest::train_with_config(&ds, &cfg).unwrap());
    });
    let dir = drf::util::tempdir().unwrap();
    let sink = dir.path().join("bench_trace.jsonl");
    drf::telemetry::set_trace_out(&sink).unwrap();
    let on = bench(3, 12.0, || {
        std::hint::black_box(RandomForest::train_with_config(&ds, &cfg).unwrap());
    });
    drf::telemetry::clear_trace_out();
    let off_rps = (rows * TREES) as f64 / off.mean_s;
    let on_rps = (rows * TREES) as f64 / on.mean_s;
    // Positive = tracing cost; small negative values are timing noise.
    let overhead = (off_rps - on_rps) / off_rps;
    println!(
        "\ntracing: off {} rows/s, on {} rows/s (overhead {:+.1}%)",
        fmt_count(off_rps),
        fmt_count(on_rps),
        overhead * 100.0
    );
    if smoke_mode() && overhead > 0.05 {
        panic!(
            "tracing overhead {:.1}% exceeds the 5% budget \
             (off {off_rps:.0} rows/s, on {on_rps:.0} rows/s)",
            overhead * 100.0
        );
    }
    let mut o = Json::object();
    o.set("off_rows_per_s", Json::Num(off_rps))
        .set("on_rows_per_s", Json::Num(on_rps))
        .set("overhead_frac", Json::Num(overhead));
    o
}

fn main() {
    let rows = sized(30_000, 3_000);
    let families: Vec<(&str, Dataset)> = vec![
        (
            "majority",
            SyntheticSpec::new(Family::Majority { informative: 5 }, rows, FEATURES, 1).generate(),
        ),
        (
            "linear",
            SyntheticSpec::new(Family::LinearCont { informative: 5 }, rows, FEATURES, 2).generate(),
        ),
    ];
    let backends = [
        StorageMode::Memory,
        StorageMode::Disk,
        StorageMode::DiskV2,
        StorageMode::Mmap,
        StorageMode::Remote,
    ];

    let mut table = Table::new(&[
        "family",
        "backend",
        "scan_threads",
        "prefetch",
        "time / forest",
        "scan/eval/update",
        "rows/s",
        "speedup",
        "net bytes",
    ]);
    let mut fam_jsons: Vec<Json> = Vec::new();
    let mut any_parallel_win = false;
    let mut mmap_vs_disk: Vec<(f64, f64)> = Vec::new();

    for (name, ds) in &families {
        // Exactness before speed: all configurations must produce the
        // reference forest bit for bit.
        let reference = RandomForest::train_with_config(ds, &config(StorageMode::Memory, 1, 0))
            .unwrap()
            .0;
        let mut results: Vec<Json> = Vec::new();
        let mut baseline_rps: f64 = 0.0;
        let (mut disk_best_rps, mut mmap_rps, mut remote_rps) = (0.0f64, 0.0f64, 0.0f64);
        for &storage in &backends {
            let mut serial_mean = 0.0f64;
            for &threads in &THREAD_SETTINGS {
                for &prefetch in prefetch_depths(storage) {
                    let cfg = config(storage, threads, prefetch);
                    let (forest, check_report) =
                        RandomForest::train_with_config(ds, &cfg).unwrap();
                    assert_eq!(
                        reference.trees, forest.trees,
                        "{name}/{storage:?}/t{threads}/p{prefetch}: exactness before speed"
                    );
                    // Storage-plane network traffic of one training run
                    // (the objstore range reads; zero for local
                    // backends) — the paper's network-cost column,
                    // measured rather than modeled.
                    let storage_net: u64 = check_report
                        .splitter_io
                        .iter()
                        .map(|s| s.net_bytes)
                        .sum();
                    // Per-phase wall time from the telemetry spans: the
                    // phase histograms are process-cumulative, so the
                    // delta across the bench loop divided by the number
                    // of trainings (1 warmup + iters measured) is the
                    // per-forest cost of each level phase.
                    let phases = ["level_scan", "level_eval", "level_update"];
                    let before: Vec<f64> = phases
                        .iter()
                        .map(|p| drf::telemetry::phase_seconds(p))
                        .collect();
                    let t = bench(3, 12.0, || {
                        std::hint::black_box(RandomForest::train_with_config(ds, &cfg).unwrap());
                    });
                    let runs = (t.iters + 1) as f64;
                    let per_forest: Vec<f64> = phases
                        .iter()
                        .zip(&before)
                        .map(|(p, b)| (drf::telemetry::phase_seconds(p) - b) / runs)
                        .collect();
                    let (scan_s, eval_s, update_s) =
                        (per_forest[0], per_forest[1], per_forest[2]);
                    // Throughput: training rows processed per wall
                    // second (rows × trees / forest time).
                    let rps = (rows * TREES) as f64 / t.mean_s;
                    if storage == StorageMode::Memory && threads == 1 {
                        baseline_rps = rps;
                    }
                    if storage == StorageMode::Disk {
                        disk_best_rps = disk_best_rps.max(rps);
                    }
                    if storage == StorageMode::Mmap {
                        mmap_rps = mmap_rps.max(rps);
                    }
                    if storage == StorageMode::Remote {
                        remote_rps = remote_rps.max(rps);
                    }
                    let speedup = if threads == 1 && prefetch == 0 {
                        serial_mean = t.mean_s;
                        1.0
                    } else {
                        serial_mean / t.mean_s
                    };
                    if threads > 1 && speedup > 1.0 {
                        any_parallel_win = true;
                    }
                    table.row(&[
                        name.to_string(),
                        backend_name(storage).into(),
                        format!("{threads}"),
                        format!("{prefetch}"),
                        t.per_iter_label(),
                        format!("{:.0}/{:.0}/{:.0}ms", scan_s * 1e3, eval_s * 1e3, update_s * 1e3),
                        fmt_count(rps),
                        format!("{speedup:.2}x"),
                        fmt_count(storage_net as f64),
                    ]);
                    let mut r = Json::object();
                    r.set("backend", Json::Str(backend_name(storage).into()))
                        .set("scan_threads", Json::from_usize(threads))
                        .set("prefetch_chunks", Json::from_usize(prefetch))
                        .set("seconds_per_forest", Json::Num(t.mean_s))
                        .set("rows_per_s", Json::Num(rps))
                        .set("speedup_vs_serial", Json::Num(speedup))
                        .set("scan_s_per_forest", Json::Num(scan_s))
                        .set("eval_s_per_forest", Json::Num(eval_s))
                        .set("update_s_per_forest", Json::Num(update_s))
                        .set("net_bytes", Json::from_u64(storage_net));
                    results.push(r);
                }
            }
        }
        mmap_vs_disk.push((mmap_rps, disk_best_rps));
        let mut fj = Json::object();
        fj.set("family", Json::Str((*name).into()))
            .set("baseline_memory_rows_per_s", Json::Num(baseline_rps))
            .set("mmap_rows_per_s", Json::Num(mmap_rps))
            .set("disk_rows_per_s", Json::Num(disk_best_rps))
            .set("remote_rows_per_s", Json::Num(remote_rps))
            .set("results", Json::Arr(results));
        fam_jsons.push(fj);
    }

    table.print();

    let depth_next = depth_next_section(rows);
    let tracing = tracing_overhead_section(rows);

    let mut o = table.to_json();
    o.set("rows", Json::from_usize(rows))
        .set("features", Json::from_usize(FEATURES))
        .set("trees", Json::from_usize(TREES))
        .set("splitters", Json::from_usize(SPLITTERS))
        .set("families", Json::Arr(fam_jsons))
        .set("depth_next", depth_next)
        .set("tracing", tracing);
    write_bench_json("train", o);
    if !any_parallel_win {
        println!(
            "WARNING: scan_threads={} never beat scan_threads=1 — \
             check the scan pool",
            THREAD_SETTINGS[1]
        );
    }
    for ((name, _), (mmap, disk)) in families.iter().zip(&mmap_vs_disk) {
        if mmap < disk {
            println!(
                "WARNING: {name}: mmap ({}) slower than disk ({}) on the \
                 warm-cache loop — zero-copy regressed",
                fmt_count(*mmap),
                fmt_count(*disk)
            );
        }
    }
}
