//! Training throughput across the ColumnStore data plane:
//! rows/s per storage backend × scan_threads on the synthetic families.
//!
//! This is the perf trajectory's first *training* datapoint (the serve
//! bench covers inference). The interesting comparisons:
//!
//! * Memory vs Disk (v1) vs DiskV2 — the cost of streaming every pass
//!   from disk, and whether the chunk-tabled v2 layout keeps up with
//!   the monolithic v1 files;
//! * `scan_threads` 1 vs N — the intra-splitter scan pool. The
//!   topology deliberately uses **few splitters for many columns** so
//!   each splitter owns several columns and the pool has real work
//!   (with one splitter per column there is nothing to parallelize).
//!
//! Exactness first: before timing, every configuration's forest is
//! checked bit-identical to the reference. Results go to
//! `BENCH_train.json` in the working directory.

use drf::config::{ForestParams, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::data::Dataset;
use drf::forest::RandomForest;
use drf::rng::BaggingMode;
use drf::util::bench::{bench, fmt_count, Table};
use drf::util::Json;

const ROWS: usize = 30_000;
const FEATURES: usize = 12;
const TREES: usize = 2;
const SPLITTERS: usize = 2; // 6 columns per splitter -> the pool matters
const THREAD_SETTINGS: [usize; 2] = [1, 4];

fn backend_name(mode: StorageMode) -> &'static str {
    match mode {
        StorageMode::Memory => "memory",
        StorageMode::Disk => "disk",
        StorageMode::DiskV2 => "disk_v2",
    }
}

fn config(storage: StorageMode, scan_threads: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.forest = ForestParams {
        num_trees: TREES,
        max_depth: 8,
        bagging: BaggingMode::Poisson,
        seed: 17,
        ..Default::default()
    };
    cfg.topology.num_splitters = Some(SPLITTERS);
    cfg.storage = storage;
    cfg.scan_threads = scan_threads;
    cfg
}

fn main() {
    let families: Vec<(&str, Dataset)> = vec![
        (
            "majority",
            SyntheticSpec::new(Family::Majority { informative: 5 }, ROWS, FEATURES, 1).generate(),
        ),
        (
            "linear",
            SyntheticSpec::new(Family::LinearCont { informative: 5 }, ROWS, FEATURES, 2).generate(),
        ),
    ];
    let backends = [StorageMode::Memory, StorageMode::Disk, StorageMode::DiskV2];

    let mut table = Table::new(&["family", "backend", "scan_threads", "time / forest", "rows/s", "speedup"]);
    let mut fam_jsons: Vec<Json> = Vec::new();
    let mut any_parallel_win = false;

    for (name, ds) in &families {
        // Exactness before speed: all configurations must produce the
        // reference forest bit for bit.
        let reference = RandomForest::train_with_config(ds, &config(StorageMode::Memory, 1))
            .unwrap()
            .0;
        let mut results: Vec<Json> = Vec::new();
        let mut baseline_rps: f64 = 0.0;
        for &storage in &backends {
            let mut serial_mean = 0.0f64;
            for &threads in &THREAD_SETTINGS {
                let cfg = config(storage, threads);
                let forest = RandomForest::train_with_config(ds, &cfg).unwrap().0;
                assert_eq!(
                    reference.trees, forest.trees,
                    "{name}/{storage:?}/t{threads}: exactness before speed"
                );
                let t = bench(3, 12.0, || {
                    std::hint::black_box(RandomForest::train_with_config(ds, &cfg).unwrap());
                });
                // Throughput: training rows processed per wall second
                // (rows × trees / forest time).
                let rps = (ROWS * TREES) as f64 / t.mean_s;
                if storage == StorageMode::Memory && threads == 1 {
                    baseline_rps = rps;
                }
                let speedup = if threads == 1 {
                    serial_mean = t.mean_s;
                    1.0
                } else {
                    serial_mean / t.mean_s
                };
                if threads > 1 && speedup > 1.0 {
                    any_parallel_win = true;
                }
                table.row(&[
                    name.to_string(),
                    backend_name(storage).into(),
                    format!("{threads}"),
                    t.per_iter_label(),
                    fmt_count(rps),
                    format!("{speedup:.2}x"),
                ]);
                let mut r = Json::object();
                r.set("backend", Json::Str(backend_name(storage).into()))
                    .set("scan_threads", Json::from_usize(threads))
                    .set("seconds_per_forest", Json::Num(t.mean_s))
                    .set("rows_per_s", Json::Num(rps))
                    .set("speedup_vs_serial", Json::Num(speedup));
                results.push(r);
            }
        }
        let mut fj = Json::object();
        fj.set("family", Json::Str((*name).into()))
            .set("baseline_memory_rows_per_s", Json::Num(baseline_rps))
            .set("results", Json::Arr(results));
        fam_jsons.push(fj);
    }

    table.print();

    let mut o = Json::object();
    o.set("bench", Json::Str("train_throughput".into()))
        .set("rows", Json::from_usize(ROWS))
        .set("features", Json::from_usize(FEATURES))
        .set("trees", Json::from_usize(TREES))
        .set("splitters", Json::from_usize(SPLITTERS))
        .set("families", Json::Arr(fam_jsons));
    let path = "BENCH_train.json";
    std::fs::write(path, o.to_string()).unwrap();
    println!("\nsummary written to {path}");
    if !any_parallel_win {
        println!(
            "WARNING: scan_threads={} never beat scan_threads=1 — \
             check the scan pool",
            THREAD_SETTINGS[1]
        );
    }
}
