//! Figure 1 — AUC as a function of training-set size and number of
//! trees, across synthetic families with and without useless variables
//! (UV), with the rote-learning baseline.
//!
//! Paper shape: AUC rises with n and with trees; curves with many UV
//! need far more data; rote learning collapses to 0.5 with UV; the
//! needle family is noisy (one run per point).

use drf::baselines::rote::RoteLearner;
use drf::config::ForestParams;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::metrics::auc;
use drf::util::bench::Table;

// Results go to BENCH_fig1_auc.json (perf/quality trajectory).

fn main() {
    let sizes = [1_000usize, 10_000, 100_000];
    let tree_counts = [1usize, 3, 10];
    let configs = [
        ("xor", Family::Xor { informative: 3 }, 3usize),
        ("xor+9UV", Family::Xor { informative: 3 }, 12),
        ("majority", Family::Majority { informative: 5 }, 5),
        ("majority+9UV", Family::Majority { informative: 5 }, 14),
        ("needle", Family::Needle { informative: 4 }, 4),
        ("needle+9UV", Family::Needle { informative: 4 }, 13),
    ];
    let mut t = Table::new(&["family", "n", "trees", "AUC", "-log(1-AUC)", "rote"]);
    for (name, family, features) in configs {
        for n in sizes {
            let train = SyntheticSpec::new(family, n, features, 1).generate();
            let test = SyntheticSpec::new(family, 20_000, features, 2).generate();
            let rote_auc = auc(
                &RoteLearner::fit(&train).predict_scores(&test),
                test.labels(),
            );
            for trees in tree_counts {
                let params = ForestParams {
                    num_trees: trees,
                    max_depth: 64,
                    min_records: 1,
                    seed: 7,
                    ..Default::default()
                };
                let forest = RandomForest::train(&train, &params).unwrap();
                let a = auc(&forest.predict_scores(&test), test.labels());
                t.row(&[
                    name.into(),
                    n.to_string(),
                    trees.to_string(),
                    format!("{a:.4}"),
                    format!("{:.2}", -(1.0 - a).max(1e-6).ln()),
                    format!("{rote_auc:.3}"),
                ]);
            }
        }
    }
    t.print();
    t.write_json("fig1_auc");
    println!("\nShape check: AUC(n) non-decreasing per family; rote ~0.5 with UV.");
}
