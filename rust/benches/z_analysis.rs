//! §3.2 — the Z analysis: per-worker feature load under feature
//! sampling, worker counts, USB, and redundant storage.
//!
//! Three views, cross-validated:
//!  1. Monte-Carlo simulation (complexity::zmodel);
//!  2. the closed-form regimes of Table 1 (complexity::table1);
//!  3. Z actually *measured* by the tree builder's per-level stats on a
//!     real training run.

use drf::complexity::table1::Workload;
use drf::complexity::zmodel::{simulate, ZConfig};
use drf::config::{ForestParams, TopologyParams, TrainConfig};
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::rng::FeatureSampling;
use drf::util::bench::{write_bench_json, Table};
use drf::util::Json;

fn monte_carlo() -> Json {
    println!("=== E[Z]: Monte-Carlo vs closed-form regimes ===");
    let mut t = Table::new(&["m", "m'", "z", "w", "d", "E[m'']", "E[Z] (MC)", "Z (model)"]);
    let cases = [
        // (m, m', z, w, d)
        (1024usize, 32usize, 1usize, 32usize, 1usize), // balance point, no redundancy
        (1024, 32, 1, 32, 3),                          // + redundancy
        (1024, 32, 1, 32, 5),                          // + more redundancy (USB win)
        (1024, 32, 64, 32, 1),                         // many nodes: m'' >> w
        (1024, 32, 64, 128, 1),                        // more workers
        (72, 9, 400, 72, 1),                           // Leo-like: w = m
    ];
    for (m, m_prime, z, w, d) in cases {
        let est = simulate(
            &ZConfig {
                m,
                m_prime,
                z,
                w,
                d,
            },
            300,
            7,
        );
        let mut wl = Workload::with_defaults(1_000_000, m as u64, w as u64, 10);
        wl.m_prime = m_prime as u64;
        wl.z = z as u64;
        wl.d = d as u64;
        t.row(&[
            m.to_string(),
            m_prime.to_string(),
            z.to_string(),
            w.to_string(),
            d.to_string(),
            format!("{:.1}", est.mean_m_double_prime),
            format!("{:.2}", est.mean_z),
            format!("{:.2}", wl.z_load()),
        ]);
    }
    t.print();
    t.to_json()
}

fn measured() -> Json {
    println!("\n=== Z measured during real training (per-level max load) ===");
    let ds = SyntheticSpec::new(Family::Majority { informative: 4 }, 20_000, 64, 3).generate();
    let mut t = Table::new(&["sampling", "w", "d", "mean Z", "max Z", "mean m''"]);
    for (sampling, w, d) in [
        (FeatureSampling::PerNode, 8usize, 1usize),
        (FeatureSampling::PerNode, 8, 2),
        (FeatureSampling::PerNode, 64, 1),
        (FeatureSampling::PerDepth, 8, 1),
        (FeatureSampling::PerDepth, 8, 2),
        (FeatureSampling::PerDepth, 64, 1),
    ] {
        let cfg = TrainConfig {
            forest: ForestParams {
                num_trees: 2,
                max_depth: 10,
                min_records: 20,
                feature_sampling: sampling,
                seed: 11,
                ..Default::default()
            },
            topology: TopologyParams {
                num_splitters: Some(w),
                redundancy: d,
                ..Default::default()
            },
            ..Default::default()
        };
        let (_, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let zs: Vec<usize> = report
            .per_tree
            .iter()
            .flat_map(|t| t.levels.iter().map(|l| l.z_max_load))
            .collect();
        let ms: Vec<usize> = report
            .per_tree
            .iter()
            .flat_map(|t| t.levels.iter().map(|l| l.m_double_prime))
            .collect();
        let mean = |v: &[usize]| v.iter().sum::<usize>() as f64 / v.len().max(1) as f64;
        t.row(&[
            format!("{sampling:?}"),
            w.to_string(),
            d.to_string(),
            format!("{:.2}", mean(&zs)),
            zs.iter().max().copied().unwrap_or(0).to_string(),
            format!("{:.1}", mean(&ms)),
        ]);
    }
    t.print();
    println!(
        "\nShape check (paper §3.2): USB (PerDepth) slashes m'' and Z;\n\
         redundancy d>1 cuts Z again at the w≈m'' balance point."
    );
    t.to_json()
}

fn main() {
    let mc = monte_carlo();
    let meas = measured();
    let mut o = Json::object();
    o.set("monte_carlo", mc).set("measured", meas);
    write_bench_json("z_analysis", o);
}
