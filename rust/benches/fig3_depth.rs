//! Figure 3 — per-depth metrics while training depth-by-depth on the
//! Leo-like dataset: level time, open leaves, node/sample density,
//! individual-tree AUC and forest AUC for depth 0..max.
//!
//! Paper shape: leaves grow ~exponentially but level time stays nearly
//! flat (scan-dominated); tree AUC saturates (then overfits on small
//! subsets) while RF AUC keeps climbing; deeper is better with more
//! data.

use drf::config::{ForestParams, TrainConfig};
use drf::data::synthetic::LeoLikeSpec;
use drf::forest::RandomForest;
use drf::metrics::auc;
use drf::util::bench::{write_bench_json, Table};
use drf::util::Json;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(80_000);
    let spec = LeoLikeSpec::new(n, 20_626);
    let full = spec.generate();
    let test = spec.generate_rows(n, (n / 4).max(5_000));

    let mut sections = Json::object();
    for (label, frac, min_records) in [("10%", 0.1f64, 13u64), ("100%", 1.0, 133)] {
        let sub_n = (n as f64 * frac) as usize;
        let ds = full.head(sub_n);
        let params = ForestParams {
            num_trees: 5,
            max_depth: 14,
            min_records,
            seed: 9,
            ..Default::default()
        };
        let cfg = TrainConfig {
            forest: params,
            ..Default::default()
        };
        let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let max_d = forest.trees.iter().map(|t| t.depth()).max().unwrap_or(0);
        let mut level_secs = vec![0.0f64; max_d as usize + 1];
        let mut level_leaves = vec![0u64; max_d as usize + 1];
        for tr in &report.per_tree {
            for l in &tr.levels {
                if (l.depth as usize) < level_secs.len() {
                    level_secs[l.depth as usize] += l.seconds / report.per_tree.len() as f64;
                    level_leaves[l.depth as usize] += l.open_before as u64;
                }
            }
        }
        println!("\n=== Figure 3 ({label} subset: n={sub_n}) ===");
        let mut t = Table::new(&[
            "depth",
            "level s (mean)",
            "open leaves (mean)",
            "tree0 AUC",
            "RF AUC",
        ]);
        for d in 0..=max_d {
            let rf_auc = auc(&forest.predict_scores_at_depth(&test, d), test.labels());
            let tree0 = &forest.trees[0];
            let t_scores: Vec<f64> = (0..test.num_rows())
                .map(|i| tree0.score_at_depth(&test.row(i), d))
                .collect();
            let t_auc = auc(&t_scores, test.labels());
            t.row(&[
                d.to_string(),
                format!("{:.3}", level_secs.get(d as usize).copied().unwrap_or(0.0)),
                format!(
                    "{:.1}",
                    level_leaves.get(d as usize).copied().unwrap_or(0) as f64
                        / report.per_tree.len() as f64
                ),
                format!("{t_auc:.4}"),
                format!("{rf_auc:.4}"),
            ]);
        }
        t.print();
        sections.set(label, t.to_json());
    }
    write_bench_json("fig3_depth", sections);
}
