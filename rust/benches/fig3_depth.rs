//! Figure 3 — per-depth metrics while training depth-by-depth on the
//! Leo-like dataset: level time, open leaves, node/sample density,
//! individual-tree AUC and forest AUC for depth 0..max.
//!
//! Paper shape: leaves grow ~exponentially but level time stays nearly
//! flat (scan-dominated); tree AUC saturates (then overfits on small
//! subsets) while RF AUC keeps climbing; deeper is better with more
//! data.
//!
//! Each subset is trained twice — pure breadth-first
//! (`depth_next_rows = 0`) and the default hybrid schedule — and the
//! per-depth level seconds of both land as typed rows in
//! `BENCH_fig3_depth.json`: the depth-next win is exactly the deep
//! tail of the breadth-first curve collapsing once the frontier goes
//! resident, visible per level rather than only in the total.

use drf::config::{ForestParams, TrainConfig};
use drf::data::synthetic::LeoLikeSpec;
use drf::forest::RandomForest;
use drf::metrics::auc;
use drf::util::bench::{sized, write_bench_json, Table};
use drf::util::Json;

/// Mean per-depth level seconds and open-leaf counts over the trees of
/// one training report.
fn level_profile(report: &drf::coordinator::TrainReport, max_d: u32) -> (Vec<f64>, Vec<f64>) {
    let mut secs = vec![0.0f64; max_d as usize + 1];
    let mut leaves = vec![0.0f64; max_d as usize + 1];
    let trees = report.per_tree.len() as f64;
    for tr in &report.per_tree {
        for l in &tr.levels {
            if (l.depth as usize) < secs.len() {
                secs[l.depth as usize] += l.seconds / trees;
                leaves[l.depth as usize] += l.open_before as f64 / trees;
            }
        }
    }
    (secs, leaves)
}

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| sized(80_000, 6_000));
    let spec = LeoLikeSpec::new(n, 20_626);
    let full = spec.generate();
    let test = spec.generate_rows(n, (n / 4).max(5_000));

    let mut sections = Json::object();
    for (label, frac, min_records) in [("10%", 0.1f64, 13u64), ("100%", 1.0, 133)] {
        let sub_n = (n as f64 * frac) as usize;
        let ds = full.head(sub_n);
        let params = ForestParams {
            num_trees: 5,
            max_depth: 14,
            min_records,
            seed: 9,
            ..Default::default()
        };
        // Breadth-first reference: every level pays a full pass.
        let bf_cfg = TrainConfig {
            forest: params,
            depth_next_rows: 0,
            ..Default::default()
        };
        let (forest, report) = RandomForest::train_with_config(&ds, &bf_cfg).unwrap();
        // Hybrid schedule (default budget): bit-identical forest, the
        // deep levels grow cache-resident.
        let dn_cfg = TrainConfig {
            forest: params,
            ..Default::default()
        };
        let (dn_forest, dn_report) = RandomForest::train_with_config(&ds, &dn_cfg).unwrap();
        assert_eq!(
            forest.trees, dn_forest.trees,
            "{label}: depth-next must match breadth-first bit for bit"
        );
        let max_d = forest.trees.iter().map(|t| t.depth()).max().unwrap_or(0);
        let (bf_secs, level_leaves) = level_profile(&report, max_d);
        let (dn_secs, _) = level_profile(&dn_report, max_d);
        println!("\n=== Figure 3 ({label} subset: n={sub_n}) ===");
        let mut t = Table::new(&[
            "depth",
            "level s (bf)",
            "level s (depth-next)",
            "open leaves (mean)",
            "tree0 AUC",
            "RF AUC",
        ]);
        let mut levels_json: Vec<Json> = Vec::new();
        for d in 0..=max_d {
            let rf_auc = auc(&forest.predict_scores_at_depth(&test, d), test.labels());
            let tree0 = &forest.trees[0];
            let t_scores: Vec<f64> = (0..test.num_rows())
                .map(|i| tree0.score_at_depth(&test.row(i), d))
                .collect();
            let t_auc = auc(&t_scores, test.labels());
            let bf_s = bf_secs.get(d as usize).copied().unwrap_or(0.0);
            let dn_s = dn_secs.get(d as usize).copied().unwrap_or(0.0);
            let open = level_leaves.get(d as usize).copied().unwrap_or(0.0);
            t.row(&[
                d.to_string(),
                format!("{bf_s:.3}"),
                format!("{dn_s:.3}"),
                format!("{open:.1}"),
                format!("{t_auc:.4}"),
                format!("{rf_auc:.4}"),
            ]);
            let mut lj = Json::object();
            lj.set("depth", Json::from_u64(d as u64))
                .set("bf_level_seconds", Json::Num(bf_s))
                .set("depth_next_level_seconds", Json::Num(dn_s))
                .set("open_leaves", Json::Num(open))
                .set("tree0_auc", Json::Num(t_auc))
                .set("rf_auc", Json::Num(rf_auc));
            levels_json.push(lj);
        }
        t.print();
        let mut section = t.to_json();
        section.set("levels", Json::Arr(levels_json));
        sections.set(label, section);
    }
    write_bench_json("fig3_depth", sections);
}
