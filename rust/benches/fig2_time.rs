//! Figure 2 — training time as a function of training-set size
//! (exact RF, m' = ⌈√m⌉, unbounded depth, min 1 record/leaf; workers =
//! dimension; trees trained sequentially, presorting amortized).
//!
//! Paper anchor: 1900-3000 s per tree at n = 3e8, m = 18 on their
//! cluster. We check the *scaling shape*: close-to-linear growth in n
//! (the level scans dominate), superlinear only through extra depth.

use drf::config::{ForestParams, TrainConfig};
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::metrics::Stopwatch;
use drf::util::bench::Table;

fn main() {
    let mut t = Table::new(&["family", "m", "n", "s/tree", "s/tree/1e5 rows", "depth"]);
    for (name, family, features) in [
        ("xor+UV (m=18)", Family::Xor { informative: 3 }, 18usize),
        ("linear (m=18)", Family::LinearCont { informative: 4 }, 18),
    ] {
        for n in [10_000usize, 30_000, 100_000, 300_000] {
            let train = SyntheticSpec::new(family, n, features, 1).generate();
            let params = ForestParams {
                num_trees: 1,
                max_depth: 64,
                min_records: 1,
                seed: 7,
                ..Default::default()
            };
            let cfg = TrainConfig {
                forest: params,
                ..Default::default()
            };
            let sw = Stopwatch::start();
            let (forest, _) = RandomForest::train_with_config(&train, &cfg).unwrap();
            let secs = sw.seconds();
            t.row(&[
                name.into(),
                features.to_string(),
                n.to_string(),
                format!("{secs:.3}"),
                format!("{:.3}", secs * 1e5 / n as f64),
                forest.trees[0].depth().to_string(),
            ]);
        }
    }
    t.print();
    t.write_json("fig2_time");
    println!("\nShape check: s/tree/1e5-rows roughly flat (linear scaling modulo depth growth).");
}
