//! Ablations of DRF's design choices (DESIGN.md §5):
//!   1. bit-packed class list vs plain u32 (memory + speed);
//!   2. SPRINT-style adaptive pruning on a fast-closing workload;
//!   3. network-latency insensitivity (paper §2);
//!   4. GBT vs RF on the same substrate (network + quality).

use drf::classlist::ClassList;
use drf::config::{ForestParams, PruneMode, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::forest::gbt::{GbtParams, GbtTrainer};
use drf::forest::RandomForest;
use drf::metrics::{auc, Stopwatch};
use drf::util::bench::{bench, fmt_bytes, write_bench_json, Table};
use drf::util::Json;

fn classlist_ablation() -> Json {
    println!("=== Ablation 1: bit-packed class list vs u32 ===");
    let n = 1_000_000usize;
    let mut t = Table::new(&["layout", "ℓ=63 memory", "get x n", "note"]);
    let mut packed = ClassList::with_open(n, 63);
    for i in 0..n {
        packed.set(i, (i % 64) as u32);
    }
    let timing = bench(10, 5.0, || {
        let mut acc = 0u64;
        for i in 0..n {
            acc += packed.get(i) as u64;
        }
        std::hint::black_box(acc);
    });
    t.row(&[
        "bit-packed (paper §2.3)".into(),
        fmt_bytes(packed.memory_bits() / 8),
        timing.per_iter_label(),
        format!("{} bits/sample", packed.width()),
    ]);
    let plain: Vec<u32> = (0..n).map(|i| (i % 64) as u32).collect();
    let timing = bench(10, 5.0, || {
        let mut acc = 0u64;
        for &v in &plain {
            acc += v as u64;
        }
        std::hint::black_box(acc);
    });
    t.row(&[
        "plain u32".into(),
        fmt_bytes(n as u64 * 4),
        timing.per_iter_label(),
        "32 bits/sample (5.3x memory)".into(),
    ]);
    t.print();
    t.to_json()
}

fn pruning_ablation() -> Json {
    println!("\n=== Ablation 2: SPRINT-style adaptive pruning (disk mode) ===");
    // min_records high -> most records land in closed leaves early,
    // the regime where the paper says pruning *would* help Sprint.
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 100_000, 8, 3).generate();
    let mut t = Table::new(&["prune", "wall s", "disk read", "identical tree"]);
    let mut reference = None;
    for (label, prune) in [
        ("never (paper's Leo runs)", PruneMode::Never),
        ("adaptive @ 30% closed", PruneMode::Adaptive { threshold: 0.3 }),
    ] {
        let cfg = TrainConfig {
            forest: ForestParams {
                num_trees: 1,
                max_depth: 12,
                min_records: 2_000,
                seed: 5,
                ..Default::default()
            },
            prune,
            storage: StorageMode::Disk,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
        let identical = match &reference {
            None => {
                reference = Some(forest.trees[0].clone());
                "reference".to_string()
            }
            Some(r) => (r == &forest.trees[0]).to_string(),
        };
        t.row(&[
            label.into(),
            format!("{:.3}", sw.seconds()),
            fmt_bytes(read),
            identical,
        ]);
    }
    t.print();
    t.to_json()
}

fn latency_ablation() -> Json {
    println!("\n=== Ablation 3: injected network latency (paper §2: DRF is latency-insensitive) ===");
    let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 30_000, 6, 3).generate();
    let mut t = Table::new(&["latency/msg", "wall s", "messages", "latency share"]);
    for latency_us in [0u64, 200, 1000] {
        let mut cfg = TrainConfig::default();
        cfg.forest = ForestParams {
            num_trees: 1,
            max_depth: 8,
            seed: 5,
            ..Default::default()
        };
        cfg.topology.latency_us = latency_us;
        let sw = Stopwatch::start();
        let (_, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let wall = sw.seconds();
        // Latency is paid once per RPC round, not per byte: the share
        // stays modest because message count is O(w x depth).
        let injected = report.net.net_messages as f64 * latency_us as f64 * 1e-6;
        t.row(&[
            format!("{latency_us} µs"),
            format!("{wall:.3}"),
            report.net.net_messages.to_string(),
            format!("{:.0}%", 100.0 * (injected.min(wall)) / wall),
        ]);
    }
    t.print();
    t.to_json()
}

fn gbt_vs_rf() -> Json {
    println!("\n=== Ablation 4: GBT vs RF on the Leo-like dataset ===");
    let spec = LeoLikeSpec::new(40_000, 20_626);
    let train = spec.generate();
    let test = spec.generate_rows(40_000, 10_000);
    let mut t = Table::new(&["model", "train s", "test AUC", "network model"]);

    let sw = Stopwatch::start();
    let params = ForestParams {
        num_trees: 30,
        max_depth: 8,
        min_records: 50,
        seed: 9,
        ..Default::default()
    };
    let (rf, report) = RandomForest::train_with_config(&train, &TrainConfig {
        forest: params,
        ..Default::default()
    })
    .unwrap();
    t.row(&[
        "RF (30 trees)".into(),
        format!("{:.2}", sw.seconds()),
        format!("{:.4}", auc(&rf.predict_scores(&test), test.labels())),
        format!("{} measured", fmt_bytes(report.net.net_bytes)),
    ]);

    let sw = Stopwatch::start();
    let trainer = GbtTrainer::new(
        &train,
        GbtParams {
            num_rounds: 60,
            max_depth: 4,
            learning_rate: 0.3,
            ..Default::default()
        },
    )
    .unwrap();
    let model = trainer.train().unwrap();
    t.row(&[
        "GBT (60 rounds)".into(),
        format!("{:.2}", sw.seconds()),
        format!("{:.4}", auc(&model.predict_scores(&test), test.labels())),
        format!(
            "{} gradient broadcasts",
            fmt_bytes(trainer.stats().net_bytes())
        ),
    ]);
    t.print();
    println!("\n(RF ships ~1 bit/sample/level; GBT adds 8 B/sample/round of gradients.)");
    t.to_json()
}

fn main() {
    let classlist = classlist_ablation();
    let pruning = pruning_ablation();
    let latency = latency_ablation();
    let gbt = gbt_vs_rf();
    let mut o = Json::object();
    o.set("classlist", classlist)
        .set("pruning", pruning)
        .set("latency", latency)
        .set("gbt_vs_rf", gbt);
    write_bench_json("ablations", o);
}
