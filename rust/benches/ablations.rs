//! Ablations of DRF's design choices (DESIGN.md §5):
//!   1. bit-packed class list vs plain u32 (memory + speed);
//!   2. SPRINT-style adaptive pruning on a fast-closing workload;
//!   3. network-latency insensitivity (paper §2);
//!   4. GBT vs RF on the same substrate (network + quality);
//!   5. exact supersplit scan vs `--split-search mab` (MABSplit-style
//!      successive elimination) — AUC and train seconds;
//!   6. breadth-first vs depth-next growth (rows/s on deep trees).

use drf::classlist::ClassList;
use drf::config::{ForestParams, PruneMode, SplitSearch, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::forest::gbt::{GbtParams, GbtTrainer};
use drf::forest::RandomForest;
use drf::metrics::{auc, Stopwatch};
use drf::util::bench::{bench, fmt_bytes, fmt_count, sized, write_bench_json, Table};
use drf::util::Json;

fn classlist_ablation() -> Json {
    println!("=== Ablation 1: bit-packed class list vs u32 ===");
    let n = sized(1_000_000, 100_000);
    let mut t = Table::new(&["layout", "ℓ=63 memory", "get x n", "note"]);
    let mut packed = ClassList::with_open(n, 63);
    for i in 0..n {
        packed.set(i, (i % 64) as u32);
    }
    let timing = bench(10, 5.0, || {
        let mut acc = 0u64;
        for i in 0..n {
            acc += packed.get(i) as u64;
        }
        std::hint::black_box(acc);
    });
    t.row(&[
        "bit-packed (paper §2.3)".into(),
        fmt_bytes(packed.memory_bits() / 8),
        timing.per_iter_label(),
        format!("{} bits/sample", packed.width()),
    ]);
    let plain: Vec<u32> = (0..n).map(|i| (i % 64) as u32).collect();
    let timing = bench(10, 5.0, || {
        let mut acc = 0u64;
        for &v in &plain {
            acc += v as u64;
        }
        std::hint::black_box(acc);
    });
    t.row(&[
        "plain u32".into(),
        fmt_bytes(n as u64 * 4),
        timing.per_iter_label(),
        "32 bits/sample (5.3x memory)".into(),
    ]);
    t.print();
    t.to_json()
}

fn pruning_ablation() -> Json {
    println!("\n=== Ablation 2: SPRINT-style adaptive pruning (disk mode) ===");
    // min_records high -> most records land in closed leaves early,
    // the regime where the paper says pruning *would* help Sprint.
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 3 }, sized(100_000, 8_000), 8, 3)
        .generate();
    let mut t = Table::new(&["prune", "wall s", "disk read", "identical tree"]);
    let mut reference = None;
    for (label, prune) in [
        ("never (paper's Leo runs)", PruneMode::Never),
        ("adaptive @ 30% closed", PruneMode::Adaptive { threshold: 0.3 }),
    ] {
        let cfg = TrainConfig {
            forest: ForestParams {
                num_trees: 1,
                max_depth: 12,
                min_records: 2_000,
                seed: 5,
                ..Default::default()
            },
            prune,
            storage: StorageMode::Disk,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
        let identical = match &reference {
            None => {
                reference = Some(forest.trees[0].clone());
                "reference".to_string()
            }
            Some(r) => (r == &forest.trees[0]).to_string(),
        };
        t.row(&[
            label.into(),
            format!("{:.3}", sw.seconds()),
            fmt_bytes(read),
            identical,
        ]);
    }
    t.print();
    t.to_json()
}

fn latency_ablation() -> Json {
    println!("\n=== Ablation 3: injected network latency (paper §2: DRF is latency-insensitive) ===");
    let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, sized(30_000, 5_000), 6, 3)
        .generate();
    let mut t = Table::new(&["latency/msg", "wall s", "messages", "latency share"]);
    for latency_us in [0u64, 200, 1000] {
        let mut cfg = TrainConfig::default();
        cfg.forest = ForestParams {
            num_trees: 1,
            max_depth: 8,
            seed: 5,
            ..Default::default()
        };
        cfg.topology.latency_us = latency_us;
        let sw = Stopwatch::start();
        let (_, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let wall = sw.seconds();
        // Latency is paid once per RPC round, not per byte: the share
        // stays modest because message count is O(w x depth).
        let injected = report.net.net_messages as f64 * latency_us as f64 * 1e-6;
        t.row(&[
            format!("{latency_us} µs"),
            format!("{wall:.3}"),
            report.net.net_messages.to_string(),
            format!("{:.0}%", 100.0 * (injected.min(wall)) / wall),
        ]);
    }
    t.print();
    t.to_json()
}

fn gbt_vs_rf() -> Json {
    println!("\n=== Ablation 4: GBT vs RF on the Leo-like dataset ===");
    let n = sized(40_000, 4_000);
    let spec = LeoLikeSpec::new(n, 20_626);
    let train = spec.generate();
    let test = spec.generate_rows(n, n / 4);
    let mut t = Table::new(&["model", "train s", "test AUC", "network model"]);

    let sw = Stopwatch::start();
    let params = ForestParams {
        num_trees: 30,
        max_depth: 8,
        min_records: 50,
        seed: 9,
        ..Default::default()
    };
    let (rf, report) = RandomForest::train_with_config(&train, &TrainConfig {
        forest: params,
        ..Default::default()
    })
    .unwrap();
    t.row(&[
        "RF (30 trees)".into(),
        format!("{:.2}", sw.seconds()),
        format!("{:.4}", auc(&rf.predict_scores(&test), test.labels())),
        format!("{} measured", fmt_bytes(report.net.net_bytes)),
    ]);

    let sw = Stopwatch::start();
    let trainer = GbtTrainer::new(
        &train,
        GbtParams {
            num_rounds: 60,
            max_depth: 4,
            learning_rate: 0.3,
            ..Default::default()
        },
    )
    .unwrap();
    let model = trainer.train().unwrap();
    t.row(&[
        "GBT (60 rounds)".into(),
        format!("{:.2}", sw.seconds()),
        format!("{:.4}", auc(&model.predict_scores(&test), test.labels())),
        format!(
            "{} gradient broadcasts",
            fmt_bytes(trainer.stats().net_bytes())
        ),
    ]);
    t.print();
    println!("\n(RF ships ~1 bit/sample/level; GBT adds 8 B/sample/round of gradients.)");
    t.to_json()
}

fn split_search_ablation() -> Json {
    println!("\n=== Ablation 5: exact scan vs --split-search mab (MABSplit) ===");
    // The sampled elimination pass only engages on nodes with >= 8192
    // live rows, so the deep tail is exact either way — the comparison
    // is about the expensive shallow levels. In smoke mode the dataset
    // is below the sampling floor and mab degenerates to exact (the
    // rows still flow, the numbers are not representative).
    let rows = sized(60_000, 4_000);
    let spec = SyntheticSpec::new(Family::LinearCont { informative: 5 }, rows, 12, 21);
    let train = spec.generate();
    let test_spec = SyntheticSpec::new(Family::LinearCont { informative: 5 }, rows / 4, 12, 9921);
    let test = test_spec.generate();
    let mut t = Table::new(&["split search", "train s", "test AUC", "identical to exact"]);
    let mut rows_json: Vec<Json> = Vec::new();
    let mut reference: Option<Vec<drf::tree::Tree>> = None;
    for (label, search) in [("exact", SplitSearch::Exact), ("mab", SplitSearch::Mab)] {
        let mut cfg = TrainConfig::default();
        cfg.forest = ForestParams {
            num_trees: 5,
            max_depth: 10,
            min_records: 10,
            seed: 21,
            ..Default::default()
        };
        cfg.split_search = search;
        let sw = Stopwatch::start();
        let (forest, _) = RandomForest::train_with_config(&train, &cfg).unwrap();
        let secs = sw.seconds();
        let a = auc(&forest.predict_scores(&test), test.labels());
        let identical = match &reference {
            None => {
                reference = Some(forest.trees.clone());
                "reference".to_string()
            }
            Some(r) => (*r == forest.trees).to_string(),
        };
        t.row(&[
            label.into(),
            format!("{secs:.3}"),
            format!("{a:.4}"),
            identical,
        ]);
        let mut r = Json::object();
        r.set("split_search", Json::Str(label.into()))
            .set("train_seconds", Json::Num(secs))
            .set("test_auc", Json::Num(a));
        rows_json.push(r);
    }
    t.print();
    let mut o = t.to_json();
    o.set("results", Json::Arr(rows_json));
    o
}

fn depth_next_ablation() -> Json {
    println!("\n=== Ablation 6: breadth-first vs depth-next growth (deep trees) ===");
    // Deep trees are where the per-level full-dataset passes dominate:
    // once a node's rows fit the budget, the resident subtree grows
    // with zero further passes, so the deep tail is nearly free. Both
    // schedules must produce the identical forest.
    let rows = sized(60_000, 4_000);
    let trees = 2usize;
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 5 }, rows, 10, 7).generate();
    let mut t = Table::new(&["schedule", "time / forest", "rows/s", "identical"]);
    let mut rows_json: Vec<Json> = Vec::new();
    let mut reference: Option<Vec<drf::tree::Tree>> = None;
    for (label, budget) in [
        ("breadth-first (budget 0)", 0u64),
        ("depth-next @4096", 4_096),
        ("depth-next @65536", 65_536),
    ] {
        let mut cfg = TrainConfig::default();
        cfg.forest = ForestParams {
            num_trees: trees,
            max_depth: 14,
            min_records: 2,
            seed: 7,
            ..Default::default()
        };
        cfg.depth_next_rows = budget;
        let (forest, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let identical = match &reference {
            None => {
                reference = Some(forest.trees);
                "reference".to_string()
            }
            Some(r) => (*r == forest.trees).to_string(),
        };
        let timing = bench(3, 15.0, || {
            std::hint::black_box(RandomForest::train_with_config(&ds, &cfg).unwrap());
        });
        let rps = (rows * trees) as f64 / timing.mean_s;
        t.row(&[
            label.into(),
            timing.per_iter_label(),
            fmt_count(rps),
            identical,
        ]);
        let mut r = Json::object();
        r.set("schedule", Json::Str(label.into()))
            .set("depth_next_rows", Json::from_u64(budget))
            .set("seconds_per_forest", Json::Num(timing.mean_s))
            .set("rows_per_s", Json::Num(rps));
        rows_json.push(r);
    }
    t.print();
    let mut o = t.to_json();
    o.set("results", Json::Arr(rows_json));
    o
}

fn main() {
    let classlist = classlist_ablation();
    let pruning = pruning_ablation();
    let latency = latency_ablation();
    let gbt = gbt_vs_rf();
    let split_search = split_search_ablation();
    let depth_next = depth_next_ablation();
    let mut o = Json::object();
    o.set("classlist", classlist)
        .set("pruning", pruning)
        .set("latency", latency)
        .set("gbt_vs_rf", gbt)
        .set("split_search", split_search)
        .set("depth_next", depth_next);
    write_bench_json("ablations", o);
}
