//! Table 1 — complexity comparison of Generic / SLIQ / SPRINT / SLIQ-D
//! / SLIQ-R / DRF / DRF-USB.
//!
//! Two halves:
//!  1. the closed-form model (complexity::table1) evaluated at the
//!     paper's Leo scale (n = 17.3e9, m = 72, w = 82, D = 20);
//!  2. *measured* counters from the real implementations (classic,
//!     SLIQ, SPRINT, DRF, DRF-USB) on a shared synthetic workload —
//!     same trees, different data structures, so the cost differences
//!     are purely algorithmic.

use drf::baselines::sliq::SliqTrainer;
use drf::baselines::sprint::SprintTrainer;
use drf::complexity::table1::{all_rows, Workload};
use drf::config::{ForestParams, StorageMode, TrainConfig};
use drf::data::io_stats::IoStats;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::metrics::Stopwatch;
use drf::rng::{BaggingMode, FeatureSampling};
use drf::util::bench::{fmt_bytes, fmt_count, write_bench_json, Table};
use drf::util::Json;

fn analytic() -> Json {
    println!("=== Table 1 (analytic), paper scale: n=17.3e9, m=72, m'=9, w=82, D=20 ===");
    let mut wl = Workload::with_defaults(17_300_000_000, 72, 82, 20);
    wl.z = 400_000; // ~open leaves at depth 20 (Table 2)
    wl.depth_bar = 18.0;
    wl.c_nodes = 870_000;
    wl.m_nodes = 435_000;
    let mut t = Table::new(&[
        "algorithm",
        "mem/worker",
        "compute/worker",
        "disk write",
        "network",
        "read/worker",
        "read passes",
    ]);
    for row in all_rows(&wl) {
        t.row(&[
            row.algorithm.into(),
            fmt_bytes((row.memory_bits_per_worker / 8.0) as u64),
            fmt_count(row.compute_ops_per_worker),
            fmt_bytes((row.disk_write_bits / 8.0) as u64),
            fmt_bytes((row.network_bits / 8.0) as u64),
            fmt_bytes((row.read_bits_per_worker / 8.0) as u64),
            fmt_count(row.read_passes),
        ]);
    }
    t.print();
    t.to_json()
}

fn measured() -> Json {
    println!("\n=== Table 1 (measured) on a shared workload: n=20k, m=12, depth<=8 ===");
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 4 }, 20_000, 12, 5).generate();
    let params = ForestParams {
        num_trees: 1,
        max_depth: 8,
        min_records: 20,
        bagging: BaggingMode::Poisson,
        seed: 42,
        ..Default::default()
    };

    let mut t = Table::new(&[
        "algorithm",
        "time (s)",
        "disk read",
        "read passes",
        "disk write",
        "write passes",
        "network",
        "identical tree",
    ]);

    // Classic in-memory (reference tree).
    let sw = Stopwatch::start();
    let classic_tree = drf::baselines::classic::ClassicTrainer::new(&ds, &params).train_tree(0);
    let classic_secs = sw.seconds();
    t.row(&[
        "generic-in-memory".into(),
        format!("{classic_secs:.3}"),
        "0 B (in RAM)".into(),
        "0".into(),
        "0 B".into(),
        "0".into(),
        "0 B".into(),
        "reference".into(),
    ]);

    // SLIQ.
    let stats = IoStats::new();
    let sw = Stopwatch::start();
    let sliq_tree = SliqTrainer::new(&ds, &params, stats.clone()).train_tree(0);
    t.row(&[
        "sliq".into(),
        format!("{:.3}", sw.seconds()),
        fmt_bytes(stats.disk_read_bytes()),
        stats.disk_read_passes().to_string(),
        fmt_bytes(stats.disk_write_bytes()),
        stats.disk_write_passes().to_string(),
        fmt_bytes(stats.net_bytes()),
        (sliq_tree == classic_tree).to_string(),
    ]);

    // SPRINT.
    let stats = IoStats::new();
    let sw = Stopwatch::start();
    let sprint_tree = SprintTrainer::new(&ds, &params, stats.clone()).train_tree(0);
    t.row(&[
        "sprint".into(),
        format!("{:.3}", sw.seconds()),
        fmt_bytes(stats.disk_read_bytes()),
        stats.disk_read_passes().to_string(),
        fmt_bytes(stats.disk_write_bytes()),
        stats.disk_write_passes().to_string(),
        fmt_bytes(stats.net_bytes()),
        (sprint_tree == classic_tree).to_string(),
    ]);

    // DRF (disk mode so reads are real) and DRF-USB.
    for (label, sampling) in [
        ("drf", FeatureSampling::PerNode),
        ("drf-usb", FeatureSampling::PerDepth),
    ] {
        let cfg = TrainConfig {
            forest: ForestParams {
                feature_sampling: sampling,
                ..params
            },
            storage: StorageMode::Disk,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let secs = sw.seconds();
        let read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
        let read_passes: u64 = report.splitter_io.iter().map(|s| s.disk_read_passes).sum();
        let write: u64 = report.splitter_io.iter().map(|s| s.disk_write_bytes).sum();
        let write_passes: u64 = report.splitter_io.iter().map(|s| s.disk_write_passes).sum();
        // Dataset prep writes (shard spill) happen once; exclude nothing,
        // report as-is and annotate.
        let identical = if sampling == FeatureSampling::PerNode {
            (forest.trees[0] == classic_tree).to_string()
        } else {
            "different sampling".into()
        };
        t.row(&[
            label.into(),
            format!("{secs:.3}"),
            fmt_bytes(read),
            read_passes.to_string(),
            format!("{} (prep)", fmt_bytes(write)),
            write_passes.to_string(),
            fmt_bytes(report.net.net_bytes),
            identical,
        ]);
    }
    t.print();
    println!(
        "\nExpected shape: SLIQ reads every candidate column fully each level;\n\
         SPRINT pays the per-split rewrite (disk writes) but prunes closed\n\
         records; DRF never writes after prep and broadcasts ~1 bit/sample/level;\n\
         USB cuts DRF reads further (z=1)."
    );
    t.to_json()
}

fn main() {
    let a = analytic();
    let m = measured();
    let mut o = Json::object();
    o.set("analytic", a).set("measured", m);
    write_bench_json("table1_complexity", o);
}
