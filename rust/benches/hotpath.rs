//! Hot-path micro-benchmarks — the profiling substrate for the perf
//! pass (EXPERIMENTS.md §Perf). Measures the components that dominate
//! training time:
//!   * Alg. 1 numerical scan throughput (rows/s) at several leaf counts;
//!   * categorical count-table pass;
//!   * class-list get/set and level-update application;
//!   * condition-evaluation bitmap production;
//!   * XLA batched scorer vs native scalar scorer (when artifacts exist).

use drf::classlist::ClassList;
use drf::coordinator::messages::{Bitmap, LeafOutcome, LevelUpdate};
use drf::coordinator::splitter::apply_update_to_class_list;
use drf::data::column::Column;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::rng::{SplitMix64, Xoshiro256pp};
use drf::splits::histogram::Histogram;
use drf::splits::numerical::best_numerical_supersplit;
use drf::splits::scorer::ScoreKind;
use drf::util::bench::{bench, format_seconds, Table};

fn main() {
    let n = 1_000_000usize;
    let mut rng = Xoshiro256pp::new(1);
    let values: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let labels: Vec<u32> = (0..n).map(|_| (rng.next_f64() < 0.3) as u32).collect();
    let col = Column::Numerical(values);
    let sorted = col.presort();

    let mut t = Table::new(&["hot path", "input", "time", "throughput"]);

    // Alg. 1 scan at 1 and 64 open leaves.
    for leaves in [1u32, 64] {
        let mut totals = vec![Histogram::new(2); leaves as usize];
        for i in 0..n {
            totals[(i as u32 % leaves) as usize].add(labels[i], 1);
        }
        let timing = bench(5, 10.0, || {
            let r = best_numerical_supersplit(
                0,
                &sorted,
                &labels,
                2,
                &totals,
                ScoreKind::Gini,
                |i| (i % leaves) + 1,
                |_| true,
                |_| 1,
            );
            std::hint::black_box(&r);
        });
        t.row(&[
            format!("alg1 scan ({leaves} leaves)"),
            format!("{n} rows"),
            timing.per_iter_label(),
            format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
        ]);
    }

    // Alg. 1 with realistic bagging + candidate checks (closure cost).
    let bagger = drf::rng::Bagger::new(7, drf::rng::BaggingMode::Poisson);
    let totals = {
        let mut h = Histogram::new(2);
        for i in 0..n {
            let w = bagger.weight(0, i as u64);
            if w > 0 {
                h.add(labels[i], w);
            }
        }
        vec![h]
    };
    let timing = bench(5, 10.0, || {
        let r = best_numerical_supersplit(
            0,
            &sorted,
            &labels,
            2,
            &totals,
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |i| bagger.weight(0, i as u64),
        );
        std::hint::black_box(&r);
    });
    t.row(&[
        "alg1 scan + poisson bag".into(),
        format!("{n} rows"),
        timing.per_iter_label(),
        format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
    ]);

    // Categorical count-table pass.
    let arity = 1000u32;
    let cat_values: Vec<u32> = (0..n)
        .map(|i| (SplitMix64::hash_key(&[3, i as u64]) % arity as u64) as u32)
        .collect();
    let timing = bench(5, 10.0, || {
        let r = drf::splits::categorical::best_categorical_supersplit(
            0,
            &cat_values,
            arity,
            &labels,
            2,
            &totals,
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        std::hint::black_box(&r);
    });
    t.row(&[
        "categorical pass (arity 1000)".into(),
        format!("{n} rows"),
        timing.per_iter_label(),
        format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
    ]);

    // Class-list reads (the sample2node closure inside every scan).
    let mut cl = ClassList::with_open(n, 64);
    for i in 0..n {
        cl.set(i, (i % 65) as u32);
    }
    let timing = bench(10, 10.0, || {
        let mut acc = 0u64;
        for i in 0..n {
            acc += cl.get(i) as u64;
        }
        std::hint::black_box(acc);
    });
    t.row(&[
        "classlist get x n".into(),
        format!("{n} reads (width {})", cl.width()),
        timing.per_iter_label(),
        format!("{:.1} Mops/s", n as f64 / timing.mean_s / 1e6),
    ]);

    // Level-update application (rewrite + repack).
    let bitmap = {
        let count = cl.histogram()[1..].iter().sum::<u64>() as usize;
        let mut per_leaf: Vec<Bitmap> = (1..=64)
            .map(|r| Bitmap::with_len(cl.histogram()[r] as usize))
            .collect();
        let mut pos = vec![0usize; 64];
        for i in 0..n {
            let c = cl.get(i);
            if c > 0 {
                per_leaf[(c - 1) as usize].set(pos[(c - 1) as usize], i % 2 == 0);
                pos[(c - 1) as usize] += 1;
            }
        }
        std::hint::black_box(count);
        per_leaf
    };
    let update = LevelUpdate {
        tree: 0,
        depth: 6,
        outcomes: bitmap
            .into_iter()
            .map(|bm| LeafOutcome::Split {
                bitmap: bm,
                left_open: true,
                right_open: true,
            })
            .collect(),
    };
    let timing = bench(5, 10.0, || {
        let r = apply_update_to_class_list(&cl, &update).unwrap();
        std::hint::black_box(&r);
    });
    t.row(&[
        "level update (64->128 leaves)".into(),
        format!("{n} samples"),
        timing.per_iter_label(),
        format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
    ]);

    // End-to-end single tree on a mid-size dataset (the composite).
    let ds = SyntheticSpec::new(Family::LinearCont { informative: 4 }, 100_000, 12, 5).generate();
    let params = drf::config::ForestParams {
        num_trees: 1,
        max_depth: 12,
        min_records: 10,
        seed: 7,
        ..Default::default()
    };
    let cfg = drf::config::TrainConfig {
        forest: params,
        ..Default::default()
    };
    let timing = bench(3, 30.0, || {
        let r = drf::forest::RandomForest::train_with_config(&ds, &cfg).unwrap();
        std::hint::black_box(&r);
    });
    t.row(&[
        "end-to-end tree (n=100k, m=12)".into(),
        "1 tree".into(),
        timing.per_iter_label(),
        format!("{:.2} Mrows*levels/s", 100_000.0 * 12.0 / timing.mean_s / 1e6),
    ]);

    // XLA scorer vs native (artifact-dependent).
    let art = std::path::Path::new("artifacts");
    if art
        .join(drf::splits::xla_scorer::XlaScorer::artifact_name(16, 512))
        .exists()
    {
        use drf::splits::xla_scorer::{ScoreTask, ScoreTasks, XlaScorer};
        let rt = drf::runtime::XlaRuntime::cpu().unwrap();
        let scorer = XlaScorer::load(&rt, art, 16, 512).unwrap();
        let tasks: Vec<ScoreTask> = (0..64)
            .map(|k| {
                let len = 512usize;
                let mut pos = Vec::with_capacity(len);
                let mut tot = Vec::with_capacity(len);
                let (mut p, mut q) = (0f32, 0f32);
                for i in 0..len {
                    q += 1.0;
                    if (i + k) % 3 == 0 {
                        p += 1.0;
                    }
                    pos.push(p);
                    tot.push(q);
                }
                ScoreTask {
                    pos_prefix: pos,
                    tot_prefix: tot,
                    parent_pos: p + 1.0,
                    parent_tot: q + 2.0,
                }
            })
            .collect();
        let timing = bench(10, 10.0, || {
            let r = scorer.score_tasks(&tasks).unwrap();
            std::hint::black_box(&r);
        });
        let boundaries = 64.0 * 512.0;
        t.row(&[
            "xla scorer (64 tasks x 512)".into(),
            format!("{boundaries:.0} boundaries"),
            timing.per_iter_label(),
            format!("{:.2} Mboundaries/s", boundaries / timing.mean_s / 1e6),
        ]);
    } else {
        println!("(skipping XLA scorer bench: run `make artifacts`)");
    }

    t.print();
    println!("\n(hotpath timings feed EXPERIMENTS.md §Perf; times via {})", format_seconds(1.0));
}
