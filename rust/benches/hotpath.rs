//! Hot-path micro-benchmarks — the profiling substrate for the perf
//! pass (EXPERIMENTS.md §Perf). Measures the components that dominate
//! training time:
//!   * Alg. 1 numerical scan throughput (rows/s) at several leaf counts;
//!   * categorical count-table pass;
//!   * class-list get/set and level-update application;
//!   * condition-evaluation bitmap production;
//!   * XLA batched scorer vs native scalar scorer (when artifacts exist).
//!
//! The `before/after` section pins the branchless/word-level rewrites
//! of the two splitter hot loops against their scalar predecessors
//! (reimplemented here verbatim), so `BENCH_hotpath.json` records the
//! speedup of each rewrite on every run:
//!   * `eval bitmap fill` — per-row `ClassList::get` + branchy
//!     `Bitmap::set` vs word-level `decode_into` + trash-slot OR fill;
//!   * `supersplit gather` — the closed/non-candidate/out-of-bag
//!     branch ladder vs the fused table-driven gather;
//!   * `classlist decode` — per-row `get` vs `decode_into`.
//!
//! `DRF_BENCH_SMOKE=1` shrinks the inputs for CI.

use drf::classlist::ClassList;
use drf::coordinator::messages::{Bitmap, LeafOutcome, LevelUpdate};
use drf::coordinator::splitter::apply_update_to_class_list;
use drf::data::column::Column;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::rng::{SplitMix64, Xoshiro256pp};
use drf::splits::histogram::Histogram;
use drf::splits::numerical::{best_numerical_supersplit, NumericalSupersplitScan};
use drf::splits::scorer::ScoreKind;
use drf::util::bench::{bench, format_seconds, sized, write_bench_json, Table};
use drf::util::Json;

/// One before/after datapoint for BENCH_hotpath.json.
struct Rewrite {
    hot_loop: &'static str,
    unit: &'static str,
    before: f64,
    after: f64,
}

fn main() {
    let n = sized(1_000_000, 50_000);
    let mut rng = Xoshiro256pp::new(1);
    let values: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let labels: Vec<u32> = (0..n).map(|_| (rng.next_f64() < 0.3) as u32).collect();
    let col = Column::Numerical(values);
    let sorted = col.presort();

    let mut t = Table::new(&["hot path", "input", "time", "throughput"]);
    let mut rewrites: Vec<Rewrite> = Vec::new();

    // Alg. 1 scan at 1 and 64 open leaves.
    for leaves in [1u32, 64] {
        let mut totals = vec![Histogram::new(2); leaves as usize];
        for i in 0..n {
            totals[(i as u32 % leaves) as usize].add(labels[i], 1);
        }
        let timing = bench(5, 10.0, || {
            let r = best_numerical_supersplit(
                0,
                &sorted,
                &labels,
                2,
                &totals,
                ScoreKind::Gini,
                |i| (i % leaves) + 1,
                |_| true,
                |_| 1,
            );
            std::hint::black_box(&r);
        });
        t.row(&[
            format!("alg1 scan ({leaves} leaves)"),
            format!("{n} rows"),
            timing.per_iter_label(),
            format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
        ]);
    }

    // ------------------------------------------------------------------
    // Rewrite 1: the supersplit class-list + bag-weight gather.
    // Before: the historical three-branch ladder (closed leaf, feature
    // not drawn, out-of-bag) over closures into the bit-packed class
    // list and the bag-weight array. After: the splitter's fused
    // table-driven gather (one multiply folds all three skips).
    // Identical candidates either way — asserted before timing.
    // ------------------------------------------------------------------
    let leaves = 64u32;
    let mut cl = ClassList::with_open(n, leaves);
    let bagger = drf::rng::Bagger::new(7, drf::rng::BaggingMode::Poisson);
    let mut bag_weights = vec![0u8; n];
    let mut totals = vec![Histogram::new(2); leaves as usize];
    // Candidate mask: this feature drawn for half the leaves.
    let cand: Vec<bool> = (0..leaves).map(|h| h % 2 == 0).collect();
    for i in 0..n {
        let h = (i as u32 % leaves) + 1;
        let b = bagger.weight(0, i as u64).min(255) as u8;
        bag_weights[i] = b;
        if b > 0 {
            cl.set(i, h);
            totals[(h - 1) as usize].add(labels[i], b as u32);
        }
    }
    let before_scan = || {
        // The pre-rewrite shape: three separate predicates, branch per
        // predicate per row (via the compatibility adapter, which is
        // exactly the historical control flow).
        let r = best_numerical_supersplit(
            0,
            &sorted,
            &labels,
            2,
            &totals,
            ScoreKind::Gini,
            |i| cl.get(i as usize),
            |h| cand[(h - 1) as usize],
            |i| bag_weights[i as usize] as u32,
        );
        std::hint::black_box(&r);
        r
    };
    let fused_scan = || {
        // The splitter's table-driven gather (scan_column_supersplit).
        let mut cand_tbl = vec![0u8; leaves as usize + 1];
        for (r, &m) in cand.iter().enumerate() {
            cand_tbl[r + 1] = m as u8;
        }
        let mut scan = NumericalSupersplitScan::new(
            0,
            &labels,
            2,
            &totals,
            ScoreKind::Gini,
            |i: u32| {
                let h = cl.get(i as usize);
                let b = bag_weights[i as usize] as u32;
                let live = (cand_tbl[h as usize] as u32) & (b != 0) as u32;
                (h * live, b)
            },
        );
        scan.push(&sorted);
        let r = scan.finish();
        std::hint::black_box(&r);
        r
    };
    assert_eq!(before_scan(), fused_scan(), "gather rewrite must be exact");
    let before = bench(5, 8.0, || {
        before_scan();
    });
    let after = bench(5, 8.0, || {
        fused_scan();
    });
    for (name, timing) in [("3-branch gather", &before), ("fused table gather", &after)] {
        t.row(&[
            format!("supersplit gather: {name}"),
            format!("{n} rows, {leaves} leaves"),
            timing.per_iter_label(),
            format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
        ]);
    }
    rewrites.push(Rewrite {
        hot_loop: "supersplit gather",
        unit: "Mrows/s",
        before: n as f64 / before.mean_s / 1e6,
        after: n as f64 / after.mean_s / 1e6,
    });

    // ------------------------------------------------------------------
    // Rewrite 2: the condition-evaluation bitmap fill.
    // Before: per-row class-list get + rank check + branchy
    // Bitmap::set. After: word-level decode_into + rank→slot table
    // with a trash slot + OR-only writes (the eval_feature_pass inner
    // loop). Identical bitmaps either way — asserted before timing.
    // ------------------------------------------------------------------
    let raw = col.as_numerical();
    let threshold = 0.5f32;
    let counts = cl.histogram();
    // One condition per even rank (mirrors a realistic eval query).
    let want_rank: Vec<usize> = (1..=leaves as usize).filter(|r| r % 2 == 1).collect();
    let eval_before = || {
        let mut bitmaps: Vec<Bitmap> = want_rank
            .iter()
            .map(|&r| Bitmap::with_len(counts[r] as usize))
            .collect();
        let mut local_of_rank = vec![usize::MAX; leaves as usize + 1];
        let mut wanted = vec![false; leaves as usize + 1];
        for (li, &r) in want_rank.iter().enumerate() {
            local_of_rank[r] = li;
            wanted[r] = true;
        }
        let mut cursor = vec![0usize; want_rank.len()];
        for (i, &v) in raw.iter().enumerate() {
            let c = cl.get(i) as usize;
            if wanted[c] {
                let li = local_of_rank[c];
                let p = cursor[li];
                bitmaps[li].set(p, v <= threshold);
                cursor[li] = p + 1;
            }
        }
        std::hint::black_box(&bitmaps);
        bitmaps
    };
    let eval_after = || {
        // The branchless shape of eval_feature_pass.
        let trash = want_rank.len();
        let mut slot_of = vec![trash; leaves as usize + 1];
        let mut thresholds = vec![f32::NAN; trash + 1];
        let mut lens = Vec::with_capacity(trash);
        let mut offset = Vec::with_capacity(trash + 2);
        let mut nwords = 0usize;
        for (li, &r) in want_rank.iter().enumerate() {
            slot_of[r] = li;
            thresholds[li] = threshold;
            let len = counts[r] as usize;
            lens.push(len);
            offset.push(nwords);
            nwords += len.div_ceil(64);
        }
        offset.push(nwords);
        let mut words = vec![0u64; nwords + 1];
        let mut wmask = vec![usize::MAX; trash + 1];
        wmask[trash] = 0;
        let mut cursor = vec![0usize; trash + 1];
        let mut codes = vec![0u32; 64 * 1024];
        let mut base = 0usize;
        for chunk in raw.chunks(64 * 1024) {
            let codes = &mut codes[..chunk.len()];
            cl.decode_into(base, codes);
            for (k, &v) in chunk.iter().enumerate() {
                let li = slot_of[codes[k] as usize];
                let p = cursor[li];
                let bit = (v <= thresholds[li]) as u64;
                words[offset[li] + ((p >> 6) & wmask[li])] |= bit << (p & 63);
                cursor[li] = p + 1;
            }
            base += chunk.len();
        }
        let bitmaps: Vec<Bitmap> = want_rank
            .iter()
            .enumerate()
            .map(|(li, _)| Bitmap::from_words(lens[li], words[offset[li]..offset[li + 1]].to_vec()))
            .collect();
        std::hint::black_box(&bitmaps);
        bitmaps
    };
    assert_eq!(eval_before(), eval_after(), "eval rewrite must be exact");
    let before = bench(5, 8.0, || {
        eval_before();
    });
    let after = bench(5, 8.0, || {
        eval_after();
    });
    for (name, timing) in [("branchy set", &before), ("word-level fill", &after)] {
        t.row(&[
            format!("eval bitmap fill: {name}"),
            format!("{n} rows, {} conditions", want_rank.len()),
            timing.per_iter_label(),
            format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
        ]);
    }
    rewrites.push(Rewrite {
        hot_loop: "eval bitmap fill",
        unit: "Mrows/s",
        before: n as f64 / before.mean_s / 1e6,
        after: n as f64 / after.mean_s / 1e6,
    });

    // ------------------------------------------------------------------
    // Rewrite 3: sequential class-list decoding (the substrate of the
    // eval fill): per-row get vs word-level decode_into.
    // ------------------------------------------------------------------
    let decode_before = bench(10, 8.0, || {
        let mut acc = 0u64;
        for i in 0..n {
            acc += cl.get(i) as u64;
        }
        std::hint::black_box(acc);
    });
    let mut codes = vec![0u32; n];
    let decode_after = bench(10, 8.0, || {
        cl.decode_into(0, &mut codes);
        let acc: u64 = codes.iter().map(|&c| c as u64).sum();
        std::hint::black_box(acc);
    });
    {
        let mut check = vec![0u32; n];
        cl.decode_into(0, &mut check);
        for i in 0..n {
            assert_eq!(check[i], cl.get(i), "decode rewrite must be exact");
        }
    }
    for (name, timing) in [("get x n", &decode_before), ("decode_into", &decode_after)] {
        t.row(&[
            format!("classlist decode: {name}"),
            format!("{n} codes (width {})", cl.width()),
            timing.per_iter_label(),
            format!("{:.1} Mops/s", n as f64 / timing.mean_s / 1e6),
        ]);
    }
    rewrites.push(Rewrite {
        hot_loop: "classlist decode",
        unit: "Mops/s",
        before: n as f64 / decode_before.mean_s / 1e6,
        after: n as f64 / decode_after.mean_s / 1e6,
    });

    // Alg. 1 with realistic bagging + candidate checks (closure cost).
    let full_totals = {
        let mut h = Histogram::new(2);
        for i in 0..n {
            let w = bagger.weight(0, i as u64);
            if w > 0 {
                h.add(labels[i], w);
            }
        }
        vec![h]
    };
    let timing = bench(5, 10.0, || {
        let r = best_numerical_supersplit(
            0,
            &sorted,
            &labels,
            2,
            &full_totals,
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |i| bagger.weight(0, i as u64),
        );
        std::hint::black_box(&r);
    });
    t.row(&[
        "alg1 scan + poisson bag".into(),
        format!("{n} rows"),
        timing.per_iter_label(),
        format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
    ]);

    // Categorical count-table pass.
    let arity = 1000u32;
    let cat_values: Vec<u32> = (0..n)
        .map(|i| (SplitMix64::hash_key(&[3, i as u64]) % arity as u64) as u32)
        .collect();
    let timing = bench(5, 10.0, || {
        let r = drf::splits::categorical::best_categorical_supersplit(
            0,
            &cat_values,
            arity,
            &labels,
            2,
            &full_totals,
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        std::hint::black_box(&r);
    });
    t.row(&[
        "categorical pass (arity 1000)".into(),
        format!("{n} rows"),
        timing.per_iter_label(),
        format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
    ]);

    // Level-update application (rewrite + repack).
    let update = {
        let mut per_leaf: Vec<Bitmap> = (1..=leaves as usize)
            .map(|r| Bitmap::with_len(counts[r] as usize))
            .collect();
        let mut pos = vec![0usize; leaves as usize];
        for i in 0..n {
            let c = cl.get(i);
            if c > 0 {
                per_leaf[(c - 1) as usize].set(pos[(c - 1) as usize], i % 2 == 0);
                pos[(c - 1) as usize] += 1;
            }
        }
        LevelUpdate {
            tree: 0,
            depth: 6,
            outcomes: per_leaf
                .into_iter()
                .map(|bm| LeafOutcome::Split {
                    bitmap: bm,
                    left_open: true,
                    right_open: true,
                })
                .collect(),
        }
    };
    let timing = bench(5, 10.0, || {
        let r = apply_update_to_class_list(&cl, &update).unwrap();
        std::hint::black_box(&r);
    });
    t.row(&[
        format!("level update ({leaves}->{} leaves)", leaves * 2),
        format!("{n} samples"),
        timing.per_iter_label(),
        format!("{:.1} Mrows/s", n as f64 / timing.mean_s / 1e6),
    ]);

    // End-to-end single tree on a mid-size dataset (the composite).
    let e2e_rows = sized(100_000, 5_000);
    let ds =
        SyntheticSpec::new(Family::LinearCont { informative: 4 }, e2e_rows, 12, 5).generate();
    let params = drf::config::ForestParams {
        num_trees: 1,
        max_depth: 12,
        min_records: 10,
        seed: 7,
        ..Default::default()
    };
    let cfg = drf::config::TrainConfig {
        forest: params,
        ..Default::default()
    };
    let timing = bench(3, 30.0, || {
        let r = drf::forest::RandomForest::train_with_config(&ds, &cfg).unwrap();
        std::hint::black_box(&r);
    });
    t.row(&[
        format!("end-to-end tree (n={e2e_rows}, m=12)"),
        "1 tree".into(),
        timing.per_iter_label(),
        format!("{:.2} Mrows*levels/s", e2e_rows as f64 * 12.0 / timing.mean_s / 1e6),
    ]);

    // XLA scorer vs native (artifact-dependent).
    let art = std::path::Path::new("artifacts");
    if art
        .join(drf::splits::xla_scorer::XlaScorer::artifact_name(16, 512))
        .exists()
    {
        use drf::splits::xla_scorer::{ScoreTask, ScoreTasks, XlaScorer};
        let rt = drf::runtime::XlaRuntime::cpu().unwrap();
        let scorer = XlaScorer::load(&rt, art, 16, 512).unwrap();
        let tasks: Vec<ScoreTask> = (0..64)
            .map(|k| {
                let len = 512usize;
                let mut pos = Vec::with_capacity(len);
                let mut tot = Vec::with_capacity(len);
                let (mut p, mut q) = (0f32, 0f32);
                for i in 0..len {
                    q += 1.0;
                    if (i + k) % 3 == 0 {
                        p += 1.0;
                    }
                    pos.push(p);
                    tot.push(q);
                }
                ScoreTask {
                    pos_prefix: pos,
                    tot_prefix: tot,
                    parent_pos: p + 1.0,
                    parent_tot: q + 2.0,
                }
            })
            .collect();
        let timing = bench(10, 10.0, || {
            let r = scorer.score_tasks(&tasks).unwrap();
            std::hint::black_box(&r);
        });
        let boundaries = 64.0 * 512.0;
        t.row(&[
            "xla scorer (64 tasks x 512)".into(),
            format!("{boundaries:.0} boundaries"),
            timing.per_iter_label(),
            format!("{:.2} Mboundaries/s", boundaries / timing.mean_s / 1e6),
        ]);
    } else {
        println!("(skipping XLA scorer bench: run `make artifacts`)");
    }

    t.print();

    // BENCH_hotpath.json: the table plus typed before/after rows
    // proving each branchless rewrite.
    let mut o = t.to_json();
    o.set("rows_scanned", Json::from_usize(n)).set(
        "rewrites",
        Json::Arr(
            rewrites
                .iter()
                .map(|r| {
                    let mut rj = Json::object();
                    rj.set("hot_loop", Json::Str(r.hot_loop.into()))
                        .set("unit", Json::Str(r.unit.into()))
                        .set("before", Json::Num(r.before))
                        .set("after", Json::Num(r.after))
                        .set("speedup", Json::Num(r.after / r.before));
                    rj
                })
                .collect(),
        ),
    );
    write_bench_json("hotpath", o);
    println!("(hotpath timings feed EXPERIMENTS.md §Perf; times via {})", format_seconds(1.0));
}
