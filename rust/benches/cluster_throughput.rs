//! Training throughput across the deployment plane: rows/s for the
//! in-process engine vs loopback TCP vs real shard-pack-backed cluster
//! workers, by splitter count.
//!
//! The interesting comparisons:
//!
//! * direct vs tcp — the cost of pushing every RPC through the wire
//!   codec and the loopback stack;
//! * tcp vs cluster — the additional cost of the full deployment path:
//!   Hello-validated connections and workers that stream their columns
//!   from DRFC v2 shard packs on disk instead of sharing the leader's
//!   address space (each training run reconnects, so the handshake is
//!   part of the measured cost, exactly as a fresh leader would pay);
//! * splitter count — how the per-level fan-out amortizes.
//!
//! Exactness first: every configuration's forest is checked
//! bit-identical to the direct reference before timing. Results go to
//! `BENCH_cluster.json` in the working directory.

use drf::cluster::{load_shard, write_shards, ShardOptions, WorkerOptions, WorkerServer};
use drf::config::{Engine, ForestParams, TrainConfig};
use drf::data::io_stats::IoStats;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::rng::BaggingMode;
use drf::util::bench::{bench, fmt_count, write_bench_json, Table};
use drf::util::Json;

const ROWS: usize = 20_000;
const FEATURES: usize = 8;
const TREES: usize = 2;
const SPLITTER_COUNTS: [usize; 2] = [2, 4];

fn config(splitters: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.forest = ForestParams {
        num_trees: TREES,
        max_depth: 8,
        bagging: BaggingMode::Poisson,
        seed: 29,
        ..Default::default()
    };
    cfg.topology.num_splitters = Some(splitters);
    cfg
}

fn main() {
    let ds = SyntheticSpec::new(Family::Majority { informative: 5 }, ROWS, FEATURES, 3).generate();

    let mut table = Table::new(&["splitters", "engine", "time / forest", "rows/s", "vs direct"]);
    let mut configs: Vec<Json> = Vec::new();

    for &w in &SPLITTER_COUNTS {
        // Shard packs + one in-process worker fleet per splitter count
        // (real sockets, real DRFC v2 files — only the OS process
        // boundary is folded away; tests/cluster.rs covers that).
        let shard_dir = drf::util::tempdir().unwrap();
        let mut cfg = config(w);
        write_shards(
            &ds,
            &cfg.topology,
            shard_dir.path(),
            &ShardOptions::default(),
            IoStats::new(),
        )
        .unwrap();
        let workers: Vec<WorkerServer> = (0..w)
            .map(|s| {
                let shard = load_shard(
                    &shard_dir.path().join(format!("shard_{s}")),
                    &WorkerOptions::default(),
                )
                .unwrap();
                WorkerServer::spawn(shard, "127.0.0.1:0", 1).unwrap()
            })
            .collect();

        let reference = RandomForest::train_with_config(&ds, &cfg).unwrap().0;
        let mut direct_rps = 0.0f64;
        for engine in ["direct", "tcp", "cluster"] {
            match engine {
                "direct" => cfg.engine = Engine::Direct,
                "tcp" => cfg.engine = Engine::Tcp,
                _ => {
                    cfg.engine = Engine::Cluster;
                    cfg.cluster_manifest = Some(shard_dir.path().join("cluster.json"));
                    cfg.cluster_workers =
                        workers.iter().map(|s| s.addr().to_string()).collect();
                }
            }
            // Exactness before speed.
            let forest = RandomForest::train_with_config(&ds, &cfg).unwrap().0;
            assert_eq!(
                reference.trees, forest.trees,
                "{engine}/{w} splitters: engines must agree bit for bit"
            );
            let t = bench(3, 10.0, || {
                std::hint::black_box(RandomForest::train_with_config(&ds, &cfg).unwrap());
            });
            let rps = (ROWS * TREES) as f64 / t.mean_s;
            if engine == "direct" {
                direct_rps = rps;
            }
            let relative = rps / direct_rps;
            table.row(&[
                format!("{w}"),
                engine.into(),
                t.per_iter_label(),
                fmt_count(rps),
                format!("{relative:.2}x"),
            ]);
            let mut r = Json::object();
            r.set("splitters", Json::from_usize(w))
                .set("engine", Json::Str(engine.into()))
                .set("seconds_per_forest", Json::Num(t.mean_s))
                .set("rows_per_s", Json::Num(rps))
                .set("relative_to_direct", Json::Num(relative));
            configs.push(r);
        }
    }

    table.print();

    let mut o = Json::object();
    o.set("bench", Json::Str("cluster_throughput".into()))
        .set("rows", Json::from_usize(ROWS))
        .set("features", Json::from_usize(FEATURES))
        .set("trees", Json::from_usize(TREES))
        .set("configs", Json::Arr(configs));
    write_bench_json("cluster", o);
}
