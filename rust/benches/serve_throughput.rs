//! Serving throughput: reference row-at-a-time traversal vs the
//! flattened engine, single-threaded and multi-threaded.
//!
//! Acceptance target for the serve subsystem: flat batched prediction
//! ≥ 3× the reference `predict_scores` throughput on a 20-tree /
//! depth-12 forest. Results are printed as a table and recorded in
//! `BENCH_serve.json` (in the working directory) so later PRs have a
//! perf trajectory to compare against.

use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::{ForestParams, RandomForest};
use drf::serve::{BatchOptions, FlatForest};
use drf::util::bench::{bench, fmt_count, write_bench_json, Table};
use drf::util::Json;

fn main() {
    // Train on a modest sample; score a bigger disjoint set (training
    // time is not what this bench measures).
    let train = SyntheticSpec::new(Family::Majority { informative: 5 }, 30_000, 10, 1).generate();
    let test = SyntheticSpec::new(Family::Majority { informative: 5 }, 100_000, 10, 2).generate();
    let params = ForestParams {
        num_trees: 20,
        max_depth: 12,
        seed: 7,
        ..Default::default()
    };
    println!(
        "training {} trees (depth<={}) on {} rows…",
        params.num_trees,
        params.max_depth,
        train.num_rows()
    );
    let forest = RandomForest::train(&train, &params).unwrap();
    let flat = FlatForest::compile(&forest);
    println!(
        "model: {} nodes, {} KB flattened; scoring {} rows",
        forest.num_nodes(),
        flat.nbytes() / 1000,
        test.num_rows()
    );

    let n = test.num_rows() as f64;
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(1);

    let t_ref = bench(5, 15.0, || {
        std::hint::black_box(forest.predict_scores_reference(&test));
    });
    let t_flat = bench(5, 15.0, || {
        std::hint::black_box(flat.predict_scores_batch(&test, &BatchOptions::single_thread()));
    });
    let t_mt = bench(5, 15.0, || {
        std::hint::black_box(flat.predict_scores_batch(&test, &BatchOptions::default()));
    });

    // Sanity: the three paths agree bit-for-bit before we compare speed.
    let a = forest.predict_scores_reference(&test);
    let b = flat.predict_scores_batch(&test, &BatchOptions::single_thread());
    let c = flat.predict_scores_batch(&test, &BatchOptions::default());
    assert!(
        a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits())
            && a.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits()),
        "serving paths disagree — exactness before speed"
    );

    let rps = |mean_s: f64| n / mean_s;
    let mut table = Table::new(&["path", "time / pass", "rows/s", "speedup"]);
    table.row(&[
        "reference (row-at-a-time)".into(),
        t_ref.per_iter_label(),
        fmt_count(rps(t_ref.mean_s)),
        "1.00x".into(),
    ]);
    table.row(&[
        "flat (1 thread)".into(),
        t_flat.per_iter_label(),
        fmt_count(rps(t_flat.mean_s)),
        format!("{:.2}x", t_ref.mean_s / t_flat.mean_s),
    ]);
    table.row(&[
        format!("flat ({threads} threads)"),
        t_mt.per_iter_label(),
        fmt_count(rps(t_mt.mean_s)),
        format!("{:.2}x", t_ref.mean_s / t_mt.mean_s),
    ]);
    table.print();

    let mut o = Json::object();
    o.set("bench", Json::Str("serve_throughput".into()))
        .set("rows", Json::from_usize(test.num_rows()))
        .set("trees", Json::from_usize(params.num_trees))
        .set("max_depth", Json::from_u64(params.max_depth as u64))
        .set("num_nodes", Json::from_usize(forest.num_nodes()))
        .set("threads", Json::from_usize(threads))
        .set("reference_rows_per_s", Json::Num(rps(t_ref.mean_s)))
        .set("flat_rows_per_s", Json::Num(rps(t_flat.mean_s)))
        .set("flat_mt_rows_per_s", Json::Num(rps(t_mt.mean_s)))
        .set("speedup_flat", Json::Num(t_ref.mean_s / t_flat.mean_s))
        .set("speedup_flat_mt", Json::Num(t_ref.mean_s / t_mt.mean_s));
    write_bench_json("serve", o);
    if t_ref.mean_s / t_flat.mean_s < 3.0 {
        println!("WARNING: flat single-thread speedup below the 3x acceptance target");
    }
}
