//! Table 2 — Leo 1% / 10% / 100%: per-tree train time, leaves, node
//! density, sample density (+ AUC, which the paper reports in the
//! text: 0.823 / 0.837 / 0.847).
//!
//! Paper values (17.3e9 rows, 82 workers, depth 20):
//!   1%   : 0.838 h/tree, 140e3 leaves, density 0.134 / 0.766
//!   10%  : 3.156 h/tree, 320e3 leaves, density 0.305 / 0.904
//!   100% : 22.29 h/tree, 435e3 leaves, density 0.415 / 0.969
//! We reproduce the *shape* at 1:~60'000 scale on one core: time and
//! leaves grow strongly sub-proportionally to n, densities and AUC rise
//! with more data.

use drf::config::{ForestParams, StorageMode, TrainConfig};
use drf::data::synthetic::LeoLikeSpec;
use drf::forest::RandomForest;
use drf::metrics::auc;
use drf::util::bench::{fmt_bytes, Table};

fn main() {
    let full_n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120_000);
    let spec = LeoLikeSpec::new(full_n, 20_626);
    println!("generating Leo-like dataset ({full_n} rows)…");
    let full = spec.generate();
    let test = spec.generate_rows(full_n, (full_n / 5).max(5_000));

    let mut t = Table::new(&[
        "Leo",
        "Samples",
        "Train time (s/tree)",
        "Leaves",
        "Node density",
        "Sample density",
        "RF AUC",
        "net traffic",
        "paper (h/tree, leaves, nd, sd, AUC)",
    ]);
    let paper = [
        ("1%", "0.838h, 140e3, .134, .766, .823"),
        ("10%", "3.156h, 320e3, .305, .904, .837"),
        ("100%", "22.29h, 435e3, .415, .969, .847"),
    ];
    for (k, (label, frac, min_records)) in
        [("1%", 0.01f64, 2u64), ("10%", 0.1, 13), ("100%", 1.0, 133)]
            .into_iter()
            .enumerate()
    {
        let n = (full_n as f64 * frac) as usize;
        let ds = full.head(n);
        let params = ForestParams {
            num_trees: 3,
            max_depth: 14,
            min_records,
            seed: 9,
            ..Default::default()
        };
        let cfg = TrainConfig {
            forest: params,
            storage: StorageMode::Disk,
            ..Default::default()
        };
        let (forest, report) = RandomForest::train_with_config(&ds, &cfg).unwrap();
        let a = auc(&forest.predict_scores(&test), test.labels());
        t.row(&[
            label.into(),
            n.to_string(),
            format!("{:.2}", report.total_tree_seconds() / 3.0),
            format!("{:.0}", forest.mean_leaves()),
            format!("{:.3}", forest.mean_node_density()),
            format!("{:.3}", forest.mean_sample_density()),
            format!("{a:.4}"),
            fmt_bytes(report.net.net_bytes),
            paper[k].1.into(),
        ]);
    }
    t.print();
    t.write_json("table2_leo");
}
