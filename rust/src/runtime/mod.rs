//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The build-time Python stack (`python/compile/`) lowers the JAX/Pallas
//! split-scoring graph to **HLO text** (`artifacts/*.hlo.txt`). This
//! module loads that text, compiles it once on the PJRT CPU client, and
//! exposes typed execution — Python never runs on the training path.
//!
//! The actual PJRT bindings live behind the **`xla` cargo feature**
//! ([`pjrt`]); the default build substitutes an API-compatible stub
//! ([`stub`]) whose [`XlaRuntime::cpu`] returns a clear "not compiled
//! in" error, so the crate builds offline from `anyhow` alone while the
//! XLA scorer code paths still type-check.

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{literal_f32, Executable, Literal, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{literal_f32, Executable, Literal, XlaRuntime};
