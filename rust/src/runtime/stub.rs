//! API-compatible stand-in for the PJRT runtime, compiled when the
//! `xla` feature is off (the default, and the only configuration that
//! builds without the vendored `xla` crate).
//!
//! Every entry point type-checks exactly like the real runtime but
//! [`XlaRuntime::cpu`] fails with a clear message, so the XLA scorer
//! path degrades to an error *only when explicitly requested*
//! (`--scorer xla`); the exact scalar scorer — the default and the
//! correctness oracle — is unaffected.

use crate::Result;
use anyhow::bail;
use std::path::Path;

const UNAVAILABLE: &str =
    "XLA/PJRT support is not compiled in (rebuild with `--features xla` and the vendored `xla` crate)";

/// Placeholder for `xla::Literal`. Never constructed.
#[derive(Debug)]
pub struct Literal {
    _never: std::convert::Infallible,
}

impl Literal {
    /// Mirrors `xla::Literal::to_vec`; unreachable because no `Literal`
    /// can be constructed in a stub build.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        match self._never {}
    }
}

/// Placeholder PJRT client. [`Self::cpu`] always fails.
pub struct XlaRuntime {
    _never: std::convert::Infallible,
}

impl XlaRuntime {
    /// Always fails in a stub build.
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> String {
        match self._never {}
    }

    pub fn load_hlo_file(&self, _path: &Path) -> Result<Executable> {
        match self._never {}
    }

    pub fn load_hlo_text(&self, _text: &str) -> Result<Executable> {
        match self._never {}
    }
}

/// Placeholder compiled executable. Never constructed.
pub struct Executable {
    _never: std::convert::Infallible,
}

impl Executable {
    pub fn execute(&self, _inputs: &[Literal]) -> Result<Literal> {
        match self._never {}
    }

    pub fn execute_tuple(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        match self._never {}
    }
}

/// Mirrors `runtime::pjrt::literal_f32`; fails because literals cannot
/// exist without a PJRT client.
pub fn literal_f32(_data: &[f32], _dims: &[i64]) -> Result<Literal> {
    bail!(UNAVAILABLE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = XlaRuntime::cpu().err().expect("stub cpu() must fail");
        assert!(format!("{err}").contains("not compiled in"));
        assert!(literal_f32(&[1.0], &[1]).is_err());
    }
}
