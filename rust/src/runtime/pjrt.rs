//! Real PJRT runtime (`xla` feature): load AOT-compiled HLO artifacts
//! and execute them through the vendored `xla` crate.
//!
//! Interchange is HLO *text*, not a serialized `HloModuleProto`: jax ≥
//! 0.5 emits protos with 64-bit instruction ids which the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).

use crate::Result;
use anyhow::Context;
use std::path::Path;

/// The runtime's literal/buffer type (re-exported so callers never name
/// the `xla` crate directly — the stub build exports its own).
pub type Literal = xla::Literal;

/// A PJRT client plus the artifacts compiled on it.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact file and compile it.
    pub fn load_hlo_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e}", path.display()))
            .context("is `make artifacts` up to date?")?;
        self.compile_proto(proto)
    }

    /// Compile HLO text given directly (used by tests).
    pub fn load_hlo_text(&self, text: &str) -> Result<Executable> {
        // The crate only exposes file-based parsing; round-trip through a
        // temp file.
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "drf_hlo_{}_{}.txt",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::write(&path, text)?;
        let res = self.load_hlo_file(&path);
        let _ = std::fs::remove_file(&path);
        res
    }

    fn compile_proto(&self, proto: xla::HloModuleProto) -> Result<Executable> {
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling HLO: {e}"))?;
        Ok(Executable { exe })
    }
}

/// A compiled, loaded executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the raw output literal
    /// (jax-lowered modules return a tuple — see [`Self::execute_tuple`]).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing artifact: {e}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
        Ok(literal)
    }

    /// Execute and unpack a tuple result into its elements.
    pub fn execute_tuple(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let lit = self.execute(inputs)?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling result: {e}"))
    }
}

/// Build an f32 literal of the given shape from a flat row-major slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal hand-written HLO text — exercises the full parse/compile/
    /// execute path without any Python-built artifact.
    const ADD_HLO: &str = r#"
HloModule add_mod

ENTRY main {
  x = f32[4] parameter(0)
  y = f32[4] parameter(1)
  ROOT add = f32[4] add(x, y)
}
"#;

    #[test]
    fn compile_and_run_handwritten_hlo() {
        let rt = XlaRuntime::cpu().unwrap();
        assert_eq!(rt.platform_name(), "cpu");
        let exe = rt.load_hlo_text(ADD_HLO).unwrap();
        let x = xla::Literal::vec1(&[1f32, 2.0, 3.0, 4.0]);
        let y = xla::Literal::vec1(&[10f32, 20.0, 30.0, 40.0]);
        let out = exe.execute(&[x, y]).unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![11f32, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn literal_f32_shape_checks() {
        assert!(literal_f32(&[1.0, 2.0], &[2, 2]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn bad_hlo_is_a_clean_error() {
        let rt = XlaRuntime::cpu().unwrap();
        assert!(rt.load_hlo_text("this is not hlo").is_err());
    }
}
