//! Alg. 1 — best supersplit for one numerical feature, one pass.
//!
//! Given the presorted column `q(j)` (paper §2.1) and the sample→leaf
//! mapping, this computes the optimal `x ≤ τ` split of **every** open
//! leaf simultaneously in a single sequential scan: per leaf it keeps a
//! running label histogram `H_h` of already-traversed records, the last
//! seen value `v_h`, and the best threshold/score so far. Candidate
//! thresholds are midpoints between consecutive *distinct* values within
//! a leaf.
//!
//! The same function serves the distributed splitter and the classic
//! baseline (which calls it with a single-node mapping), guaranteeing
//! identical split decisions.

use super::histogram::Histogram;
use super::scorer::{midpoint, split_gain, ScoreKind, SplitCandidate};
use crate::data::column::SortedEntry;
use crate::tree::Condition;

/// Per-leaf scan state.
struct LeafState {
    hist: Histogram,
    last_value: Option<f32>,
    best_gain: f64,
    best_threshold: f32,
    best_left: Option<Histogram>,
    /// Binary-Gini constants of the parent, hoisted out of the
    /// per-boundary gain (EXPERIMENTS.md §Perf): gain =
    /// `parent_term − (2/n)·(L1·L0/n_L + R1·R0/n_R)`.
    inv_n2: f64,
    parent_term: f64,
}

impl LeafState {
    fn new(num_classes: u32, total: &Histogram) -> Self {
        let n = total.total() as f64;
        let (inv_n2, parent_term) = if total.counts().len() == 2 && n > 0.0 {
            let p1 = total.counts()[1] as f64;
            let p0 = total.counts()[0] as f64;
            (2.0 / n, 2.0 / n * (p1 * p0 / n))
        } else {
            (0.0, 0.0)
        };
        Self {
            hist: Histogram::new(num_classes),
            last_value: None,
            best_gain: 0.0,
            best_threshold: 0.0,
            best_left: None,
            inv_n2,
            parent_term,
        }
    }
}

/// Chunk-incremental supersplit scan over one numerical feature.
///
/// Alg. 1 is a pure left-to-right fold over the presorted entries, so
/// the scan state can be fed the column **chunk by chunk**
/// ([`push`](Self::push)) — this is what lets the
/// [`crate::data::store::ColumnStore`] backends stream arbitrarily
/// large columns through a bounded buffer. Results are invariant to
/// chunk boundaries: pushing one whole slice and pushing it split at
/// any points produce identical candidates
/// ([`best_numerical_supersplit`] is exactly the one-slice wrapper).
///
/// Per-sample filtering goes through a single **gather** closure
/// (`gather(i) -> (rank, bag)`; rank 0 = skip) instead of three
/// separate predicates: the splitter feeds a table-driven gather whose
/// skip decision compiles to one well-predicted branch, instead of the
/// historical closed-leaf / non-candidate / out-of-bag branch ladder
/// (see [`crate::splits::fused_gather`] for the adapter and
/// BENCH_hotpath.json for the before/after).
pub struct NumericalSupersplitScan<'a, G>
where
    G: Fn(u32) -> (u32, u32),
{
    feature: usize,
    labels: &'a [u32],
    leaf_totals: &'a [Histogram],
    kind: ScoreKind,
    binary_gini: bool,
    states: Vec<LeafState>,
    gather: G,
}

impl<'a, G> NumericalSupersplitScan<'a, G>
where
    G: Fn(u32) -> (u32, u32),
{
    /// * `labels` — the shared label column (indexed by sample);
    /// * `leaf_totals[h-1]` — bagged label histogram of open leaf rank
    ///   `h` (1-based ranks; rank 0 means closed — see
    ///   [`crate::classlist`]);
    /// * `gather(i)` — `(leaf rank, bagged multiplicity)` of sample
    ///   `i`; rank 0 means skip (closed leaf, feature not drawn for
    ///   the sample's leaf, or out-of-bag). A returned rank > 0
    ///   guarantees bag > 0.
    pub fn new(
        feature: usize,
        labels: &'a [u32],
        num_classes: u32,
        leaf_totals: &'a [Histogram],
        kind: ScoreKind,
        gather: G,
    ) -> Self {
        let states: Vec<LeafState> = leaf_totals
            .iter()
            .map(|t| LeafState::new(num_classes, t))
            .collect();
        Self {
            feature,
            labels,
            leaf_totals,
            kind,
            binary_gini: num_classes == 2 && kind == ScoreKind::Gini,
            states,
            gather,
        }
    }

    /// Feed the next chunk of presorted entries (in value order,
    /// continuing exactly where the previous chunk ended).
    pub fn push(&mut self, q: &[SortedEntry]) {
        for e in q {
            let (h, b) = (self.gather)(e.sample);
            if h == 0 {
                continue; // closed / non-candidate / out-of-bag
            }
            let st = &mut self.states[(h - 1) as usize];
            if let Some(v) = st.last_value {
                // Only a *distinct-value* boundary is a candidate
                // threshold.
                if e.value > v {
                    let totals = &self.leaf_totals[(h - 1) as usize];
                    // Same ranking as scorer::split_gain; the
                    // binary-Gini branch inlines the hoisted-constant
                    // form.
                    let gain = if self.binary_gini {
                        let l1 = st.hist.counts()[1] as f64;
                        let l0 = st.hist.counts()[0] as f64;
                        let nl = l1 + l0;
                        let p1 = totals.counts()[1] as f64;
                        let p0 = totals.counts()[0] as f64;
                        let nr = (p1 - l1) + (p0 - l0);
                        if nl == 0.0 || nr == 0.0 {
                            None
                        } else {
                            Some(
                                st.parent_term
                                    - st.inv_n2
                                        * (l1 * l0 / nl + (p1 - l1) * (p0 - l0) / nr),
                            )
                        }
                    } else {
                        split_gain(self.kind, totals, &st.hist)
                    };
                    if let Some(gain) = gain {
                        // Strict '>' keeps the first (lowest) best
                        // threshold, exactly as Alg. 1's `if s' > s_h`.
                        if gain > 0.0 && gain > st.best_gain {
                            st.best_gain = gain;
                            st.best_threshold = midpoint(v, e.value);
                            st.best_left = Some(st.hist.clone());
                        }
                    }
                }
            }
            st.hist.add(self.labels[e.sample as usize], b);
            st.last_value = Some(e.value);
        }
    }

    /// Close the scan: per leaf rank−1, the best candidate split
    /// (gain > 0) if any.
    pub fn finish(self) -> Vec<Option<SplitCandidate>> {
        let feature = self.feature;
        let leaf_totals = self.leaf_totals;
        self.states
            .into_iter()
            .enumerate()
            .map(|(idx, st)| {
                let left = st.best_left?;
                let right = leaf_totals[idx].minus(&left);
                Some(SplitCandidate {
                    condition: Condition::NumLe {
                        feature,
                        threshold: st.best_threshold,
                    },
                    gain: st.best_gain,
                    left_counts: left.into_counts(),
                    right_counts: right.into_counts(),
                })
            })
            .collect()
    }
}

/// Compute the best `x ≤ τ` split of every open leaf for `feature` in
/// one call over the whole presorted column `q` — the single-slice
/// wrapper around [`NumericalSupersplitScan`] (used by the baselines
/// and the in-memory fast paths).
#[allow(clippy::too_many_arguments)]
pub fn best_numerical_supersplit(
    feature: usize,
    q: &[SortedEntry],
    labels: &[u32],
    num_classes: u32,
    leaf_totals: &[Histogram],
    kind: ScoreKind,
    sample2node: impl Fn(u32) -> u32,
    is_candidate: impl Fn(u32) -> bool,
    bag: impl Fn(u32) -> u32,
) -> Vec<Option<SplitCandidate>> {
    let mut scan = NumericalSupersplitScan::new(
        feature,
        labels,
        num_classes,
        leaf_totals,
        kind,
        crate::splits::fused_gather(sample2node, is_candidate, bag),
    );
    scan.push(q);
    scan.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;

    fn presort(values: &[f32]) -> Vec<SortedEntry> {
        Column::Numerical(values.to_vec()).presort()
    }

    fn totals_of(labels: &[u32], num_classes: u32) -> Vec<Histogram> {
        let mut h = Histogram::new(num_classes);
        for &y in labels {
            h.add(y, 1);
        }
        vec![h]
    }

    #[test]
    fn perfectly_separable_single_leaf() {
        // values < 5 are class 0, values >= 5 are class 1.
        let values = [1.0f32, 2.0, 3.0, 7.0, 8.0, 9.0];
        let labels = [0u32, 0, 0, 1, 1, 1];
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &totals_of(&labels, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let c = res[0].as_ref().unwrap();
        assert!((c.gain - 0.5).abs() < 1e-12, "full gini gain");
        match &c.condition {
            Condition::NumLe { threshold, .. } => {
                assert_eq!(*threshold, 5.0, "midpoint of 3 and 7");
            }
            _ => panic!(),
        }
        assert_eq!(c.left_counts, vec![3, 0]);
        assert_eq!(c.right_counts, vec![0, 3]);
    }

    #[test]
    fn constant_column_has_no_split() {
        let values = [2.0f32; 5];
        let labels = [0u32, 1, 0, 1, 0];
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &totals_of(&labels, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        assert!(res[0].is_none());
    }

    #[test]
    fn pure_leaf_has_no_positive_gain() {
        let values = [1.0f32, 2.0, 3.0];
        let labels = [1u32, 1, 1];
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &totals_of(&labels, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        assert!(res[0].is_none());
    }

    #[test]
    fn respects_bagging_weights() {
        // Sample 2 (the only class-1 below 5) is out-of-bag; with it
        // excluded the best split separates perfectly.
        let values = [1.0f32, 2.0, 3.0, 7.0, 8.0];
        let labels = [0u32, 0, 1, 1, 1];
        let bag = |i: u32| if i == 2 { 0 } else { 1 };
        let mut totals = Histogram::new(2);
        for (i, &y) in labels.iter().enumerate() {
            totals.add(y, bag(i as u32));
        }
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &[totals],
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            bag,
        );
        let c = res[0].as_ref().unwrap();
        assert_eq!(c.left_counts, vec![2, 0]);
        assert_eq!(c.right_counts, vec![0, 2]);
    }

    #[test]
    fn two_leaves_split_independently_in_one_pass() {
        // Leaf 1 = even samples (class = value > 4), leaf 2 = odd samples
        // (class = value > 6).
        let values = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let node = |i: u32| (i % 2) + 1;
        let labels: Vec<u32> = values
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                if i % 2 == 0 {
                    (v > 4.0) as u32
                } else {
                    (v > 6.0) as u32
                }
            })
            .collect();
        let mut t1 = Histogram::new(2);
        let mut t2 = Histogram::new(2);
        for (i, &y) in labels.iter().enumerate() {
            if i % 2 == 0 {
                t1.add(y, 1)
            } else {
                t2.add(y, 1)
            }
        }
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &[t1, t2],
            ScoreKind::Gini,
            node,
            |_| true,
            |_| 1,
        );
        let c1 = res[0].as_ref().unwrap();
        let c2 = res[1].as_ref().unwrap();
        let thr = |c: &SplitCandidate| match c.condition {
            Condition::NumLe { threshold, .. } => threshold,
            _ => panic!(),
        };
        assert_eq!(thr(c1), 4.0, "leaf1 splits between 3 and 5");
        assert_eq!(thr(c2), 7.0, "leaf2 splits between 6 and 8");
        // Leaf1: [2,2] separated perfectly -> gini gain 0.5.
        assert!((c1.gain - 0.5).abs() < 1e-12);
        // Leaf2: [3,1] separated perfectly -> gain = gini([3,1]) = 0.375.
        assert!((c2.gain - 0.375).abs() < 1e-12);
    }

    #[test]
    fn non_candidate_feature_skipped() {
        let values = [1.0f32, 2.0, 3.0, 4.0];
        let labels = [0u32, 0, 1, 1];
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &totals_of(&labels, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| false, // not drawn for any leaf
            |_| 1,
        );
        assert!(res[0].is_none());
    }

    #[test]
    fn ties_prefer_lowest_threshold() {
        // Two equally good thresholds (symmetric XOR-free case):
        // labels 0,1,0,1 -> splits at 1.5 and 3.5 both give gain 0 — no
        // split. Use labels 0,1,1,0: thresholds 1.5 / 3.5 give equal
        // gain; Alg. 1's strict '>' keeps the first (1.5).
        let values = [1.0f32, 2.0, 3.0, 4.0];
        let labels = [0u32, 1, 1, 0];
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &totals_of(&labels, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let c = res[0].as_ref().unwrap();
        match c.condition {
            Condition::NumLe { threshold, .. } => assert_eq!(threshold, 1.5),
            _ => panic!(),
        }
    }

    #[test]
    fn chunked_push_matches_single_slice() {
        // Feeding the scan in arbitrary chunk sizes must be invariant.
        let values: Vec<f32> = (0..200).map(|i| ((i * 37) % 50) as f32).collect();
        let labels: Vec<u32> = (0..200).map(|i| ((i * 13) % 2) as u32).collect();
        let q = presort(&values);
        let totals = totals_of(&labels, 2);
        let whole = best_numerical_supersplit(
            0,
            &q,
            &labels,
            2,
            &totals,
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        for chunk in [1usize, 7, 64, 199] {
            let mut scan = NumericalSupersplitScan::new(
                0,
                &labels,
                2,
                &totals,
                ScoreKind::Gini,
                crate::splits::fused_gather(|_| 1, |_| true, |_| 1),
            );
            for c in q.chunks(chunk) {
                scan.push(c);
            }
            let got = scan.finish();
            assert_eq!(got.len(), whole.len());
            for (a, b) in whole.iter().zip(&got) {
                match (a, b) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        assert_eq!(a.condition, b.condition, "chunk={chunk}");
                        assert_eq!(a.gain.to_bits(), b.gain.to_bits(), "chunk={chunk}");
                        assert_eq!(a.left_counts, b.left_counts);
                        assert_eq!(a.right_counts, b.right_counts);
                    }
                    _ => panic!("candidate presence differs at chunk={chunk}"),
                }
            }
        }
    }

    #[test]
    fn entropy_kind_works() {
        let values = [1.0f32, 2.0, 3.0, 4.0];
        let labels = [0u32, 0, 1, 1];
        let res = best_numerical_supersplit(
            0,
            &presort(&values),
            &labels,
            2,
            &totals_of(&labels, 2),
            ScoreKind::Entropy,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let c = res[0].as_ref().unwrap();
        assert!((c.gain - std::f64::consts::LN_2).abs() < 1e-12);
    }
}
