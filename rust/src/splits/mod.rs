//! Best-split search (paper §2.4 and Alg. 1).
//!
//! A *supersplit* is a set of splits mapped one-to-one with the open
//! leaves at a given depth. The functions here compute, for one feature
//! column, the optimal split of **every** open leaf in a **single
//! sequential pass** over the column — the property that gives DRF its
//! `Z·n·D` read complexity (one pass per feature per *level*, never per
//! node).
//!
//! * [`histogram`] — weighted label histograms + impurity measures;
//! * [`scorer`] — split gain, candidate comparison (deterministic
//!   tie-breaking shared by DRF and the classic baseline — this is what
//!   makes the two algorithms produce identical trees);
//! * [`numerical`] — Alg. 1 over a presorted column;
//! * [`categorical`] — count-table search with the exact
//!   sorted-by-class-ratio subset construction for binary labels;
//! * [`xla_scorer`] — optional batched threshold scoring through the
//!   AOT-compiled XLA/Pallas artifact (see `runtime`).

pub mod categorical;
pub mod histogram;
pub mod numerical;
pub mod regression;
pub mod scorer;
pub mod xla_scorer;

pub use histogram::Histogram;
pub use scorer::{ScoreKind, SplitCandidate};

/// Fuse the classic three scan predicates — sample→leaf mapping,
/// per-leaf feature candidacy, bag weight — into the single
/// `gather(i) -> (rank, bag)` closure the supersplit scans consume
/// (rank 0 = skip the sample). This is the compatibility adapter for
/// callers holding separate closures (baselines, tests); the splitter
/// hot path builds a branchless table-driven gather instead
/// (`SplitterCore::scan_column_supersplit`, BENCH_hotpath.json
/// `supersplit gather`).
pub fn fused_gather(
    sample2node: impl Fn(u32) -> u32,
    is_candidate: impl Fn(u32) -> bool,
    bag: impl Fn(u32) -> u32,
) -> impl Fn(u32) -> (u32, u32) {
    move |i| {
        let h = sample2node(i);
        if h == 0 || !is_candidate(h) {
            return (0, 0);
        }
        let b = bag(i);
        if b == 0 {
            (0, 0)
        } else {
            (h, b)
        }
    }
}
