//! Regression split search for gradient-boosted trees (paper §1/§2:
//! "the proposed algorithm can be applied to other DF models, notably
//! Gradient Boosted Trees").
//!
//! Second-order (Newton) scoring à la XGBoost: each sample carries a
//! gradient/hessian pair `(g, h)`; the quality of a split is
//!
//! `gain = ½ [ G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ) ]`
//!
//! and the optimal leaf weight is `−G/(H+λ)`. The scan structure is
//! identical to Alg. 1 (one pass over the presorted column per level),
//! so a distributed GBT inherits DRF's complexity — except gradients
//! change per tree, which costs one `2·f32` broadcast per sample per
//! tree (see DESIGN.md §5 and `forest::gbt`).

use crate::data::column::SortedEntry;
use crate::splits::scorer::midpoint;

/// Aggregated gradient statistics of a sample set.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GradStats {
    pub grad: f64,
    pub hess: f64,
}

impl GradStats {
    #[inline]
    pub fn add(&mut self, g: f64, h: f64) {
        self.grad += g;
        self.hess += h;
    }

    #[inline]
    pub fn minus(&self, other: &GradStats) -> GradStats {
        GradStats {
            grad: self.grad - other.grad,
            hess: self.hess - other.hess,
        }
    }

    /// Newton objective reduction contributed by a leaf with these stats.
    #[inline]
    pub fn score(&self, lambda: f64) -> f64 {
        self.grad * self.grad / (self.hess + lambda)
    }

    /// Optimal leaf weight.
    #[inline]
    pub fn weight(&self, lambda: f64) -> f64 {
        -self.grad / (self.hess + lambda)
    }
}

/// A found regression split.
#[derive(Debug, Clone, PartialEq)]
pub struct RegSplit {
    pub threshold: f32,
    pub gain: f64,
    pub left: GradStats,
    pub right: GradStats,
}

/// Best `x ≤ τ` regression split of one node over a presorted column
/// slice (entries already restricted to the node's rows).
pub fn best_regression_split(
    entries: &[SortedEntry],
    grads: &[f64],
    hess: &[f64],
    parent: GradStats,
    lambda: f64,
    min_child_hess: f64,
) -> Option<RegSplit> {
    let mut left = GradStats::default();
    let mut last: Option<f32> = None;
    let mut best: Option<RegSplit> = None;
    let parent_score = parent.score(lambda);
    for e in entries {
        if let Some(v) = last {
            if e.value > v {
                let right = parent.minus(&left);
                if left.hess >= min_child_hess && right.hess >= min_child_hess {
                    let gain =
                        0.5 * (left.score(lambda) + right.score(lambda) - parent_score);
                    // Strict improvement keeps the lowest threshold on ties.
                    if gain > 1e-12 && best.as_ref().map_or(true, |b| gain > b.gain) {
                        best = Some(RegSplit {
                            threshold: midpoint(v, e.value),
                            gain,
                            left,
                            right,
                        });
                    }
                }
            }
        }
        left.add(grads[e.sample as usize], hess[e.sample as usize]);
        last = Some(e.value);
    }
    best
}

/// A found categorical regression split: subset + stats.
#[derive(Debug, Clone, PartialEq)]
pub struct RegCatSplit {
    pub values: Vec<u32>,
    pub gain: f64,
    pub left: GradStats,
    pub right: GradStats,
}

/// Best `x ∈ C` regression split of one node. The exact construction
/// for squared-error-style objectives: sort observed values by their
/// optimal leaf weight and scan prefixes (the regression analogue of
/// the Breiman trick).
pub fn best_categorical_regression(
    values_in_node: impl Iterator<Item = (u32, f64, f64)>, // (value, g, h)
    parent: GradStats,
    lambda: f64,
    min_child_hess: f64,
) -> Option<RegCatSplit> {
    use std::collections::BTreeMap;
    let mut table: BTreeMap<u32, GradStats> = BTreeMap::new();
    for (v, g, h) in values_in_node {
        table.entry(v).or_default().add(g, h);
    }
    if table.len() < 2 {
        return None;
    }
    let mut entries: Vec<(u32, GradStats)> = table.into_iter().collect();
    entries.sort_by(|(va, sa), (vb, sb)| {
        sa.weight(lambda)
            .partial_cmp(&sb.weight(lambda))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(va.cmp(vb))
    });
    let parent_score = parent.score(lambda);
    let mut left = GradStats::default();
    let mut best: Option<(f64, usize)> = None;
    for (k, (_, s)) in entries.iter().enumerate().take(entries.len() - 1) {
        left.add(s.grad, s.hess);
        let right = parent.minus(&left);
        if left.hess < min_child_hess || right.hess < min_child_hess {
            continue;
        }
        let gain = 0.5 * (left.score(lambda) + right.score(lambda) - parent_score);
        if gain > 1e-12 && best.map_or(true, |(bg, _)| gain > bg) {
            best = Some((gain, k + 1));
        }
    }
    let (gain, prefix) = best?;
    let mut left = GradStats::default();
    for (_, s) in &entries[..prefix] {
        left.add(s.grad, s.hess);
    }
    Some(RegCatSplit {
        values: entries[..prefix].iter().map(|(v, _)| *v).collect(),
        gain,
        left,
        right: parent.minus(&left),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(values: &[f32]) -> Vec<SortedEntry> {
        let mut v: Vec<SortedEntry> = values
            .iter()
            .enumerate()
            .map(|(i, &value)| SortedEntry {
                value,
                sample: i as u32,
            })
            .collect();
        v.sort_by(|a, b| a.value.partial_cmp(&b.value).unwrap());
        v
    }

    #[test]
    fn separates_opposite_gradients() {
        // Samples below 5 want +1, above want -1 (gradients −1 / +1).
        let values = [1.0f32, 2.0, 3.0, 7.0, 8.0, 9.0];
        let grads = [-1.0, -1.0, -1.0, 1.0, 1.0, 1.0];
        let hess = [1.0; 6];
        let parent = GradStats {
            grad: 0.0,
            hess: 6.0,
        };
        let s = best_regression_split(&entries(&values), &grads, &hess, parent, 1.0, 0.0)
            .unwrap();
        assert_eq!(s.threshold, 5.0);
        assert!(s.gain > 0.0);
        assert!((s.left.weight(1.0) - 0.75).abs() < 1e-12); // -(-3)/(3+1)
        assert!((s.right.weight(1.0) + 0.75).abs() < 1e-12);
    }

    #[test]
    fn uniform_gradients_no_split() {
        let values = [1.0f32, 2.0, 3.0, 4.0];
        let grads = [1.0; 4];
        let hess = [1.0; 4];
        let parent = GradStats {
            grad: 4.0,
            hess: 4.0,
        };
        assert!(
            best_regression_split(&entries(&values), &grads, &hess, parent, 1.0, 0.0).is_none()
        );
    }

    #[test]
    fn min_child_hess_enforced() {
        let values = [1.0f32, 2.0, 3.0, 4.0];
        let grads = [-5.0, 1.0, 1.0, 1.0];
        let hess = [0.5; 4];
        let parent = GradStats {
            grad: -2.0,
            hess: 2.0,
        };
        // The natural cut isolates sample 0 (hess 0.5) — forbidden at
        // min_child_hess = 1.0.
        let s = best_regression_split(&entries(&values), &grads, &hess, parent, 1.0, 1.0);
        if let Some(s) = s {
            assert!(s.left.hess >= 1.0 && s.right.hess >= 1.0);
        }
    }

    #[test]
    fn categorical_regression_groups_by_weight() {
        // values 0,1 pull negative weights; 2,3 positive.
        let samples = vec![
            (0u32, 2.0, 1.0),
            (0, 2.0, 1.0),
            (1, 1.5, 1.0),
            (2, -1.5, 1.0),
            (3, -2.0, 1.0),
            (3, -2.0, 1.0),
        ];
        let mut parent = GradStats::default();
        for &(_, g, h) in &samples {
            parent.add(g, h);
        }
        let s = best_categorical_regression(samples.into_iter(), parent, 1.0, 0.0).unwrap();
        // Sorted by weight: positive-grad values first (negative weight).
        assert!(s.gain > 0.0);
        let mut vals = s.values.clone();
        vals.sort_unstable();
        assert!(vals == vec![0, 1] || vals == vec![2, 3], "grouping {vals:?}");
    }

    #[test]
    fn constant_column_no_split() {
        let values = [2.0f32; 4];
        let grads = [-1.0, 1.0, -1.0, 1.0];
        let hess = [1.0; 4];
        let parent = GradStats {
            grad: 0.0,
            hess: 4.0,
        };
        assert!(
            best_regression_split(&entries(&values), &grads, &hess, parent, 1.0, 0.0).is_none()
        );
    }
}
