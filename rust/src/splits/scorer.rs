//! Split gain and deterministic candidate comparison.
//!
//! DRF's exactness claim requires every worker — and the classic
//! sequential baseline — to rank candidate splits identically. All
//! ranking therefore goes through this module: the same `f64` gain
//! formula over exact integer counts, and one total order
//! ([`SplitCandidate::better_than`]) with explicit tie-breaking.

use super::histogram::Histogram;
use crate::tree::Condition;

/// Which impurity measure drives split selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreKind {
    /// Gini index (Breiman's Random Forest default).
    Gini,
    /// Information gain (Shannon entropy).
    Entropy,
}

impl Default for ScoreKind {
    fn default() -> Self {
        ScoreKind::Gini
    }
}

impl ScoreKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ScoreKind::Gini => "gini",
            ScoreKind::Entropy => "entropy",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "gini" => ScoreKind::Gini,
            "entropy" => ScoreKind::Entropy,
            _ => anyhow::bail!("unknown score kind '{s}'"),
        })
    }

    #[inline]
    pub fn impurity(self, h: &Histogram) -> f64 {
        match self {
            ScoreKind::Gini => h.gini(),
            ScoreKind::Entropy => h.entropy(),
        }
    }
}

/// Weighted impurity decrease of splitting `parent` into `left` and
/// `parent - left`:
///
/// `gain = imp(parent) − (n_L/n)·imp(L) − (n_R/n)·imp(R)`
///
/// Returns `None` when either side is empty (not a real split).
///
/// Allocation-free: the right child's impurity is computed from the
/// count differences directly (this sits in Alg. 1's innermost loop —
/// see EXPERIMENTS.md §Perf).
#[inline]
pub fn split_gain(kind: ScoreKind, parent: &Histogram, left: &Histogram) -> Option<f64> {
    let n = parent.total();
    let nl = left.total();
    if nl == 0 || nl >= n {
        return None;
    }
    let nr = n - nl;
    // Binary Gini fast path (the overwhelmingly common case, and the
    // innermost loop of Alg. 1): algebraically identical ranking with 3
    // divisions instead of 5 impurity evaluations —
    //   gain = 2/n · ( P1·P0/n − L1·L0/n_L − R1·R0/n_R ).
    if kind == ScoreKind::Gini && parent.counts().len() == 2 {
        let p1 = parent.counts()[1] as f64;
        let p0 = parent.counts()[0] as f64;
        let l1 = left.counts()[1] as f64;
        let l0 = left.counts()[0] as f64;
        let r1 = p1 - l1;
        let r0 = p0 - l0;
        let nf = n as f64;
        return Some(
            2.0 / nf * (p1 * p0 / nf - l1 * l0 / nl as f64 - r1 * r0 / nr as f64),
        );
    }
    let imp = |counts: ImpurityInput<'_>, total: u64| -> f64 {
        let t = total as f64;
        match kind {
            ScoreKind::Gini => {
                let mut acc = 0.0;
                counts.for_each(|c| {
                    let p = c as f64 / t;
                    acc += p * p;
                });
                1.0 - acc
            }
            ScoreKind::Entropy => {
                let mut acc = 0.0;
                counts.for_each(|c| {
                    if c > 0 {
                        let p = c as f64 / t;
                        acc -= p * p.ln();
                    }
                });
                acc
            }
        }
    };
    let pc = parent.counts();
    let lc = left.counts();
    let nf = n as f64;
    Some(
        imp(ImpurityInput::Direct(pc), n)
            - (nl as f64 / nf) * imp(ImpurityInput::Direct(lc), nl)
            - (nr as f64 / nf) * imp(ImpurityInput::Diff(pc, lc), nr),
    )
}

/// Count source for impurity: a slice, or an elementwise difference of
/// two slices (the right child), iterated without materialization.
enum ImpurityInput<'a> {
    Direct(&'a [u64]),
    Diff(&'a [u64], &'a [u64]),
}

impl ImpurityInput<'_> {
    #[inline]
    fn for_each(&self, mut f: impl FnMut(u64)) {
        match self {
            ImpurityInput::Direct(c) => {
                for &v in *c {
                    f(v);
                }
            }
            ImpurityInput::Diff(a, b) => {
                for (&x, &y) in a.iter().zip(*b) {
                    debug_assert!(x >= y);
                    f(x - y);
                }
            }
        }
    }
}

/// Midpoint threshold between two consecutive distinct sorted values
/// (Alg. 1's `τ = (a + v_h)/2`). Computed in f64, stored as f32 —
/// **every implementation must use this function** so thresholds agree
/// bit-for-bit.
#[inline]
pub fn midpoint(lo: f32, hi: f32) -> f32 {
    ((lo as f64 + hi as f64) / 2.0) as f32
}

/// A fully scored candidate split for one leaf.
#[derive(Debug, Clone, PartialEq)]
pub struct SplitCandidate {
    pub condition: Condition,
    /// Weighted impurity decrease (strictly positive for usable splits).
    pub gain: f64,
    /// Label histogram of the left child (condition true).
    pub left_counts: Vec<u64>,
    /// Label histogram of the right child.
    pub right_counts: Vec<u64>,
}

impl SplitCandidate {
    /// Total order used everywhere a "best" split is chosen.
    ///
    /// Higher gain wins. Exact ties break to the **lower feature
    /// index**, then to the numerically lower threshold / smaller
    /// category set — all deterministic, no HashMap iteration order or
    /// float ambiguity involved.
    pub fn better_than(&self, other: &SplitCandidate) -> bool {
        if self.gain != other.gain {
            return self.gain > other.gain;
        }
        let (fa, fb) = (self.condition.feature(), other.condition.feature());
        if fa != fb {
            return fa < fb;
        }
        match (&self.condition, &other.condition) {
            (
                Condition::NumLe { threshold: a, .. },
                Condition::NumLe { threshold: b, .. },
            ) => a < b,
            (Condition::CatIn { set: a, .. }, Condition::CatIn { set: b, .. }) => {
                if a.len() != b.len() {
                    return a.len() < b.len();
                }
                // Lexicographic on members.
                a.iter().lt(b.iter())
            }
            // A feature is either numerical or categorical, never both.
            _ => false,
        }
    }
}

/// Reduce candidates to the best one (used by splitters over their local
/// features and by the tree builder over splitter answers).
pub fn pick_best(candidates: impl IntoIterator<Item = SplitCandidate>) -> Option<SplitCandidate> {
    let mut best: Option<SplitCandidate> = None;
    for c in candidates {
        match &best {
            None => best = Some(c),
            Some(b) => {
                if c.better_than(b) {
                    best = Some(c);
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::CategorySet;

    fn num_cand(feature: usize, threshold: f32, gain: f64) -> SplitCandidate {
        SplitCandidate {
            condition: Condition::NumLe { feature, threshold },
            gain,
            left_counts: vec![1, 0],
            right_counts: vec![0, 1],
        }
    }

    #[test]
    fn gain_matches_hand_computation() {
        // parent: [4, 4] gini 0.5; left [4, 0] gini 0; right [0, 4] gini 0.
        let parent = Histogram::from_counts(vec![4, 4]);
        let left = Histogram::from_counts(vec![4, 0]);
        let g = split_gain(ScoreKind::Gini, &parent, &left).unwrap();
        assert!((g - 0.5).abs() < 1e-12);
        // Useless split: left [2,2] -> gain 0.
        let left2 = Histogram::from_counts(vec![2, 2]);
        let g2 = split_gain(ScoreKind::Gini, &parent, &left2).unwrap();
        assert!(g2.abs() < 1e-12);
    }

    #[test]
    fn gain_rejects_empty_sides() {
        let parent = Histogram::from_counts(vec![4, 4]);
        assert!(split_gain(ScoreKind::Gini, &parent, &Histogram::new(2)).is_none());
        assert!(split_gain(ScoreKind::Gini, &parent, &parent).is_none());
    }

    #[test]
    fn entropy_gain_positive_for_separating_split() {
        let parent = Histogram::from_counts(vec![6, 2]);
        let left = Histogram::from_counts(vec![6, 0]);
        let g = split_gain(ScoreKind::Entropy, &parent, &left).unwrap();
        assert!(g > 0.0);
    }

    #[test]
    fn ordering_gain_then_feature_then_threshold() {
        let a = num_cand(3, 1.0, 0.5);
        let b = num_cand(0, 1.0, 0.4);
        assert!(a.better_than(&b), "higher gain wins");
        let c = num_cand(0, 1.0, 0.5);
        assert!(c.better_than(&a), "tie: lower feature wins");
        let d = num_cand(0, 0.5, 0.5);
        assert!(d.better_than(&c), "tie: lower threshold wins");
        assert!(!c.better_than(&c), "irreflexive");
    }

    #[test]
    fn ordering_categorical_sets() {
        let mk = |vals: &[u32], gain: f64| SplitCandidate {
            condition: Condition::CatIn {
                feature: 1,
                set: CategorySet::from_values(10, vals.iter().copied()),
            },
            gain,
            left_counts: vec![1, 0],
            right_counts: vec![0, 1],
        };
        let small = mk(&[1], 0.3);
        let big = mk(&[1, 2], 0.3);
        assert!(small.better_than(&big), "tie: smaller set wins");
        let lex1 = mk(&[1, 3], 0.3);
        let lex2 = mk(&[2, 3], 0.3);
        assert!(lex1.better_than(&lex2), "tie: lexicographic");
    }

    #[test]
    fn pick_best_returns_max() {
        let best = pick_best(vec![
            num_cand(1, 0.0, 0.1),
            num_cand(2, 0.0, 0.9),
            num_cand(3, 0.0, 0.5),
        ])
        .unwrap();
        assert_eq!(best.condition.feature(), 2);
        assert!(pick_best(vec![]).is_none());
    }

    #[test]
    fn midpoint_deterministic() {
        assert_eq!(midpoint(1.0, 2.0), 1.5);
        assert_eq!(midpoint(0.1, 0.2), ((0.1f32 as f64 + 0.2f32 as f64) / 2.0) as f32);
    }
}
