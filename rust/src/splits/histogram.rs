//! Weighted label histograms and impurity measures.
//!
//! Alg. 1 maintains one histogram per open leaf (`H_h`) and scores each
//! candidate threshold from it incrementally. All arithmetic that can
//! affect a split decision is done in `f64` over exact integer counts,
//! so scores are bit-reproducible across DRF workers and the classic
//! baseline.


/// A weighted per-class count vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>,
}

impl Histogram {
    pub fn new(num_classes: u32) -> Self {
        Self {
            counts: vec![0; num_classes as usize],
        }
    }

    pub fn from_counts(counts: Vec<u64>) -> Self {
        Self { counts }
    }

    pub fn num_classes(&self) -> u32 {
        self.counts.len() as u32
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn into_counts(self) -> Vec<u64> {
        self.counts
    }

    /// Add `weight` observations of `class` (Alg. 1's "Add label y
    /// weighted by b to H_h").
    #[inline]
    pub fn add(&mut self, class: u32, weight: u32) {
        self.counts[class as usize] += weight as u64;
    }

    #[inline]
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn is_zero(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    /// Reset all counts to zero.
    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }

    /// Element-wise sum.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// `self - other`, element-wise (other must be a sub-histogram).
    pub fn minus(&self, other: &Histogram) -> Histogram {
        assert_eq!(self.counts.len(), other.counts.len());
        Histogram {
            counts: self
                .counts
                .iter()
                .zip(&other.counts)
                .map(|(&a, &b)| {
                    debug_assert!(a >= b, "minus would underflow");
                    a - b
                })
                .collect(),
        }
    }

    /// Gini impurity: `1 - Σ p_c²`.
    pub fn gini(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let t = total as f64;
        1.0 - self
            .counts
            .iter()
            .map(|&c| {
                let p = c as f64 / t;
                p * p
            })
            .sum::<f64>()
    }

    /// Shannon entropy in nats: `-Σ p_c ln p_c`.
    pub fn entropy(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let t = total as f64;
        -self
            .counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / t;
                p * p.ln()
            })
            .sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_total_merge_minus() {
        let mut h = Histogram::new(3);
        h.add(0, 2);
        h.add(2, 5);
        assert_eq!(h.total(), 7);
        assert_eq!(h.counts(), &[2, 0, 5]);
        let mut h2 = Histogram::new(3);
        h2.add(1, 1);
        h2.merge(&h);
        assert_eq!(h2.counts(), &[2, 1, 5]);
        let d = h2.minus(&h);
        assert_eq!(d.counts(), &[0, 1, 0]);
        assert!(!h.is_zero());
        let mut z = h.clone();
        z.clear();
        assert!(z.is_zero());
    }

    #[test]
    fn gini_known_values() {
        let h = Histogram::from_counts(vec![5, 5]);
        assert!((h.gini() - 0.5).abs() < 1e-12);
        let pure = Histogram::from_counts(vec![10, 0]);
        assert_eq!(pure.gini(), 0.0);
        let empty = Histogram::new(2);
        assert_eq!(empty.gini(), 0.0);
        // 3 classes uniform: 1 - 3*(1/3)^2 = 2/3
        let h3 = Histogram::from_counts(vec![4, 4, 4]);
        assert!((h3.gini() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_known_values() {
        let h = Histogram::from_counts(vec![5, 5]);
        assert!((h.entropy() - std::f64::consts::LN_2).abs() < 1e-12);
        let pure = Histogram::from_counts(vec![10, 0]);
        assert_eq!(pure.entropy(), 0.0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "underflow")]
    fn minus_underflow_asserts() {
        let a = Histogram::from_counts(vec![1]);
        let b = Histogram::from_counts(vec![2]);
        let _ = a.minus(&b);
    }
}
