//! Batched split scoring through the AOT XLA/Pallas artifact.
//!
//! The Pallas kernel (`python/compile/kernels/split_gain.py`) computes,
//! for a batch of `B` scoring *tasks* × `T` candidate thresholds, the
//! binary Gini gain of every threshold plus the per-task argmax. A task
//! is one (leaf, feature) pair: its inputs are the cumulative
//! positive/total weights at each candidate boundary (exactly the
//! prefix state Alg. 1 maintains incrementally).
//!
//! This is the paper's compute hot-spot lifted to the accelerator stack.
//! It is **optional**: the scalar scorer in [`super::numerical`] is the
//! default and the exactness oracle (the kernel computes in f32; ties
//! can fall differently than the f64 scalar path, so XLA scoring is for
//! throughput experiments, not for bit-exact reproduction — see
//! DESIGN.md §5.5).

use super::histogram::Histogram;
use super::scorer::{midpoint, SplitCandidate};
use crate::data::column::SortedEntry;
use crate::runtime::{literal_f32, Executable, XlaRuntime};
use crate::tree::Condition;
use crate::Result;
use std::path::Path;

/// One scoring task: cumulative counts at each candidate boundary.
#[derive(Debug, Clone, Default)]
pub struct ScoreTask {
    /// Cumulative class-1 weight at each boundary (left side of the cut).
    pub pos_prefix: Vec<f32>,
    /// Cumulative total weight at each boundary.
    pub tot_prefix: Vec<f32>,
    /// Parent class-1 weight.
    pub parent_pos: f32,
    /// Parent total weight.
    pub parent_tot: f32,
}

/// Result of one task: best boundary index and its gain.
pub type TaskBest = Option<(usize, f64)>;

/// Anything that can score batches of tasks. Implemented by
/// [`XlaScorer`] (same-thread use) and [`ScorerClient`] (cross-thread
/// use — the PJRT client is `!Send`, so in the threaded runtime a
/// [`ScorerService`] thread owns it and splitters talk to it over a
/// channel, like a device server).
pub trait ScoreTasks {
    fn score_tasks(&self, tasks: &[ScoreTask]) -> Result<Vec<TaskBest>>;
}

/// The loaded split-scorer artifact (fixed `B × T` block shape; callers
/// chunk and pad).
pub struct XlaScorer {
    exe: Executable,
    batch: usize,
    thresholds: usize,
}

impl XlaScorer {
    /// Artifact file name for a block shape.
    pub fn artifact_name(batch: usize, thresholds: usize) -> String {
        format!("split_scorer_{batch}x{thresholds}.hlo.txt")
    }

    /// Load `artifacts/split_scorer_{B}x{T}.hlo.txt` from `dir`.
    pub fn load(rt: &XlaRuntime, dir: &Path, batch: usize, thresholds: usize) -> Result<Self> {
        let path = dir.join(Self::artifact_name(batch, thresholds));
        let exe = rt.load_hlo_file(&path)?;
        Ok(Self {
            exe,
            batch,
            thresholds,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn thresholds(&self) -> usize {
        self.thresholds
    }

    /// Score a slice of tasks (any length; chunked into `B`-sized calls,
    /// each task's boundary list truncated/padded to `T`).
    ///
    /// Tasks with more than `T` boundaries are scored in multiple chunks
    /// and reduced (first-best wins ties, matching `jnp.argmax`).
    pub fn score_tasks(&self, tasks: &[ScoreTask]) -> Result<Vec<TaskBest>> {
        // Expand tasks into (task_idx, boundary_offset) chunks of <= T.
        struct Chunk {
            task: usize,
            offset: usize,
            len: usize,
        }
        let mut chunks = Vec::new();
        for (ti, task) in tasks.iter().enumerate() {
            debug_assert_eq!(task.pos_prefix.len(), task.tot_prefix.len());
            if task.pos_prefix.is_empty() {
                continue;
            }
            let mut off = 0;
            while off < task.pos_prefix.len() {
                let len = (task.pos_prefix.len() - off).min(self.thresholds);
                chunks.push(Chunk {
                    task: ti,
                    offset: off,
                    len,
                });
                off += len;
            }
        }

        let mut best: Vec<TaskBest> = vec![None; tasks.len()];
        let (b, t) = (self.batch, self.thresholds);
        for group in chunks.chunks(b) {
            let mut pos = vec![0f32; b * t];
            let mut tot = vec![0f32; b * t];
            let mut valid = vec![0f32; b * t];
            let mut ppos = vec![0f32; b];
            let mut ptot = vec![1f32; b]; // avoid 0/0 in padding rows
            for (row, ch) in group.iter().enumerate() {
                let task = &tasks[ch.task];
                let src = ch.offset..ch.offset + ch.len;
                pos[row * t..row * t + ch.len].copy_from_slice(&task.pos_prefix[src.clone()]);
                tot[row * t..row * t + ch.len].copy_from_slice(&task.tot_prefix[src]);
                valid[row * t..row * t + ch.len].fill(1.0);
                ppos[row] = task.parent_pos;
                ptot[row] = task.parent_tot.max(1.0);
            }
            let inputs = [
                literal_f32(&pos, &[b as i64, t as i64])?,
                literal_f32(&tot, &[b as i64, t as i64])?,
                literal_f32(&ppos, &[b as i64])?,
                literal_f32(&ptot, &[b as i64])?,
                literal_f32(&valid, &[b as i64, t as i64])?,
            ];
            let outputs = self.exe.execute_tuple(&inputs)?;
            anyhow::ensure!(outputs.len() == 2, "expected (best_gain, best_idx)");
            let gains = outputs[0].to_vec::<f32>()?;
            let idxs = outputs[1].to_vec::<i32>()?;
            for (row, ch) in group.iter().enumerate() {
                let g = gains[row] as f64;
                let idx = idxs[row] as usize;
                if g > 0.0 && idx < ch.len {
                    let global_idx = ch.offset + idx;
                    let cur = &mut best[ch.task];
                    // Strictly-greater: earlier chunks win ties, matching
                    // a single argmax over the concatenation.
                    if cur.map_or(true, |(_, bg)| g > bg) {
                        *cur = Some((global_idx, g));
                    }
                }
            }
        }
        Ok(best)
    }
}

impl ScoreTasks for XlaScorer {
    fn score_tasks(&self, tasks: &[ScoreTask]) -> Result<Vec<TaskBest>> {
        XlaScorer::score_tasks(self, tasks)
    }
}

/// A scoring request travelling to the service thread.
type ScoreRequest = (Vec<ScoreTask>, std::sync::mpsc::Sender<Result<Vec<TaskBest>>>);

/// Dedicated thread owning the PJRT client + compiled artifact.
/// Splitter threads hold [`ScorerClient`]s.
pub struct ScorerService {
    tx: std::sync::mpsc::Sender<ScoreRequest>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ScorerService {
    /// Spawn the service; fails fast if the artifact cannot be loaded.
    pub fn spawn(artifacts_dir: &Path, batch: usize, thresholds: usize) -> Result<Self> {
        let (tx, rx) = std::sync::mpsc::channel::<ScoreRequest>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<std::result::Result<(), String>>();
        let dir = artifacts_dir.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("drf-xla-scorer".into())
            .spawn(move || {
                let scorer = XlaRuntime::cpu()
                    .and_then(|rt| XlaScorer::load(&rt, &dir, batch, thresholds));
                let scorer = match scorer {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok((tasks, reply)) = rx.recv() {
                    let _ = reply.send(scorer.score_tasks(&tasks));
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("scorer service died during startup"))?
            .map_err(|e| anyhow::anyhow!("loading XLA scorer artifact: {e}"))?;
        Ok(Self {
            tx,
            handle: Some(handle),
        })
    }

    /// A cloneable, `Send + Sync` client handle.
    pub fn client(&self) -> ScorerClient {
        ScorerClient {
            tx: std::sync::Mutex::new(self.tx.clone()),
        }
    }
}

impl Drop for ScorerService {
    fn drop(&mut self) {
        // Closing the channel stops the service loop.
        let (tx, _) = std::sync::mpsc::channel();
        self.tx = tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Channel-backed scorer handle (Send + Sync; `mpsc::Sender` is Send but
/// not Sync, hence the mutex).
pub struct ScorerClient {
    tx: std::sync::Mutex<std::sync::mpsc::Sender<ScoreRequest>>,
}

impl ScoreTasks for ScorerClient {
    fn score_tasks(&self, tasks: &[ScoreTask]) -> Result<Vec<TaskBest>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send((tasks.to_vec(), reply_tx))
            .map_err(|_| anyhow::anyhow!("scorer service is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("scorer service dropped the request"))?
    }
}

/// Candidate boundaries of one leaf collected from a presorted scan:
/// the inputs the XLA scorer needs, plus the threshold values.
#[derive(Debug, Clone, Default)]
pub struct LeafBoundaries {
    /// Midpoint thresholds, one per candidate boundary.
    pub thresholds: Vec<f32>,
    /// Cumulative (left-side) class-1 weight at each boundary.
    pub pos_prefix: Vec<f32>,
    /// Cumulative total weight at each boundary.
    pub tot_prefix: Vec<f32>,
    /// Left-side full histograms at each boundary — kept so the winning
    /// boundary can be turned into a `SplitCandidate` with exact counts.
    pub left_hists: Vec<Histogram>,
}

/// Scan a presorted numerical column and materialize, per open leaf, the
/// candidate-boundary arrays (the "wide" form of Alg. 1's incremental
/// state). Shared by the XLA scoring path and its tests.
#[allow(clippy::too_many_arguments)]
pub fn collect_boundaries(
    q: &[SortedEntry],
    labels: &[u32],
    num_classes: u32,
    num_leaves: usize,
    sample2node: impl Fn(u32) -> u32,
    is_candidate: impl Fn(u32) -> bool,
    bag: impl Fn(u32) -> u32,
) -> Vec<LeafBoundaries> {
    struct State {
        hist: Histogram,
        last: Option<f32>,
    }
    let mut states: Vec<State> = (0..num_leaves)
        .map(|_| State {
            hist: Histogram::new(num_classes),
            last: None,
        })
        .collect();
    let mut out: Vec<LeafBoundaries> = vec![LeafBoundaries::default(); num_leaves];

    for e in q {
        let h = sample2node(e.sample);
        if h == 0 || !is_candidate(h) {
            continue;
        }
        let b = bag(e.sample);
        if b == 0 {
            continue;
        }
        let st = &mut states[(h - 1) as usize];
        if let Some(v) = st.last {
            if e.value > v {
                let lb = &mut out[(h - 1) as usize];
                lb.thresholds.push(midpoint(v, e.value));
                lb.pos_prefix
                    .push(st.hist.counts().get(1).copied().unwrap_or(0) as f32);
                lb.tot_prefix.push(st.hist.total() as f32);
                lb.left_hists.push(st.hist.clone());
            }
        }
        st.hist.add(labels[e.sample as usize], b);
        st.last = Some(e.value);
    }
    out
}

/// XLA-accelerated variant of Alg. 1: collect boundaries, score them in
/// batch on the artifact, and assemble `SplitCandidate`s. Binary labels
/// only (the kernel computes binary Gini).
#[allow(clippy::too_many_arguments)]
pub fn best_numerical_supersplit_xla(
    scorer: &dyn ScoreTasks,
    feature: usize,
    q: &[SortedEntry],
    labels: &[u32],
    leaf_totals: &[Histogram],
    sample2node: impl Fn(u32) -> u32,
    is_candidate: impl Fn(u32) -> bool,
    bag: impl Fn(u32) -> u32,
) -> Result<Vec<Option<SplitCandidate>>> {
    let num_leaves = leaf_totals.len();
    let boundaries = collect_boundaries(
        q,
        labels,
        2,
        num_leaves,
        sample2node,
        is_candidate,
        bag,
    );
    let tasks: Vec<ScoreTask> = boundaries
        .iter()
        .zip(leaf_totals)
        .map(|(lb, total)| ScoreTask {
            pos_prefix: lb.pos_prefix.clone(),
            tot_prefix: lb.tot_prefix.clone(),
            parent_pos: total.counts().get(1).copied().unwrap_or(0) as f32,
            parent_tot: total.total() as f32,
        })
        .collect();
    let bests = scorer.score_tasks(&tasks)?;
    Ok(bests
        .into_iter()
        .enumerate()
        .map(|(leaf, best)| {
            let (idx, gain) = best?;
            let lb = &boundaries[leaf];
            let left = lb.left_hists[idx].clone();
            let right = leaf_totals[leaf].minus(&left);
            Some(SplitCandidate {
                condition: Condition::NumLe {
                    feature,
                    threshold: lb.thresholds[idx],
                },
                gain,
                left_counts: left.into_counts(),
                right_counts: right.into_counts(),
            })
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;

    #[test]
    fn collect_boundaries_matches_manual() {
        // values 1,2,2,3 labels 0,1,1,1 — boundaries at 1.5 (between 1
        // and 2) and 2.5 (between 2 and 3).
        let col = Column::Numerical(vec![1.0, 2.0, 2.0, 3.0]);
        let labels = [0u32, 1, 1, 1];
        let out = collect_boundaries(
            &col.presort(),
            &labels,
            2,
            1,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let lb = &out[0];
        assert_eq!(lb.thresholds, vec![1.5, 2.5]);
        assert_eq!(lb.pos_prefix, vec![0.0, 2.0]);
        assert_eq!(lb.tot_prefix, vec![1.0, 3.0]);
        assert_eq!(lb.left_hists[1].counts(), &[1, 2]);
    }

    #[test]
    fn collect_boundaries_respects_closed_and_oob() {
        let col = Column::Numerical(vec![1.0, 2.0, 3.0, 4.0]);
        let labels = [0u32, 1, 0, 1];
        // Sample 1 out of bag; samples routed to leaf 1 except sample 3
        // (closed).
        let out = collect_boundaries(
            &col.presort(),
            &labels,
            2,
            1,
            |i| if i == 3 { 0 } else { 1 },
            |_| true,
            |i| if i == 1 { 0 } else { 1 },
        );
        // Remaining live samples: 0 (v=1) and 2 (v=3) -> one boundary at 2.
        assert_eq!(out[0].thresholds, vec![2.0]);
        assert_eq!(out[0].tot_prefix, vec![1.0]);
    }

    // End-to-end kernel agreement tests live in rust/tests/xla_agreement.rs
    // (they need `make artifacts`).
}
