//! Categorical supersplit search via count tables (paper §2.4, §3.1).
//!
//! SPRINT/SLIQ-style: one sequential pass over the column builds, for
//! every open leaf, the count table `value × class → weighted count`.
//! For **binary** labels the optimal subset `C ⊆ support` is then found
//! exactly with Breiman's trick: sort values by `P(class 1 | value)` and
//! only prefixes of that order need to be considered (Breiman et al.
//! 1984, Thm 4.5). For more than two classes we fall back to the best
//! one-vs-rest single-value split (exhaustive subset search is
//! exponential; the paper's experiments are all binary).
//!
//! Determinism: count tables are kept in `BTreeMap`s and the ratio sort
//! breaks ties by value id, so every worker and the classic baseline
//! produce the same `C`.

use super::histogram::Histogram;
use super::scorer::{split_gain, ScoreKind, SplitCandidate};
use crate::tree::{CategorySet, Condition};
use std::collections::BTreeMap;

/// Per-leaf count-table representation. Two layouts:
///  * dense (flat Vec indexed by value*classes) when the total
///    footprint is modest — no per-row tree walk, ~3x faster;
///  * sparse BTreeMap otherwise (huge arity, sparse support).
/// Both produce identical tables; the per-leaf split search iterates in
/// value order either way, so split decisions are byte-identical
/// (EXPERIMENTS.md §Perf).
enum CountTables {
    Dense { cells: Vec<u64>, stride: usize },
    Sparse { tables: Vec<BTreeMap<u32, Histogram>> },
}

/// Chunk-incremental supersplit scan over one categorical feature.
///
/// Building the `value × class → weighted count` tables is a pure fold
/// over the raw column in row order, so chunks can be fed one at a time
/// ([`push`](Self::push)) with any boundaries — the
/// [`crate::data::store::ColumnStore`] backends stream columns through
/// a bounded buffer this way. [`best_categorical_supersplit`] is the
/// single-slice wrapper.
///
/// Per-sample filtering goes through the same single `gather` closure
/// as [`super::numerical::NumericalSupersplitScan`] (rank 0 = skip;
/// [`crate::splits::fused_gather`] adapts the classic three-predicate
/// form).
pub struct CategoricalSupersplitScan<'a, G>
where
    G: Fn(u32) -> (u32, u32),
{
    feature: usize,
    arity: u32,
    labels: &'a [u32],
    num_classes: u32,
    leaf_totals: &'a [Histogram],
    kind: ScoreKind,
    tables: CountTables,
    gather: G,
}

impl<'a, G> CategoricalSupersplitScan<'a, G>
where
    G: Fn(u32) -> (u32, u32),
{
    /// Interface mirrors [`super::numerical::NumericalSupersplitScan`].
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        feature: usize,
        arity: u32,
        labels: &'a [u32],
        num_classes: u32,
        leaf_totals: &'a [Histogram],
        kind: ScoreKind,
        gather: G,
    ) -> Self {
        let num_leaves = leaf_totals.len();
        let dense_cells = arity as usize * num_classes as usize * num_leaves;
        let tables = if dense_cells <= (1 << 24) {
            CountTables::Dense {
                cells: vec![0u64; dense_cells],
                stride: arity as usize * num_classes as usize,
            }
        } else {
            CountTables::Sparse {
                tables: vec![BTreeMap::new(); num_leaves],
            }
        };
        Self {
            feature,
            arity,
            labels,
            num_classes,
            leaf_totals,
            kind,
            tables,
            gather,
        }
    }

    /// Feed the next chunk of raw values; `base_row` is the row index
    /// of `values[0]`.
    pub fn push(&mut self, base_row: usize, values: &[u32]) {
        for (k, &v) in values.iter().enumerate() {
            let i = (base_row + k) as u32;
            let (h, b) = (self.gather)(i);
            if h == 0 {
                continue; // closed / non-candidate / out-of-bag
            }
            let y = self.labels[i as usize];
            match &mut self.tables {
                CountTables::Dense { cells, stride } => {
                    let base = (h - 1) as usize * *stride
                        + v as usize * self.num_classes as usize
                        + y as usize;
                    cells[base] += b as u64;
                }
                CountTables::Sparse { tables } => {
                    tables[(h - 1) as usize]
                        .entry(v)
                        .or_insert_with(|| Histogram::new(self.num_classes))
                        .add(y, b);
                }
            }
        }
    }

    /// Close the scan: per leaf rank−1, the best candidate split if any.
    pub fn finish(self) -> Vec<Option<SplitCandidate>> {
        let num_leaves = self.leaf_totals.len();
        match self.tables {
            CountTables::Dense { cells, stride } => (0..num_leaves)
                .map(|leaf| {
                    let mut table: BTreeMap<u32, Histogram> = BTreeMap::new();
                    for v in 0..self.arity as usize {
                        let cell = &cells[leaf * stride + v * self.num_classes as usize
                            ..leaf * stride + (v + 1) * self.num_classes as usize];
                        if cell.iter().any(|&c| c > 0) {
                            table.insert(v as u32, Histogram::from_counts(cell.to_vec()));
                        }
                    }
                    best_subset_split(
                        self.feature,
                        self.arity,
                        &table,
                        &self.leaf_totals[leaf],
                        self.num_classes,
                        self.kind,
                    )
                })
                .collect(),
            CountTables::Sparse { tables } => tables
                .into_iter()
                .enumerate()
                .map(|(idx, table)| {
                    best_subset_split(
                        self.feature,
                        self.arity,
                        &table,
                        &self.leaf_totals[idx],
                        self.num_classes,
                        self.kind,
                    )
                })
                .collect(),
        }
    }
}

/// Compute the best `x ∈ C` split of every open leaf for `feature` in
/// one call; `values` is the whole raw column in row order. The
/// single-slice wrapper around [`CategoricalSupersplitScan`] (used by
/// the baselines and in-memory fast paths).
#[allow(clippy::too_many_arguments)]
pub fn best_categorical_supersplit(
    feature: usize,
    values: &[u32],
    arity: u32,
    labels: &[u32],
    num_classes: u32,
    leaf_totals: &[Histogram],
    kind: ScoreKind,
    sample2node: impl Fn(u32) -> u32,
    is_candidate: impl Fn(u32) -> bool,
    bag: impl Fn(u32) -> u32,
) -> Vec<Option<SplitCandidate>> {
    let mut scan = CategoricalSupersplitScan::new(
        feature,
        arity,
        labels,
        num_classes,
        leaf_totals,
        kind,
        crate::splits::fused_gather(sample2node, is_candidate, bag),
    );
    scan.push(0, values);
    scan.finish()
}

/// Best subset split for one leaf given its count table.
fn best_subset_split(
    feature: usize,
    arity: u32,
    table: &BTreeMap<u32, Histogram>,
    total: &Histogram,
    num_classes: u32,
    kind: ScoreKind,
) -> Option<SplitCandidate> {
    if table.len() < 2 {
        return None; // single observed value cannot split
    }
    if num_classes == 2 {
        best_binary_subset(feature, arity, table, total, kind)
    } else {
        best_one_vs_rest(feature, arity, table, total, kind)
    }
}

/// Breiman's exact construction for binary labels: sort observed values
/// by positive ratio, scan prefixes.
fn best_binary_subset(
    feature: usize,
    arity: u32,
    table: &BTreeMap<u32, Histogram>,
    total: &Histogram,
    kind: ScoreKind,
) -> Option<SplitCandidate> {
    let mut entries: Vec<(u32, &Histogram)> = table.iter().map(|(&v, h)| (v, h)).collect();
    // Sort by P(class 1 | value); exact integer cross-multiplication
    // avoids float-ratio ambiguity: p_a < p_b  <=>  pos_a*tot_b < pos_b*tot_a.
    entries.sort_by(|(va, ha), (vb, hb)| {
        let (pa, ta) = (ha.counts()[1] as u128, ha.total() as u128);
        let (pb, tb) = (hb.counts()[1] as u128, hb.total() as u128);
        (pa * tb).cmp(&(pb * ta)).then(va.cmp(vb))
    });

    let mut left = Histogram::new(2);
    let mut best: Option<(f64, usize)> = None;
    // Prefixes 1..len-1 (both sides non-empty).
    for (k, (_, h)) in entries.iter().enumerate().take(entries.len() - 1) {
        left.merge(h);
        if let Some(gain) = split_gain(kind, total, &left) {
            // Strict '>' keeps the shortest prefix among ties
            // (deterministic, mirrors Alg. 1's strict improvement).
            if gain > 0.0 && best.map_or(true, |(bg, _)| gain > bg) {
                best = Some((gain, k + 1));
            }
        }
    }
    let (gain, prefix) = best?;
    let set = CategorySet::from_values(arity, entries[..prefix].iter().map(|(v, _)| *v));
    let mut left = Histogram::new(2);
    for (_, h) in &entries[..prefix] {
        left.merge(h);
    }
    let right = total.minus(&left);
    Some(SplitCandidate {
        condition: Condition::CatIn { feature, set },
        gain,
        left_counts: left.into_counts(),
        right_counts: right.into_counts(),
    })
}

/// Multiclass fallback: best single value vs rest.
fn best_one_vs_rest(
    feature: usize,
    arity: u32,
    table: &BTreeMap<u32, Histogram>,
    total: &Histogram,
    kind: ScoreKind,
) -> Option<SplitCandidate> {
    let mut best: Option<(f64, u32, &Histogram)> = None;
    for (&v, h) in table {
        if let Some(gain) = split_gain(kind, total, h) {
            if gain > 0.0 && best.map_or(true, |(bg, _, _)| gain > bg) {
                best = Some((gain, v, h));
            }
        }
    }
    let (gain, v, left) = best?;
    let right = total.minus(left);
    Some(SplitCandidate {
        condition: Condition::CatIn {
            feature,
            set: CategorySet::from_values(arity, [v]),
        },
        gain,
        left_counts: left.clone().into_counts(),
        right_counts: right.into_counts(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn totals_of(labels: &[u32], weights: &[u32], num_classes: u32) -> Vec<Histogram> {
        let mut h = Histogram::new(num_classes);
        for (&y, &w) in labels.iter().zip(weights) {
            h.add(y, w);
        }
        vec![h]
    }

    fn set_of(c: &SplitCandidate) -> Vec<u32> {
        match &c.condition {
            Condition::CatIn { set, .. } => set.iter().collect(),
            _ => panic!("expected categorical"),
        }
    }

    #[test]
    fn perfectly_separating_subset() {
        // Values 0,1 are class 0; values 2,3 are class 1.
        let values = [0u32, 1, 2, 3, 0, 1, 2, 3];
        let labels = [0u32, 0, 1, 1, 0, 0, 1, 1];
        let w = [1u32; 8];
        let res = best_categorical_supersplit(
            0,
            &values,
            4,
            &labels,
            2,
            &totals_of(&labels, &w, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let c = res[0].as_ref().unwrap();
        assert!((c.gain - 0.5).abs() < 1e-12);
        assert_eq!(set_of(c), vec![0, 1], "the pure-negative values");
        assert_eq!(c.left_counts, vec![4, 0]);
    }

    #[test]
    fn subset_better_than_any_single_value() {
        // Mixed ratios: values {0: 90% pos, 1: 80% pos, 2: 10% pos,
        // 3: 20% pos}. Optimal C groups {2,3} vs {0,1}; any one-vs-rest
        // split is worse.
        let mut values = Vec::new();
        let mut labels = Vec::new();
        for (v, pos, neg) in [(0u32, 9, 1), (1, 8, 2), (2, 1, 9), (3, 2, 8)] {
            for _ in 0..pos {
                values.push(v);
                labels.push(1u32);
            }
            for _ in 0..neg {
                values.push(v);
                labels.push(0u32);
            }
        }
        let w = vec![1u32; values.len()];
        let res = best_categorical_supersplit(
            0,
            &values,
            4,
            &labels,
            2,
            &totals_of(&labels, &w, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let c = res[0].as_ref().unwrap();
        assert_eq!(set_of(c), vec![2, 3]);
    }

    #[test]
    fn single_observed_value_no_split() {
        let values = [5u32; 6];
        let labels = [0u32, 1, 0, 1, 0, 1];
        let w = [1u32; 6];
        let res = best_categorical_supersplit(
            0,
            &values,
            10,
            &labels,
            2,
            &totals_of(&labels, &w, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        assert!(res[0].is_none());
    }

    #[test]
    fn bagging_zero_weight_excluded() {
        // Without bagging value 2 is impure; with sample 4 (the stray
        // positive in value 2) out of bag, the split is perfect.
        let values = [0u32, 0, 2, 2, 2];
        let labels = [1u32, 1, 0, 0, 1];
        let bag = |i: u32| if i == 4 { 0u32 } else { 1 };
        let weights: Vec<u32> = (0..5).map(bag).collect();
        let res = best_categorical_supersplit(
            0,
            &values,
            3,
            &labels,
            2,
            &totals_of(&labels, &weights, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            bag,
        );
        let c = res[0].as_ref().unwrap();
        assert!((c.gain - 0.5).abs() < 1e-12);
        assert_eq!(set_of(c), vec![2]);
    }

    #[test]
    fn per_leaf_tables_independent() {
        // Leaf 1 prefers isolating value 0 (pure negative); leaf 2 sees
        // inverted labels so it prefers isolating value 2.
        let values = [0u32, 1, 1, 2, 0, 1, 1, 2];
        let node = |i: u32| if i < 4 { 1 } else { 2 };
        let labels = [0u32, 1, 0, 1, 1, 0, 1, 0];
        let mut t1 = Histogram::new(2);
        let mut t2 = Histogram::new(2);
        for i in 0..8u32 {
            if i < 4 {
                t1.add(labels[i as usize], 1);
            } else {
                t2.add(labels[i as usize], 1);
            }
        }
        let res = best_categorical_supersplit(
            0,
            &values,
            3,
            &labels,
            2,
            &[t1, t2],
            ScoreKind::Gini,
            node,
            |_| true,
            |_| 1,
        );
        assert!(res[0].is_some());
        assert!(res[1].is_some());
        // Both leaves have one stray, so the two best sets differ.
        assert_ne!(set_of(res[0].as_ref().unwrap()), set_of(res[1].as_ref().unwrap()));
    }

    #[test]
    fn chunked_push_matches_single_slice() {
        let values: Vec<u32> = (0..300).map(|i| ((i * 17) % 6) as u32).collect();
        let labels: Vec<u32> = (0..300).map(|i| ((i * 7) % 2) as u32).collect();
        let w = vec![1u32; 300];
        let totals = totals_of(&labels, &w, 2);
        let whole = best_categorical_supersplit(
            0,
            &values,
            6,
            &labels,
            2,
            &totals,
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        for chunk in [1usize, 13, 128, 299] {
            let mut scan = CategoricalSupersplitScan::new(
                0,
                6,
                &labels,
                2,
                &totals,
                ScoreKind::Gini,
                crate::splits::fused_gather(|_| 1, |_| true, |_| 1),
            );
            let mut base = 0;
            for c in values.chunks(chunk) {
                scan.push(base, c);
                base += c.len();
            }
            let got = scan.finish();
            assert_eq!(
                whole[0].as_ref().map(|c| (set_of(c), c.gain.to_bits())),
                got[0].as_ref().map(|c| (set_of(c), c.gain.to_bits())),
                "chunk={chunk}"
            );
        }
    }

    #[test]
    fn multiclass_one_vs_rest() {
        let values = [0u32, 0, 1, 1, 2, 2];
        let labels = [0u32, 0, 1, 1, 2, 2];
        let w = [1u32; 6];
        let res = best_categorical_supersplit(
            0,
            &values,
            3,
            &labels,
            3,
            &totals_of(&labels, &w, 3),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let c = res[0].as_ref().unwrap();
        assert_eq!(set_of(c).len(), 1, "one-vs-rest");
        assert!(c.gain > 0.0);
    }

    #[test]
    fn high_arity_sparse_support() {
        // Arity 10_000 but only 3 observed values — table stays sparse.
        let values = [9999u32, 5000, 0, 9999, 5000, 0];
        let labels = [1u32, 0, 0, 1, 0, 0];
        let w = [1u32; 6];
        let res = best_categorical_supersplit(
            0,
            &values,
            10_000,
            &labels,
            2,
            &totals_of(&labels, &w, 2),
            ScoreKind::Gini,
            |_| 1,
            |_| true,
            |_| 1,
        );
        let c = res[0].as_ref().unwrap();
        // Parent [4,2] split perfectly: gain = gini([4,2]) = 4/9.
        assert!((c.gain - 4.0 / 9.0).abs() < 1e-12);
        assert_eq!(set_of(c), vec![0, 5000]);
    }
}
