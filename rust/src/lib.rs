//! # drf — Exact Distributed Random Forest
//!
//! A production-quality reproduction of *"Exact Distributed Training:
//! Random Forest with Billions of Examples"* (Guillame-Bert & Teytaud,
//! 2018). DRF trains Random Forests (and other decision-forest models)
//! **exactly** — producing bit-identical models to the classical
//! sequential algorithm — while distributing both the computation and the
//! dataset across workers:
//!
//! * the dataset is partitioned **by column** across *splitter* workers;
//! * each tree is driven depth-level-by-depth-level by a *tree builder*;
//! * a *manager* coordinates tree builders and assembles the forest;
//! * bagging uses a deterministic seeded PRNG so no sample indices are
//!   ever shipped over the network (§2.2 of the paper);
//! * the sample→leaf mapping ("class list") is bit-packed to
//!   `n·⌈log2(ℓ+1)⌉` bits (§2.3);
//! * per depth level, exactly one bit per live sample is broadcast to
//!   update class lists (§2.4, Alg. 2 step 5-7).
//!
//! ## Data plane
//!
//! (See `ARCHITECTURE.md` at the repository root for the four-plane
//! map — data / cluster / serve / bench — and the dataflow of one
//! cluster training round.)
//!
//! All splitter dataset access goes through the
//! [`data::store::ColumnStore`] trait: **chunk-granular sequential
//! scans** (a visitor is fed bounded, ordered slices of a column), the
//! narrowest interface that still covers every scan site — Alg. 1
//! supersplit search, condition evaluation, root statistics, and the
//! SPRINT pruning rebuild. Five backends implement it:
//!
//! * [`data::store::MemStore`] — columns in RAM, zero-copy borrowed
//!   chunks;
//! * [`data::store::DiskStore`] — DRFC v1 files streamed through a
//!   bounded buffer, every byte charged to [`data::io_stats::IoStats`];
//! * [`data::store::DiskV2Store`] — chunked DRFC v2 files (per-chunk
//!   record counts in the header) whose passes can be resumed or
//!   stopped at any chunk boundary;
//! * [`data::mmap::MmapStore`] — the zero-copy scan engine: DRFC files
//!   memory-mapped once (self-declared unix `mmap`/`madvise` FFI, no
//!   extra crates; buffered fallback elsewhere), scans borrow chunk
//!   slices straight from the mapping. Headers and truncation are
//!   validated at open; I/O is charged on the first-touch pass only —
//!   warm re-scans cost zero syscalls and zero copies;
//! * [`data::remote::RemoteStore`] — the object-store backend
//!   (`--storage remote`): every scan becomes **chunk-aligned
//!   byte-range reads** against a [`data::objserve`] `drf objstore`
//!   server, driven by the same v2 chunk table. Complete passes
//!   re-fold the shard manifest's FNV-1a checksums over the fetched
//!   bytes; transient fetch failures retry with bounded backoff and
//!   **resume at the chunk boundary they had reached**; a background
//!   fetcher optionally prefetches range reads. This is the paper's
//!   actual deployment shape — shards on remote storage, streamed to
//!   splitters that never hold a whole column file.
//!
//! The streaming backends (disk reads and remote range reads)
//! optionally run each scan as a **double-buffered prefetch pipeline**
//! (`TrainConfig::prefetch_chunks`): a background reader decodes (or
//! fetches) chunk `N+1` while the visitor consumes chunk `N`; delivery
//! stays strictly in order, so prefetching is deterministic by
//! construction.
//!
//! Because every scan algorithm is a pure left-to-right fold, chunk
//! boundaries — and therefore the backend — cannot change a single
//! split decision: all backends produce bit-identical forests
//! (`tests/storage_backends.rs` asserts the full backend ×
//! `scan_threads` × `prefetch_chunks` matrix, and drills the remote
//! backend through a real objstore process crash + restart). On top of
//! the store, a splitter owning `k` columns scans them concurrently on
//! a scoped pool bounded by `TrainConfig::scan_threads`
//! ([`data::store::run_scans`]); per-column results merge in
//! deterministic column order, so the thread count is a pure
//! wall-clock knob.
//!
//! **Adding a storage backend** is a one-seam job, and the crate now
//! contains two complete worked examples of the recipe —
//! [`data::mmap`] (local, zero-copy) and [`data::remote`] +
//! [`data::objserve`] (remote, streaming). The steps, each pointing at
//! the shipped remote code:
//!
//! 1. implement [`data::store::ColumnStore`]'s `scan_raw`/`scan_sorted`
//!    over your medium — feed ordered chunks, charge
//!    [`data::io_stats::IoStats`] (`RemoteStore::scan_records` shows
//!    the shape, including the optional prefetch pipeline and the
//!    chunk-table-driven resume);
//! 2. validate at open, not mid-scan — parse the DRFC header, check
//!    truncation against the medium's own size
//!    (`data::remote` `fetch_header` / [`data::disk::Header`]);
//! 3. verify integrity against the shard manifest's checksums —
//!    [`cluster::manifest::checksum_bytes`] one-shot for resident
//!    bytes (mmap), [`cluster::manifest::checksum_update`] streaming
//!    for bytes you never hold at once (remote);
//! 4. add a [`config::StorageMode`] variant and wire it in
//!    `Manager::train`'s storage match
//!    ([`coordinator::splitter::remote_storage_for`] is the glue
//!    helper);
//! 5. for cluster deployments, give `cluster::worker` a loader that
//!    builds your store from a [`cluster::ShardManifest`]
//!    ([`cluster::load_shard_remote`] is the worked example) — nothing
//!    above the store changes;
//! 6. extend the `tests/storage_backends.rs` matrix with your backend:
//!    bit-identity across the matrix is the acceptance bar.
//!
//! ## Cluster plane
//!
//! The [`cluster`] subsystem turns the reproduction into a deployable
//! multi-process trainer (shard → worker → leader):
//!
//! * `drf shard` partitions a dataset by the topology ownership map
//!   into per-splitter **shard packs** — presorted DRFC v2 column
//!   files plus a [`cluster::ShardManifest`] carrying the schema,
//!   topology parameters, and per-column checksums — and a
//!   [`cluster::ClusterManifest`] deployment map;
//! * `drf worker --shard DIR --addr A:P` serves one pack over the
//!   splitter wire protocol, loading it through the same
//!   [`data::store::ColumnStore`] backends training uses in-process —
//!   streaming, `--preload`ed zero-copy, or fetched from a
//!   `drf objstore` with `--object-store HOST:PORT`
//!   ([`cluster::load_shard_remote`]: manifest, labels, and every
//!   training scan arrive by range reads, so the worker serves a shard
//!   it never downloaded in full); the leader's Hello handshake
//!   delivers the training configuration and validates protocol
//!   version, shard id, column inventory, and row count;
//! * `drf train --engine cluster --manifest cluster.json` puts a
//!   [`cluster::ClusterPool`] (connect retry/timeout, reconnect on
//!   drop) under the tree builders, wrapped in the generic
//!   [`coordinator::recovery::RecoveringPool`] so a worker killed and
//!   restarted mid-training is rebuilt by replaying the level-update
//!   log. Trees are bit-identical to `--engine direct` by construction
//!   and by end-to-end test (`tests/cluster.rs`).
//!
//! The remote shard source is exactly the promised one-seam change
//! realized: [`data::remote::RemoteStore`] slots in underneath
//! ([`cluster::load_shard_remote`]), and nothing above the store
//! changed — see the data-plane recipe above and `ARCHITECTURE.md`.
//!
//! The numeric hot-spot — scoring all candidate thresholds of a
//! presorted feature against cumulative label histograms (Alg. 1) — is
//! additionally available as an AOT-compiled XLA/Pallas artifact executed
//! through PJRT (see [`runtime`] and [`splits::xla_scorer`]); the exact
//! scalar scorer remains the default and the correctness oracle.
//!
//! Trained forests are **served** by the [`serve`] subsystem: the
//! forest is compiled into a [`serve::FlatForest`] (structure-of-arrays
//! nodes + a shared categorical-bitset arena) and scored with blocked,
//! breadth-first, multi-threaded batch traversal that stays
//! bit-identical to the reference per-row walk. A threaded TCP
//! prediction server ([`serve::PredictionServer`]) exposes `Score`,
//! `Classify`, `ModelInfo`, and hot model `Reload` over a
//! length-prefixed binary protocol.
//!
//! ## Observability
//!
//! The [`telemetry`] subsystem gives every process a metrics plane:
//! a global registry of counters, gauges, and log₂-bucketed histograms
//! ([`telemetry::registry`]), phase-tracing spans (the [`span!`] macro,
//! streamed as JSONL via `--trace-out`), and a `GET /metrics` listener
//! ([`telemetry::MetricsServer`], enabled with `--metrics-addr` on
//! `drf train`/`worker`/`objstore`/`serve`) scraped by
//! `drf metrics ADDR [--watch]`. Instrumentation never feeds back into
//! training, so telemetry-on forests stay bit-identical to
//! telemetry-off runs. The metric catalog is in `docs/observability.md`.
//!
//! ## Fuzzing
//!
//! Every decoder that consumes untrusted bytes — the wire codecs of
//! all three protocols, JSON manifest parsing, DRFC headers — is
//! covered by the in-tree deterministic fuzzer ([`fuzz`]): seeded
//! mutations of encoder-generated corpus frames, run under
//! `catch_unwind` plus a peak-allocation guard, with the invariant
//! *no panic, no over-allocation, graceful `Err` only*. Run it with
//! `drf fuzz --target all --seed 42 --iters 10000`; CI runs the same
//! budget on every push (`fuzz-smoke`). See `docs/fuzzing.md` for the
//! corpus layout and how to reproduce, minimize, and regress a
//! finding.
//!
//! ## Quickstart
//!
//! ```no_run
//! use drf::data::synthetic::{SyntheticSpec, Family};
//! use drf::forest::{RandomForest, ForestParams};
//!
//! let ds = SyntheticSpec::new(Family::Xor { informative: 4 }, 10_000, 8, 42).generate();
//! let params = ForestParams { num_trees: 10, max_depth: 16, ..Default::default() };
//! let forest = RandomForest::train(&ds, &params).unwrap();
//! let auc = drf::metrics::auc(&forest.predict_scores(&ds), ds.labels());
//! println!("train AUC = {auc:.3}");
//! ```
//!
//! ## Serving quickstart
//!
//! Train and save a model, serve it, then score over TCP:
//!
//! ```text
//! drf train --family xor --informative 3 --rows 10000 --features 6 \
//!     --trees 20 --depth 12 --out /tmp/forest.json
//! drf serve --model /tmp/forest.json --addr 127.0.0.1:7878
//! drf predict --addr 127.0.0.1:7878 --family xor --informative 3 \
//!     --rows 5000 --features 6 --seed 99
//! ```
//!
//! or in-process:
//!
//! ```no_run
//! use drf::data::synthetic::{SyntheticSpec, Family};
//! use drf::forest::{RandomForest, ForestParams};
//! use drf::serve::{BatchOptions, FlatForest};
//!
//! let ds = SyntheticSpec::new(Family::Xor { informative: 4 }, 10_000, 8, 42).generate();
//! let forest = RandomForest::train(&ds, &ForestParams::default()).unwrap();
//! let flat = FlatForest::compile(&forest); // compile once…
//! let scores = flat.predict_scores_batch(&ds, &BatchOptions::default()); // …score many times
//! assert_eq!(scores.len(), ds.num_rows());
//! ```

pub mod baselines;
pub mod classlist;
pub mod cluster;
pub mod complexity;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod forest;
pub mod fuzz;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod splits;
pub mod telemetry;
pub mod tree;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
