//! Monte-Carlo model of the per-worker feature load `Z` (§3.2).
//!
//! At each depth, `z` independent subsets of `m'` features are drawn out
//! of `m`; the drawn (distinct) features are assigned to workers — each
//! feature lives on `d` replicas, and the scheduler routes it to the
//! least-loaded replica ("power of d choices"). `Z` is the maximum
//! number of features any single worker must scan. The paper's §3.2
//! results, which this module lets the `z_analysis` bench verify
//! empirically:
//!
//! * `E[m''] = Θ(min(z·m', m))` — no free lunch from collisions;
//! * `E[Z] = O(⌈m''/w⌉)` when `m''` grows faster than `w`;
//! * at `w = m''` without redundancy, `E[Z] = Θ(log m''/log log m'')`;
//! * with `d`-fold redundancy, `E[Z] = O(log log m''/log d)` (+ mean).

use crate::rng::{SplitMix64, Xoshiro256pp};

/// One Monte-Carlo draw configuration.
#[derive(Debug, Clone, Copy)]
pub struct ZConfig {
    /// Total features `m`.
    pub m: usize,
    /// Features drawn per node `m'`.
    pub m_prime: usize,
    /// Independent draws per depth `z` (1 = USB).
    pub z: usize,
    /// Workers `w`.
    pub w: usize,
    /// Replication `d` (1 = none).
    pub d: usize,
}

/// Result of a Monte-Carlo estimate.
#[derive(Debug, Clone, Copy)]
pub struct ZEstimate {
    pub mean_m_double_prime: f64,
    pub mean_z: f64,
    pub max_z: usize,
}

/// Simulate `trials` depth levels and return the mean/max observed `Z`
/// and mean `m''`.
pub fn simulate(cfg: &ZConfig, trials: usize, seed: u64) -> ZEstimate {
    assert!(cfg.m_prime <= cfg.m && cfg.w >= 1 && cfg.d >= 1);
    let mut sum_mpp = 0.0;
    let mut sum_z = 0.0;
    let mut max_z = 0usize;
    for t in 0..trials {
        let mut rng = Xoshiro256pp::new(SplitMix64::hash_key(&[seed, t as u64]));
        // Union of z draws of m' features.
        let mut drawn = vec![false; cfg.m];
        for _ in 0..cfg.z {
            // Partial Fisher-Yates draw of m' distinct features.
            let mut idx: Vec<usize> = (0..cfg.m).collect();
            for i in 0..cfg.m_prime {
                let j = i + rng.next_below((cfg.m - i) as u64) as usize;
                idx.swap(i, j);
                drawn[idx[i]] = true;
            }
        }
        let features: Vec<usize> =
            (0..cfg.m).filter(|&f| drawn[f]).collect();
        sum_mpp += features.len() as f64;

        // Assign each drawn feature to the least-loaded of its d replicas
        // (replicas = deterministic hash of the feature id).
        let mut load = vec![0usize; cfg.w];
        for &f in &features {
            let mut best_worker = usize::MAX;
            let mut best_load = usize::MAX;
            for k in 0..cfg.d.min(cfg.w) {
                let owner =
                    (SplitMix64::hash_key(&[0xF0F0, f as u64, k as u64]) % cfg.w as u64) as usize;
                if load[owner] < best_load {
                    best_load = load[owner];
                    best_worker = owner;
                }
            }
            load[best_worker] += 1;
        }
        let z_this = load.iter().copied().max().unwrap_or(0);
        sum_z += z_this as f64;
        max_z = max_z.max(z_this);
    }
    ZEstimate {
        mean_m_double_prime: sum_mpp / trials as f64,
        mean_z: sum_z / trials as f64,
        max_z,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usb_gives_z_near_one_with_w_equal_m_prime() {
        // z=1, w=m', d>=log(m'): E[Z] = O(1) — the paper's headline.
        let cfg = ZConfig {
            m: 1024,
            m_prime: 32,
            z: 1,
            w: 32,
            d: 5,
        };
        let est = simulate(&cfg, 200, 1);
        assert!((est.mean_m_double_prime - 32.0).abs() < 1e-9);
        assert!(est.mean_z <= 3.0, "E[Z] should be ~1-2, got {}", est.mean_z);
    }

    #[test]
    fn no_redundancy_is_worse_at_balance_point() {
        let base = ZConfig {
            m: 4096,
            m_prime: 64,
            z: 1,
            w: 64,
            d: 1,
        };
        let with_red = ZConfig { d: 4, ..base };
        let e1 = simulate(&base, 100, 2);
        let e2 = simulate(&with_red, 100, 2);
        assert!(
            e1.mean_z > e2.mean_z,
            "redundancy must reduce Z: {} vs {}",
            e1.mean_z,
            e2.mean_z
        );
    }

    #[test]
    fn m_double_prime_saturates() {
        // Huge z: every feature drawn.
        let cfg = ZConfig {
            m: 64,
            m_prime: 8,
            z: 100,
            w: 8,
            d: 1,
        };
        let est = simulate(&cfg, 20, 3);
        assert!(est.mean_m_double_prime > 60.0);
        // And Z ~ m/w.
        assert!(est.mean_z >= 8.0);
    }

    #[test]
    fn z_collisions_match_expectation() {
        // z=2 draws of m' out of m: E[m''] = m(1 - (1 - m'/m)^z) approx.
        let cfg = ZConfig {
            m: 100,
            m_prime: 10,
            z: 2,
            w: 10,
            d: 1,
        };
        let est = simulate(&cfg, 500, 4);
        let expect = 100.0 * (1.0 - (0.9f64).powi(2));
        assert!(
            (est.mean_m_double_prime - expect).abs() < 1.0,
            "E[m''] {} vs {}",
            est.mean_m_double_prime,
            expect
        );
    }
}
