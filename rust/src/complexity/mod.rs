//! Closed-form complexity model (paper Table 1 + §3.2's Z analysis).
pub mod table1;
pub mod zmodel;
