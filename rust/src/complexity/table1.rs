//! Closed-form complexity model — the analytical half of the paper's
//! Table 1. Each algorithm's memory / parallel-time / disk / network
//! cost is expressed as a function of the workload parameters; the
//! `table1_complexity` bench prints these side by side with *measured*
//! counters from the real implementations.

/// Workload parameters (the symbols of §3.2 / Table 1).
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Number of samples `n`.
    pub n: u64,
    /// Number of features `m`.
    pub m: u64,
    /// Candidate features per node `m'` (typically ⌈√m⌉).
    pub m_prime: u64,
    /// Number of distinct candidate sets per depth `z` (open nodes for
    /// classic RF; 1 for USB).
    pub z: u64,
    /// Number of workers `w`.
    pub w: u64,
    /// Feature replication factor `d` (redundant storage).
    pub d: u64,
    /// Effective tree depth `D`.
    pub depth: u64,
    /// Mean leaf depth `D̄` (≤ D).
    pub depth_bar: f64,
    /// Total number of tree nodes `C`.
    pub c_nodes: u64,
    /// Maximum number of open nodes at any depth `M`.
    pub m_nodes: u64,
    /// Bits to store one feature/label value.
    pub bits_value: u64,
    /// Bits to store one record index.
    pub bits_index: u64,
}

impl Workload {
    /// The paper's default storage sizes: f32 values, u32 indices.
    pub fn with_defaults(n: u64, m: u64, w: u64, depth: u64) -> Workload {
        let m_prime = (m as f64).sqrt().ceil() as u64;
        Workload {
            n,
            m,
            m_prime,
            z: 1 << depth.min(20), // worst case: all nodes distinct sets
            w,
            d: 1,
            depth,
            depth_bar: depth as f64,
            c_nodes: (1 << (depth.min(30) + 1)) - 1,
            m_nodes: 1 << depth.min(30),
            bits_value: 32,
            bits_index: 32,
        }
    }

    /// Total drawn features per depth: `m'' = min(z·m', m)` (§3.2: no
    /// hope of doing better — E[m''] = Ω(min(zm', m))).
    pub fn m_double_prime(&self) -> u64 {
        (self.z * self.m_prime).min(self.m)
    }

    /// `K = ⌈m/w⌉`: features per worker with no redundancy.
    pub fn k(&self) -> u64 {
        self.m.div_ceil(self.w)
    }

    /// Expected per-worker feature load `Z` (§3.2): `O(⌈m''/w⌉)` when
    /// m'' ≫ w; `log m''/log log m''` at the balance point w = m''
    /// without redundancy; `log log m''/log d` with d-choice
    /// replication (Azar et al.).
    pub fn z_load(&self) -> f64 {
        let mpp = self.m_double_prime() as f64;
        let w = self.w as f64;
        if mpp >= 2.0 * w {
            (mpp / w).ceil()
        } else if self.d <= 1 {
            // Balls-into-bins maximum load regime.
            let l = mpp.max(2.0).ln();
            let ll = l.max(1.001).ln().max(0.01);
            (mpp / w).max(1.0) * (l / ll).max(1.0)
        } else {
            let ll = mpp.max(2.0).ln().max(1.001).ln().max(0.01);
            (mpp / w).max(1.0) * (ll / (self.d as f64).ln().max(0.01)).max(1.0)
        }
    }

    /// Presort cost per worker (PS): sort K columns of n entries.
    pub fn presort_ops(&self) -> f64 {
        self.k() as f64 * self.n as f64 * (self.n as f64).log2().max(1.0)
    }
}

/// One algorithm's predicted costs (bits / ops / bytes; `passes` are
/// sequential passes over data per worker).
#[derive(Debug, Clone)]
pub struct CostRow {
    pub algorithm: &'static str,
    pub memory_bits_per_worker: f64,
    pub compute_ops_per_worker: f64,
    pub disk_write_bits: f64,
    pub write_passes: f64,
    pub network_bits: f64,
    pub read_bits_per_worker: f64,
    pub read_passes: f64,
}

/// Table 1, row "Generic sequential recursive tree, all in memory".
pub fn generic_in_memory(wl: &Workload) -> CostRow {
    let n = wl.n as f64;
    CostRow {
        algorithm: "generic-in-memory",
        memory_bits_per_worker: (wl.m as f64) * n * wl.bits_value as f64,
        compute_ops_per_worker: wl.m_prime as f64 * n * n.log2().max(1.0) * wl.depth as f64,
        disk_write_bits: 0.0,
        write_passes: 0.0,
        network_bits: 0.0,
        read_bits_per_worker: (wl.m as f64 + 1.0) * n * wl.bits_value as f64,
        read_passes: 1.0,
    }
}

/// Table 1, row "Sliq (on one machine)".
pub fn sliq(wl: &Workload) -> CostRow {
    let n = wl.n as f64;
    let mpp = wl.m_double_prime() as f64;
    CostRow {
        algorithm: "sliq",
        memory_bits_per_worker: n * (wl.bits_value + wl.bits_index) as f64,
        compute_ops_per_worker: mpp * n * wl.depth as f64 + wl.presort_ops(),
        disk_write_bits: 0.0,
        write_passes: 0.0,
        network_bits: 0.0,
        read_bits_per_worker: (mpp + 1.0)
            * n
            * wl.depth as f64
            * (wl.bits_value + wl.bits_index) as f64,
        read_passes: (mpp + 1.0) * wl.depth as f64,
    }
}

/// Table 1, row "Sprint".
pub fn sprint(wl: &Workload) -> CostRow {
    let n = wl.n as f64;
    let k = wl.k() as f64;
    CostRow {
        algorithm: "sprint",
        memory_bits_per_worker: n * wl.bits_index as f64,
        compute_ops_per_worker: k * n * wl.depth_bar + wl.presort_ops(),
        disk_write_bits: k * n * wl.depth_bar * (2 * wl.bits_value + wl.bits_index) as f64,
        write_passes: wl.c_nodes as f64 * k,
        network_bits: (n + wl.depth_bar * n) * wl.bits_index as f64,
        read_bits_per_worker: 2.0
            * k
            * n
            * wl.depth_bar
            * (2 * wl.bits_value + wl.bits_index) as f64,
        read_passes: k * wl.c_nodes as f64,
    }
}

/// Table 1, row "Sliq/D" (class list distributed over workers).
pub fn sliq_d(wl: &Workload) -> CostRow {
    let n = wl.n as f64;
    let mpp = wl.m_double_prime() as f64;
    let d_lvl = wl.depth as f64;
    CostRow {
        algorithm: "sliq/D",
        memory_bits_per_worker: (n / wl.w as f64) * (wl.bits_value + wl.bits_index) as f64,
        compute_ops_per_worker: mpp * (n / wl.w as f64) * d_lvl + wl.presort_ops(),
        disk_write_bits: 0.0,
        write_passes: 0.0,
        // n row indices for bagging + coordination + D broadcasts of Dn bits
        network_bits: n * wl.bits_index as f64 + d_lvl * d_lvl * n,
        read_bits_per_worker: mpp
            * (n / wl.w as f64)
            * d_lvl
            * (wl.bits_value + wl.bits_index) as f64,
        read_passes: mpp * wl.c_nodes as f64,
    }
}

/// Table 1, row "Sliq/R" (class list replicated on every worker).
pub fn sliq_r(wl: &Workload) -> CostRow {
    let n = wl.n as f64;
    let z = wl.z_load();
    let d_lvl = wl.depth as f64;
    CostRow {
        algorithm: "sliq/R",
        memory_bits_per_worker: n * (wl.bits_value + wl.bits_index) as f64,
        compute_ops_per_worker: z * n * d_lvl + wl.presort_ops(),
        disk_write_bits: 0.0,
        write_passes: 0.0,
        network_bits: n * wl.bits_index as f64 + d_lvl * n,
        read_bits_per_worker: z * n * d_lvl * (wl.bits_value + wl.bits_index) as f64,
        read_passes: z * wl.c_nodes as f64,
    }
}

/// Table 1, row "DRF" (this paper).
pub fn drf(wl: &Workload) -> CostRow {
    let n = wl.n as f64;
    let z = wl.z_load();
    let d_lvl = wl.depth as f64;
    let class_list_bits = n * (1.0 + (wl.m_nodes as f64).log2().max(1.0));
    CostRow {
        algorithm: "drf",
        memory_bits_per_worker: class_list_bits,
        compute_ops_per_worker: (z + 1.0) * n * d_lvl + wl.presort_ops(),
        disk_write_bits: 0.0,
        write_passes: 0.0,
        // Seeded bagging: zero index shipping. Dn bits in D allreduce.
        network_bits: d_lvl * n,
        read_bits_per_worker: z
            * n
            * d_lvl
            * (2 * wl.bits_value + wl.bits_index) as f64,
        read_passes: z * d_lvl,
    }
}

/// Table 1, row "DRF-USB, w = m', d = log(m')".
pub fn drf_usb(wl: &Workload) -> CostRow {
    let n = wl.n as f64;
    let d_lvl = wl.depth as f64;
    let class_list_bits = n * (1.0 + (wl.m_nodes as f64).log2().max(1.0));
    CostRow {
        algorithm: "drf-usb",
        memory_bits_per_worker: class_list_bits,
        compute_ops_per_worker: n * d_lvl + wl.presort_ops(),
        disk_write_bits: 0.0,
        write_passes: 0.0,
        network_bits: d_lvl * n,
        read_bits_per_worker: 2.0 * d_lvl * n * (2 * wl.bits_value + wl.bits_index) as f64,
        read_passes: 2.0 * d_lvl,
    }
}

/// All rows in Table 1 order.
pub fn all_rows(wl: &Workload) -> Vec<CostRow> {
    vec![
        generic_in_memory(wl),
        sliq(wl),
        sprint(wl),
        sliq_d(wl),
        sliq_r(wl),
        drf(wl),
        drf_usb(wl),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leo_like_workload() -> Workload {
        // The paper's §5 scale: n = 17.3e9, m = 72, w = 82, depth 20.
        let mut wl = Workload::with_defaults(17_300_000_000, 72, 82, 20);
        wl.z = 400_000; // ~ leaves at depth 20 (Table 2: 435k)
        wl
    }

    #[test]
    fn drf_memory_beats_sliq_variants() {
        let wl = leo_like_workload();
        let drf_mem = drf(&wl).memory_bits_per_worker;
        assert!(drf_mem < sliq_r(&wl).memory_bits_per_worker / 2.0);
        assert!(drf_mem < sliq(&wl).memory_bits_per_worker / 2.0);
        // DRF class list for Leo: ~ n * (1 + log2 M) bits << 64n.
        assert!(drf_mem < wl.n as f64 * 64.0);
    }

    #[test]
    fn drf_network_beats_sprint_and_sliq_d() {
        let wl = leo_like_workload();
        let d = drf(&wl).network_bits;
        assert!(d < sprint(&wl).network_bits, "no index shipping");
        assert!(d < sliq_d(&wl).network_bits);
        // Exactly Dn bits.
        assert_eq!(d, wl.depth as f64 * wl.n as f64);
    }

    #[test]
    fn drf_never_writes_after_presort() {
        let wl = leo_like_workload();
        assert_eq!(drf(&wl).disk_write_bits, 0.0);
        assert!(sprint(&wl).disk_write_bits > 0.0);
    }

    #[test]
    fn usb_reduces_reads() {
        let wl = leo_like_workload();
        assert!(drf_usb(&wl).read_bits_per_worker < drf(&wl).read_bits_per_worker);
        assert!(drf_usb(&wl).read_passes < drf(&wl).read_passes);
    }

    #[test]
    fn m_double_prime_saturates_at_m() {
        let mut wl = Workload::with_defaults(1000, 100, 10, 5);
        wl.z = 1_000_000;
        assert_eq!(wl.m_double_prime(), 100);
        wl.z = 2;
        assert_eq!(wl.m_double_prime(), 20);
    }

    #[test]
    fn z_load_regimes() {
        // Many features per worker: ceil(m''/w).
        let mut wl = Workload::with_defaults(1000, 1000, 10, 5);
        wl.z = 1000;
        assert_eq!(wl.z_load(), 100.0);
        // Balance point w = m'': superconstant but small.
        let mut wl2 = Workload::with_defaults(1000, 64, 64, 5);
        wl2.z = 1; // m'' = 8... make z big enough that m'' = 64
        wl2.z = 64;
        wl2.w = 64;
        let z1 = wl2.z_load();
        assert!(z1 > 1.0 && z1 < 20.0, "log/loglog regime, got {z1}");
        // Redundancy shrinks it.
        wl2.d = 4;
        assert!(wl2.z_load() < z1);
    }
}
