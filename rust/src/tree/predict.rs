//! Tree and forest inference.

use super::{Condition, Tree};
use crate::data::dataset::{Dataset, RowView};

impl Tree {
    /// Walk a row to its leaf; returns the leaf node id.
    pub fn leaf_for(&self, row: &RowView<'_>) -> u32 {
        let mut id = 0u32;
        loop {
            let node = &self.nodes[id as usize];
            match &node.condition {
                None => return id,
                Some(Condition::NumLe { feature, threshold }) => {
                    id = if row.numerical(*feature) <= *threshold {
                        node.left
                    } else {
                        node.right
                    };
                }
                Some(Condition::CatIn { feature, set }) => {
                    id = if set.contains(row.categorical(*feature)) {
                        node.left
                    } else {
                        node.right
                    };
                }
            }
        }
    }

    /// Walk a row only down to `max_depth`, returning the node reached.
    /// Used for the paper's Figure 3: evaluating the AUC of depth-
    /// truncated trees without retraining.
    pub fn node_at_depth(&self, row: &RowView<'_>, max_depth: u32) -> u32 {
        let mut id = 0u32;
        loop {
            let node = &self.nodes[id as usize];
            if node.depth >= max_depth {
                return id;
            }
            match &node.condition {
                None => return id,
                Some(Condition::NumLe { feature, threshold }) => {
                    id = if row.numerical(*feature) <= *threshold {
                        node.left
                    } else {
                        node.right
                    };
                }
                Some(Condition::CatIn { feature, set }) => {
                    id = if set.contains(row.categorical(*feature)) {
                        node.left
                    } else {
                        node.right
                    };
                }
            }
        }
    }

    /// P(class 1) for a row (binary classification score).
    pub fn score(&self, row: &RowView<'_>) -> f64 {
        let leaf = self.leaf_for(row);
        self.nodes[leaf as usize].distribution()[1]
    }

    /// P(class 1) with traversal truncated at `max_depth`.
    pub fn score_at_depth(&self, row: &RowView<'_>, max_depth: u32) -> f64 {
        let node = self.node_at_depth(row, max_depth);
        self.nodes[node as usize].distribution()[1]
    }

    /// Predicted class for a row.
    pub fn predict_class(&self, row: &RowView<'_>) -> u32 {
        let leaf = self.leaf_for(row);
        self.nodes[leaf as usize].majority_class()
    }

    /// Scores for every row of a dataset.
    pub fn predict_scores(&self, ds: &Dataset) -> Vec<f64> {
        (0..ds.num_rows()).map(|i| self.score(&ds.row(i))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::schema::{ColumnSpec, Schema};
    use crate::tree::CategorySet;

    fn toy_ds() -> Dataset {
        let schema = Schema::new(
            vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("c", 4),
            ],
            2,
        );
        Dataset::new(
            schema,
            vec![
                Column::Numerical(vec![0.2, 0.8, 0.4, 0.9]),
                Column::Categorical {
                    values: vec![0, 1, 2, 3],
                    arity: 4,
                },
            ],
            vec![0, 1, 0, 1],
        )
    }

    fn toy_tree() -> Tree {
        // root: x <= 0.5 ? left : (c in {1,3} ? pos-ish : neg)
        let mut t = Tree::new_root(vec![2, 2]);
        t.split_node(
            0,
            Condition::NumLe {
                feature: 0,
                threshold: 0.5,
            },
            0.2,
            vec![2, 0],
            vec![0, 2],
        );
        t.split_node(
            2,
            Condition::CatIn {
                feature: 1,
                set: CategorySet::from_values(4, [1, 3]),
            },
            0.1,
            vec![0, 2],
            vec![0, 0],
        );
        t
    }

    #[test]
    fn traversal_routes_correctly() {
        let ds = toy_ds();
        let t = toy_tree();
        assert_eq!(t.leaf_for(&ds.row(0)), 1); // x=0.2 <= 0.5
        assert_eq!(t.leaf_for(&ds.row(1)), 3); // x=0.8, c=1 in set
        assert_eq!(t.leaf_for(&ds.row(2)), 1);
        assert_eq!(t.leaf_for(&ds.row(3)), 3); // c=3 in set
        assert_eq!(t.predict_class(&ds.row(0)), 0);
        assert_eq!(t.predict_class(&ds.row(1)), 1);
    }

    #[test]
    fn depth_truncated_traversal() {
        let ds = toy_ds();
        let t = toy_tree();
        // Depth 0: everyone at root.
        assert_eq!(t.node_at_depth(&ds.row(1), 0), 0);
        assert_eq!(t.score_at_depth(&ds.row(1), 0), 0.5);
        // Depth 1: row 1 reaches node 2 (internal at depth 1).
        assert_eq!(t.node_at_depth(&ds.row(1), 1), 2);
        // Full depth equals leaf_for.
        assert_eq!(t.node_at_depth(&ds.row(1), 99), t.leaf_for(&ds.row(1)));
    }

    #[test]
    fn batch_scores() {
        let ds = toy_ds();
        let t = toy_tree();
        let scores = t.predict_scores(&ds);
        assert_eq!(scores, vec![0.0, 1.0, 0.0, 1.0]);
    }
}
