//! Tree (de)serialization via the in-tree JSON module.
//!
//! Human-inspectable, diff-able in tests, and the manager uses it to
//! persist fully trained trees ("The manager is responsible for the
//! fully trained trees", §2). The format stores f32 thresholds by their
//! bit pattern so round-trips are exact.

use super::{CategorySet, Condition, Node, Tree, NO_CHILD};
use crate::util::Json;
use crate::Result;
use anyhow::Context;
use std::path::Path;

impl CategorySet {
    fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("arity", Json::from_u64(self.arity() as u64)).set(
            "values",
            Json::Arr(self.iter().map(|v| Json::from_u64(v as u64)).collect()),
        );
        o
    }

    fn from_json(v: &Json) -> Result<CategorySet> {
        let arity = v.get("arity")?.as_u32()?;
        let values: Vec<u32> = v
            .get("values")?
            .as_arr()?
            .iter()
            .map(|x| x.as_u32())
            .collect::<Result<_>>()?;
        Ok(CategorySet::from_values(arity, values))
    }
}

impl Condition {
    fn to_json(&self) -> Json {
        let mut o = Json::object();
        match self {
            Condition::NumLe { feature, threshold } => {
                o.set("kind", Json::Str("num_le".into()))
                    .set("feature", Json::from_usize(*feature))
                    // Bit-exact f32 roundtrip.
                    .set("threshold_bits", Json::from_u64(threshold.to_bits() as u64));
            }
            Condition::CatIn { feature, set } => {
                o.set("kind", Json::Str("cat_in".into()))
                    .set("feature", Json::from_usize(*feature))
                    .set("set", set.to_json());
            }
        }
        o
    }

    fn from_json(v: &Json) -> Result<Condition> {
        match v.get("kind")?.as_str()? {
            "num_le" => Ok(Condition::NumLe {
                feature: v.get("feature")?.as_usize()?,
                threshold: f32::from_bits(v.get("threshold_bits")?.as_u32()?),
            }),
            "cat_in" => Ok(Condition::CatIn {
                feature: v.get("feature")?.as_usize()?,
                set: CategorySet::from_json(v.get("set")?)?,
            }),
            k => anyhow::bail!("unknown condition kind '{k}'"),
        }
    }
}

impl Node {
    fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set(
            "condition",
            match &self.condition {
                None => Json::Null,
                Some(c) => c.to_json(),
            },
        )
        .set("left", Json::from_u64(self.left as u64))
        .set("right", Json::from_u64(self.right as u64))
        .set("depth", Json::from_u64(self.depth as u64))
        .set("class_counts", Json::from_slice_u64(&self.class_counts))
        .set("split_gain", Json::Num(self.split_gain));
        o
    }

    fn from_json(v: &Json) -> Result<Node> {
        Ok(Node {
            condition: match v.get("condition")? {
                Json::Null => None,
                c => Some(Condition::from_json(c)?),
            },
            left: v.get("left")?.as_u32()?,
            right: v.get("right")?.as_u32()?,
            depth: v.get("depth")?.as_u32()?,
            class_counts: v.get("class_counts")?.as_vec_u64()?,
            split_gain: v.get("split_gain")?.as_f64()?,
        })
    }
}

impl Tree {
    /// Serialize to a JSON value.
    pub fn to_json_value(&self) -> Json {
        let mut o = Json::object();
        o.set("num_classes", Json::from_u64(self.num_classes as u64))
            .set(
                "nodes",
                Json::Arr(self.nodes.iter().map(|n| n.to_json()).collect()),
            );
        o
    }

    /// Serialize to a JSON string.
    pub fn to_json(&self) -> Result<String> {
        Ok(self.to_json_value().to_string())
    }

    /// Deserialize from a JSON value.
    pub fn from_json_value(v: &Json) -> Result<Tree> {
        let nodes: Vec<Node> = v
            .get("nodes")?
            .as_arr()?
            .iter()
            .map(Node::from_json)
            .collect::<Result<_>>()?;
        anyhow::ensure!(!nodes.is_empty(), "tree has no nodes");
        // Structural validation: child ids in range, no self-loops.
        for (i, n) in nodes.iter().enumerate() {
            if !n.is_leaf() {
                anyhow::ensure!(
                    n.left != NO_CHILD && n.right != NO_CHILD,
                    "internal node {i} missing children"
                );
                anyhow::ensure!(
                    (n.left as usize) < nodes.len()
                        && (n.right as usize) < nodes.len()
                        && n.left as usize != i
                        && n.right as usize != i,
                    "node {i} has invalid child ids"
                );
            }
        }
        Ok(Tree {
            nodes,
            num_classes: v.get("num_classes")?.as_u32()?,
        })
    }

    /// Deserialize from a JSON string.
    pub fn from_json(s: &str) -> Result<Tree> {
        Self::from_json_value(&Json::parse(s)?)
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json()?)
            .with_context(|| format!("saving tree to {}", path.display()))
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Tree> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("loading tree from {}", path.display()))?;
        Tree::from_json(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut t = Tree::new_root(vec![3, 2]);
        t.split_node(
            0,
            Condition::CatIn {
                feature: 2,
                set: CategorySet::from_values(10, [1, 5, 9]),
            },
            0.33,
            vec![3, 0],
            vec![0, 2],
        );
        t.split_node(
            1,
            Condition::NumLe {
                feature: 0,
                threshold: 0.1f32, // not exactly representable in decimal
            },
            0.125,
            vec![2, 0],
            vec![1, 0],
        );
        let json = t.to_json().unwrap();
        let back = Tree::from_json(&json).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn threshold_bit_exactness() {
        let mut t = Tree::new_root(vec![1, 1]);
        let weird = f32::from_bits(0x3DCCCCCD); // 0.1f32
        t.split_node(
            0,
            Condition::NumLe {
                feature: 0,
                threshold: weird,
            },
            0.0,
            vec![1, 0],
            vec![0, 1],
        );
        let back = Tree::from_json(&t.to_json().unwrap()).unwrap();
        match back.nodes[0].condition.as_ref().unwrap() {
            Condition::NumLe { threshold, .. } => {
                assert_eq!(threshold.to_bits(), weird.to_bits());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("tree.json");
        let t = Tree::new_root(vec![1, 1]);
        t.save(&path).unwrap();
        assert_eq!(Tree::load(&path).unwrap(), t);
    }

    #[test]
    fn corrupt_json_fails_cleanly() {
        assert!(Tree::from_json("{not json").is_err());
        assert!(Tree::from_json("{\"num_classes\": 2, \"nodes\": []}").is_err());
        // Internal node with out-of-range child.
        let bad = r#"{"num_classes":2,"nodes":[{"condition":{"kind":"num_le","feature":0,"threshold_bits":0},"left":5,"right":6,"depth":0,"class_counts":[1,1],"split_gain":0}]}"#;
        assert!(Tree::from_json(bad).is_err());
    }
}
