//! Decision tree structure: nodes, split conditions, and tree metrics.
//!
//! Conditions follow the paper §2.4: numerical columns split on
//! `x ≤ τ` (τ ∈ ℝ), categorical columns split on `x ∈ C` with `C` a
//! subset of the column's support, stored as a bitset.
//!
//! Node ids are assigned in **breadth-first creation order** — the same
//! order in both the distributed builder and the classic baseline — so
//! that deterministic per-node feature sampling (keyed by node id) makes
//! the two algorithms produce *identical* trees. This is the crux of the
//! "exact" claim and is enforced by `tests/exactness.rs`.

pub mod predict;
pub mod serialize;


/// A set of category ids, bit-packed. Categorical split conditions test
/// membership in such a set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CategorySet {
    arity: u32,
    words: Vec<u64>,
}

impl CategorySet {
    pub fn empty(arity: u32) -> Self {
        Self {
            arity,
            words: vec![0u64; (arity as usize).div_ceil(64)],
        }
    }

    pub fn from_values(arity: u32, values: impl IntoIterator<Item = u32>) -> Self {
        let mut s = Self::empty(arity);
        for v in values {
            s.insert(v);
        }
        s
    }

    pub fn arity(&self) -> u32 {
        self.arity
    }

    #[inline]
    pub fn insert(&mut self, v: u32) {
        debug_assert!(v < self.arity);
        self.words[(v / 64) as usize] |= 1u64 << (v % 64);
    }

    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        if v >= self.arity {
            return false;
        }
        (self.words[(v / 64) as usize] >> (v % 64)) & 1 == 1
    }

    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.arity).filter(move |&v| self.contains(v))
    }

    /// The raw bit-packed words (bit `v` of word `v / 64` set ⇔ `v` is a
    /// member). Only bits below `arity` can be set. The serving engine
    /// copies these into its shared bitset arena ([`crate::serve::flat`]).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Wire size in bytes when shipped in a supersplit answer.
    pub fn wire_bytes(&self) -> u64 {
        4 + self.words.len() as u64 * 8
    }
}

/// A split condition; `true` routes the sample to the **left** child.
#[derive(Debug, Clone, PartialEq)]
pub enum Condition {
    /// `x[feature] <= threshold`.
    NumLe { feature: usize, threshold: f32 },
    /// `x[feature] ∈ set`.
    CatIn { feature: usize, set: CategorySet },
}

impl Condition {
    pub fn feature(&self) -> usize {
        match self {
            Condition::NumLe { feature, .. } | Condition::CatIn { feature, .. } => *feature,
        }
    }

    /// Wire size in bytes (for network accounting).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Condition::NumLe { .. } => 4 + 4,
            Condition::CatIn { set, .. } => 4 + set.wire_bytes(),
        }
    }
}

/// Sentinel for "no child".
pub const NO_CHILD: u32 = u32::MAX;

/// One tree node.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// Split condition; `None` for leaves.
    pub condition: Option<Condition>,
    /// Left child id (condition true), or `NO_CHILD`.
    pub left: u32,
    /// Right child id (condition false), or `NO_CHILD`.
    pub right: u32,
    /// Depth (root = 0).
    pub depth: u32,
    /// Bagged (weighted) label histogram of training samples at this node.
    pub class_counts: Vec<u64>,
    /// Gain of the chosen split (0 for leaves); feeds feature importance.
    pub split_gain: f64,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.condition.is_none()
    }

    /// Total bagged weight at this node.
    pub fn total_count(&self) -> u64 {
        self.class_counts.iter().sum()
    }

    /// Majority class (ties to the lower class id, deterministically).
    pub fn majority_class(&self) -> u32 {
        let mut best = 0usize;
        for (c, &n) in self.class_counts.iter().enumerate() {
            if n > self.class_counts[best] {
                best = c;
            }
        }
        best as u32
    }

    /// P(class) estimates (uniform if the node is empty).
    pub fn distribution(&self) -> Vec<f64> {
        let total = self.total_count();
        if total == 0 {
            return vec![1.0 / self.class_counts.len() as f64; self.class_counts.len()];
        }
        self.class_counts
            .iter()
            .map(|&c| c as f64 / total as f64)
            .collect()
    }
}

/// A decision tree. Node 0 is the root; children are appended in
/// breadth-first creation order during training.
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    pub nodes: Vec<Node>,
    pub num_classes: u32,
}

impl Tree {
    /// A tree with a single root leaf holding `class_counts`.
    pub fn new_root(class_counts: Vec<u64>) -> Self {
        let num_classes = class_counts.len() as u32;
        Self {
            nodes: vec![Node {
                condition: None,
                left: NO_CHILD,
                right: NO_CHILD,
                depth: 0,
                class_counts,
                split_gain: 0.0,
            }],
            num_classes,
        }
    }

    /// Split a leaf: attach `condition` and create left/right children
    /// with the given histograms. Returns `(left_id, right_id)`.
    pub fn split_node(
        &mut self,
        node_id: u32,
        condition: Condition,
        gain: f64,
        left_counts: Vec<u64>,
        right_counts: Vec<u64>,
    ) -> (u32, u32) {
        let depth = self.nodes[node_id as usize].depth;
        assert!(
            self.nodes[node_id as usize].is_leaf(),
            "splitting a non-leaf"
        );
        let left = self.nodes.len() as u32;
        let right = left + 1;
        self.nodes.push(Node {
            condition: None,
            left: NO_CHILD,
            right: NO_CHILD,
            depth: depth + 1,
            class_counts: left_counts,
            split_gain: 0.0,
        });
        self.nodes.push(Node {
            condition: None,
            left: NO_CHILD,
            right: NO_CHILD,
            depth: depth + 1,
            class_counts: right_counts,
            split_gain: 0.0,
        });
        let node = &mut self.nodes[node_id as usize];
        node.condition = Some(condition);
        node.left = left;
        node.right = right;
        node.split_gain = gain;
        (left, right)
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Effective depth D: depth of the deepest leaf.
    pub fn depth(&self) -> u32 {
        self.nodes
            .iter()
            .filter(|n| n.is_leaf())
            .map(|n| n.depth)
            .max()
            .unwrap_or(0)
    }

    /// Average leaf depth weighted by bagged sample count (paper's D̄).
    pub fn mean_leaf_depth(&self) -> f64 {
        let (mut num, mut den) = (0.0, 0.0);
        for n in self.nodes.iter().filter(|n| n.is_leaf()) {
            let w = n.total_count() as f64;
            num += n.depth as f64 * w;
            den += w;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }

    /// Node density (paper §5): #leaves / 2^D — how close the tree is to
    /// a dense tree of the same depth.
    pub fn node_density(&self) -> f64 {
        let d = self.depth();
        self.num_leaves() as f64 / 2f64.powi(d as i32)
    }

    /// Sample density (paper §5): fraction of bagged training weight
    /// sitting in leaves at the maximum depth.
    pub fn sample_density(&self) -> f64 {
        let d = self.depth();
        let (mut deep, mut total) = (0u64, 0u64);
        for n in self.nodes.iter().filter(|n| n.is_leaf()) {
            let w = n.total_count();
            total += w;
            if n.depth == d {
                deep += w;
            }
        }
        if total == 0 {
            0.0
        } else {
            deep as f64 / total as f64
        }
    }

    /// Leaf ids in id order.
    pub fn leaf_ids(&self) -> Vec<u32> {
        (0..self.nodes.len() as u32)
            .filter(|&i| self.nodes[i as usize].is_leaf())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn split_cond(f: usize, t: f32) -> Condition {
        Condition::NumLe {
            feature: f,
            threshold: t,
        }
    }

    #[test]
    fn category_set_ops() {
        let mut s = CategorySet::empty(130);
        s.insert(0);
        s.insert(64);
        s.insert(129);
        assert!(s.contains(0) && s.contains(64) && s.contains(129));
        assert!(!s.contains(1) && !s.contains(200));
        assert_eq!(s.len(), 3);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 64, 129]);
        let s2 = CategorySet::from_values(130, [0, 64, 129]);
        assert_eq!(s, s2);
    }

    #[test]
    fn tree_construction_and_metrics() {
        let mut t = Tree::new_root(vec![6, 4]);
        assert_eq!(t.num_leaves(), 1);
        assert_eq!(t.depth(), 0);
        let (l, r) = t.split_node(0, split_cond(0, 0.5), 0.1, vec![5, 1], vec![1, 3]);
        assert_eq!((l, r), (1, 2));
        assert_eq!(t.num_nodes(), 3);
        assert_eq!(t.num_leaves(), 2);
        assert_eq!(t.depth(), 1);
        assert_eq!(t.node_density(), 1.0); // 2 leaves / 2^1
        let (_l2, _r2) = t.split_node(1, split_cond(1, 0.0), 0.05, vec![5, 0], vec![0, 1]);
        assert_eq!(t.num_leaves(), 3);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.node_density(), 3.0 / 4.0);
        // Deep leaves hold 6 of 10 samples.
        assert!((t.sample_density() - 0.6).abs() < 1e-12);
        // D̄ = (2*5 + 2*1 + 1*4)/10 = 1.6
        assert!((t.mean_leaf_depth() - 1.6).abs() < 1e-12);
    }

    #[test]
    fn majority_and_distribution() {
        let n = Node {
            condition: None,
            left: NO_CHILD,
            right: NO_CHILD,
            depth: 0,
            class_counts: vec![2, 5, 3],
            split_gain: 0.0,
        };
        assert_eq!(n.majority_class(), 1);
        let d = n.distribution();
        assert!((d[1] - 0.5).abs() < 1e-12);
        // Tie breaks low.
        let tie = Node {
            class_counts: vec![3, 3],
            ..n.clone()
        };
        assert_eq!(tie.majority_class(), 0);
    }

    #[test]
    #[should_panic(expected = "non-leaf")]
    fn double_split_panics() {
        let mut t = Tree::new_root(vec![1, 1]);
        t.split_node(0, split_cond(0, 0.5), 0.0, vec![1, 0], vec![0, 1]);
        t.split_node(0, split_cond(0, 0.5), 0.0, vec![1, 0], vec![0, 1]);
    }

    #[test]
    fn condition_wire_bytes() {
        assert_eq!(split_cond(3, 1.0).wire_bytes(), 8);
        let c = Condition::CatIn {
            feature: 1,
            set: CategorySet::empty(100),
        };
        assert_eq!(c.wire_bytes(), 4 + 4 + 16);
    }
}
