//! The Random Forest model: training entry point, prediction,
//! serialization, and feature importance.

pub mod gbt;
pub mod importance;
pub mod oob;

use crate::config::TrainConfig;
pub use crate::config::{ForestParams, TopologyParams};
use crate::coordinator::{Manager, TrainReport};
use crate::data::Dataset;
use crate::serve::{BatchOptions, FlatForest};
use crate::tree::Tree;
use crate::Result;
use anyhow::Context;
use std::path::Path;

/// The class that wins a vote histogram: the **highest vote count,
/// ties broken to the lowest class id**. This is the forest's only
/// vote-resolution rule — shared by the reference per-row path and the
/// flattened serving engine so the two can never disagree. (It replaces
/// an opaque `usize::MAX - c` key-packing trick with an explicit,
/// documented comparator.) Returns class 0 for an all-zero (or empty)
/// histogram.
pub fn winning_class(votes: &[u32]) -> u32 {
    let mut best = 0usize;
    for (c, &v) in votes.iter().enumerate().skip(1) {
        // Strictly-greater keeps the earlier (lower) class on ties.
        if v > votes[best] {
            best = c;
        }
    }
    best as u32
}

/// A trained Random Forest.
#[derive(Debug, Clone, PartialEq)]
pub struct RandomForest {
    pub trees: Vec<Tree>,
    pub num_classes: u32,
}

impl RandomForest {
    /// Train with default topology on the in-process distributed runtime.
    pub fn train(ds: &Dataset, params: &ForestParams) -> Result<RandomForest> {
        let cfg = TrainConfig {
            forest: *params,
            ..Default::default()
        };
        Ok(Self::train_with_config(ds, &cfg)?.0)
    }

    /// Train with a full [`TrainConfig`]; also returns the training
    /// report (per-level stats, I/O and network counters).
    pub fn train_with_config(
        ds: &Dataset,
        cfg: &TrainConfig,
    ) -> Result<(RandomForest, TrainReport)> {
        let manager = Manager::new(cfg.clone())?;
        let (trees, report) = manager.train(ds)?;
        Ok((
            RandomForest {
                trees,
                num_classes: ds.num_classes(),
            },
            report,
        ))
    }

    pub fn num_trees(&self) -> usize {
        self.trees.len()
    }

    /// Forest score for one row: mean of tree scores (P(class 1)).
    pub fn score(&self, row: &crate::data::dataset::RowView<'_>) -> f64 {
        if self.trees.is_empty() {
            return 0.5;
        }
        self.trees.iter().map(|t| t.score(row)).sum::<f64>() / self.trees.len() as f64
    }

    /// Forest score with every tree truncated at `max_depth` (paper
    /// Figure 3's per-depth AUC curves, no retraining needed).
    pub fn score_at_depth(
        &self,
        row: &crate::data::dataset::RowView<'_>,
        max_depth: u32,
    ) -> f64 {
        if self.trees.is_empty() {
            return 0.5;
        }
        self.trees
            .iter()
            .map(|t| t.score_at_depth(row, max_depth))
            .sum::<f64>()
            / self.trees.len() as f64
    }

    /// Compile this forest for serving (see [`crate::serve::flat`]).
    pub fn compile(&self) -> FlatForest {
        FlatForest::compile(self)
    }

    /// Scores for every row of a dataset.
    ///
    /// Runs through the flattened serving engine — blocked, breadth-
    /// first, multi-threaded batch traversal — which is bit-identical
    /// to [`Self::predict_scores_reference`]. Compilation is linear in
    /// the model size and paid per call; callers scoring many batches
    /// should [`Self::compile`] once and reuse the [`FlatForest`].
    pub fn predict_scores(&self, ds: &Dataset) -> Vec<f64> {
        self.compile().predict_scores_batch(ds, &BatchOptions::default())
    }

    /// Reference row-at-a-time scores (the correctness oracle for the
    /// serving engine; also the baseline in `benches/serve_throughput`).
    pub fn predict_scores_reference(&self, ds: &Dataset) -> Vec<f64> {
        (0..ds.num_rows()).map(|i| self.score(&ds.row(i))).collect()
    }

    /// Depth-truncated scores for every row.
    pub fn predict_scores_at_depth(&self, ds: &Dataset, max_depth: u32) -> Vec<f64> {
        (0..ds.num_rows())
            .map(|i| self.score_at_depth(&ds.row(i), max_depth))
            .collect()
    }

    /// Majority-vote class predictions (ties to the lowest class id,
    /// see [`winning_class`]), via the flattened batch engine.
    pub fn predict_classes(&self, ds: &Dataset) -> Vec<u32> {
        self.compile().predict_classes_batch(ds, &BatchOptions::default())
    }

    /// Reference row-at-a-time class predictions; same vote-resolution
    /// rule ([`winning_class`]) as the batch path.
    pub fn predict_classes_reference(&self, ds: &Dataset) -> Vec<u32> {
        (0..ds.num_rows())
            .map(|i| {
                let row = ds.row(i);
                let mut votes = vec![0u32; self.num_classes as usize];
                for t in &self.trees {
                    votes[t.predict_class(&row) as usize] += 1;
                }
                winning_class(&votes)
            })
            .collect()
    }

    /// Total node count across trees.
    pub fn num_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.num_nodes()).sum()
    }

    /// Mean leaves per tree (Table 2's "Leaves" column).
    pub fn mean_leaves(&self) -> f64 {
        if self.trees.is_empty() {
            return 0.0;
        }
        self.trees.iter().map(|t| t.num_leaves() as f64).sum::<f64>() / self.trees.len() as f64
    }

    /// Mean node density per tree (Table 2).
    pub fn mean_node_density(&self) -> f64 {
        if self.trees.is_empty() {
            return 0.0;
        }
        self.trees.iter().map(|t| t.node_density()).sum::<f64>() / self.trees.len() as f64
    }

    /// Mean sample density per tree (Table 2).
    pub fn mean_sample_density(&self) -> f64 {
        if self.trees.is_empty() {
            return 0.0;
        }
        self.trees.iter().map(|t| t.sample_density()).sum::<f64>() / self.trees.len() as f64
    }

    pub fn to_json(&self) -> Result<String> {
        let mut o = crate::util::Json::object();
        o.set(
            "num_classes",
            crate::util::Json::from_u64(self.num_classes as u64),
        )
        .set(
            "trees",
            crate::util::Json::Arr(self.trees.iter().map(|t| t.to_json_value()).collect()),
        );
        Ok(o.to_string())
    }

    pub fn from_json(s: &str) -> Result<Self> {
        let v = crate::util::Json::parse(s)?;
        let trees = v
            .get("trees")?
            .as_arr()?
            .iter()
            .map(Tree::from_json_value)
            .collect::<Result<Vec<_>>>()?;
        Ok(RandomForest {
            trees,
            num_classes: v.get("num_classes")?.as_u32()?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json()?)
            .with_context(|| format!("saving forest to {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(
            &std::fs::read_to_string(path)
                .with_context(|| format!("loading forest from {}", path.display()))?,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::metrics::auc;
    use crate::rng::BaggingMode;

    fn params(trees: usize, seed: u64) -> ForestParams {
        ForestParams {
            num_trees: trees,
            max_depth: 8,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn forest_learns_majority() {
        let train = SyntheticSpec::new(Family::Majority { informative: 5 }, 2000, 8, 1).generate();
        let test = SyntheticSpec::new(Family::Majority { informative: 5 }, 1000, 8, 2).generate();
        let f = RandomForest::train(&train, &params(10, 3)).unwrap();
        let a = auc(&f.predict_scores(&test), test.labels());
        assert!(a > 0.9, "forest should learn majority, AUC = {a}");
    }

    #[test]
    fn more_trees_help_on_xor() {
        let train = SyntheticSpec::new(Family::Xor { informative: 3 }, 3000, 6, 1).generate();
        let test = SyntheticSpec::new(Family::Xor { informative: 3 }, 1000, 6, 2).generate();
        let f1 = RandomForest::train(&train, &params(1, 3)).unwrap();
        let f10 = RandomForest::train(&train, &params(10, 3)).unwrap();
        let a1 = auc(&f1.predict_scores(&test), test.labels());
        let a10 = auc(&f10.predict_scores(&test), test.labels());
        assert!(a10 > a1 - 0.02, "more trees should not hurt: {a1} vs {a10}");
        assert!(a10 > 0.75, "10-tree forest should crack 3-XOR, AUC = {a10}");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 500, 4, 1).generate();
        let f1 = RandomForest::train(&ds, &params(3, 7)).unwrap();
        let f2 = RandomForest::train(&ds, &params(3, 7)).unwrap();
        assert_eq!(f1, f2);
        let f3 = RandomForest::train(&ds, &params(3, 8)).unwrap();
        assert_ne!(f1, f3);
    }

    #[test]
    fn json_roundtrip() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 200, 4, 1).generate();
        let f = RandomForest::train(&ds, &params(2, 7)).unwrap();
        let back = RandomForest::from_json(&f.to_json().unwrap()).unwrap();
        assert_eq!(f, back);
    }

    #[test]
    fn depth_truncated_scores_interpolate() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 800, 6, 1).generate();
        let mut p = params(5, 7);
        p.bagging = BaggingMode::Poisson;
        let f = RandomForest::train(&ds, &p).unwrap();
        let full = f.predict_scores(&ds);
        let deep = f.predict_scores_at_depth(&ds, 50);
        assert_eq!(full, deep, "depth beyond tree depth = full scores");
        let shallow = f.predict_scores_at_depth(&ds, 0);
        assert!(shallow.iter().all(|&s| (s - shallow[0]).abs() < 1e-9),
            "depth 0 = root prior for everyone");
    }

    #[test]
    fn winning_class_ties_break_low() {
        assert_eq!(winning_class(&[]), 0);
        assert_eq!(winning_class(&[0, 0, 0]), 0);
        assert_eq!(winning_class(&[1, 3, 2]), 1);
        assert_eq!(winning_class(&[2, 3, 3]), 1, "tie 1-vs-2 goes to 1");
        assert_eq!(winning_class(&[3, 3, 3]), 0, "three-way tie goes to 0");
    }

    #[test]
    fn multiclass_tie_predicts_lowest_class() {
        // Three single-leaf trees voting for classes 2, 1, and 0: a
        // three-way tie that must resolve to class 0, through both the
        // batched fast path and the reference path.
        let forest = RandomForest {
            trees: vec![
                Tree::new_root(vec![0, 0, 5]),
                Tree::new_root(vec![0, 5, 0]),
                Tree::new_root(vec![5, 0, 0]),
            ],
            num_classes: 3,
        };
        let ds = Dataset::new(
            crate::data::schema::Schema::new(
                vec![crate::data::schema::ColumnSpec::numerical("x")],
                3,
            ),
            vec![crate::data::column::Column::Numerical(vec![0.0, 1.0])],
            vec![0, 2],
        );
        assert_eq!(forest.predict_classes(&ds), vec![0, 0]);
        assert_eq!(forest.predict_classes_reference(&ds), vec![0, 0]);
        // Two votes for class 2 beat one for class 1.
        let skewed = RandomForest {
            trees: vec![
                Tree::new_root(vec![0, 0, 5]),
                Tree::new_root(vec![0, 0, 5]),
                Tree::new_root(vec![0, 5, 0]),
            ],
            num_classes: 3,
        };
        assert_eq!(skewed.predict_classes(&ds), vec![2, 2]);
    }

    #[test]
    fn batched_scores_match_reference_bitwise() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 900, 7, 1).generate();
        let f = RandomForest::train(&ds, &params(6, 2)).unwrap();
        let fast = f.predict_scores(&ds);
        let slow = f.predict_scores_reference(&ds);
        assert_eq!(fast.len(), slow.len());
        for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
        }
        assert_eq!(f.predict_classes(&ds), f.predict_classes_reference(&ds));
    }

    #[test]
    fn table2_metric_helpers() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 500, 6, 1).generate();
        let f = RandomForest::train(&ds, &params(3, 7)).unwrap();
        assert!(f.mean_leaves() >= 1.0);
        assert!(f.mean_node_density() > 0.0 && f.mean_node_density() <= 1.0);
        assert!((0.0..=1.0).contains(&f.mean_sample_density()));
        assert!(f.num_nodes() >= f.num_trees());
    }
}
