//! Feature importance (paper goal #5: "distributed computing of feature
//! importance").
//!
//! We compute **mean decrease in impurity** (MDI): each internal node
//! contributes `gain × node_weight` to its split feature, summed over
//! all trees and normalized. In the distributed setting this needs *no
//! extra data passes*: the gains are already part of the supersplit
//! answers the splitters ship, so importance is an O(#nodes) reduction
//! the manager performs over the finished trees — exactly the cost the
//! paper claims.

use super::RandomForest;
use crate::tree::Tree;

/// Per-feature importance scores, normalized to sum to 1 (all-zero if
/// the forest never split).
pub fn mdi_importance(forest: &RandomForest, num_features: usize) -> Vec<f64> {
    let mut imp = vec![0.0f64; num_features];
    for tree in &forest.trees {
        accumulate_tree(tree, &mut imp);
    }
    let total: f64 = imp.iter().sum();
    if total > 0.0 {
        for v in &mut imp {
            *v /= total;
        }
    }
    imp
}

fn accumulate_tree(tree: &Tree, imp: &mut [f64]) {
    for node in &tree.nodes {
        if let Some(cond) = &node.condition {
            let w = node.total_count() as f64;
            imp[cond.feature()] += node.split_gain * w;
        }
    }
}

/// Rank features by importance, descending (ties to lower index).
pub fn rank_features(importance: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..importance.len()).collect();
    idx.sort_by(|&a, &b| {
        importance[b]
            .partial_cmp(&importance[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ForestParams;
    use crate::data::synthetic::{Family, SyntheticSpec};

    #[test]
    fn informative_features_rank_top() {
        // Majority over features 0..2, features 3..7 useless.
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 3000, 8, 1).generate();
        let params = ForestParams {
            num_trees: 10,
            max_depth: 6,
            seed: 4,
            ..Default::default()
        };
        let f = RandomForest::train(&ds, &params).unwrap();
        let imp = mdi_importance(&f, 8);
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let ranks = rank_features(&imp);
        let top3: std::collections::HashSet<usize> = ranks[..3].iter().copied().collect();
        assert_eq!(
            top3,
            [0usize, 1, 2].into_iter().collect(),
            "planted features must rank top, got importance {imp:?}"
        );
    }

    #[test]
    fn untrained_forest_zero_importance() {
        let f = RandomForest {
            trees: vec![],
            num_classes: 2,
        };
        let imp = mdi_importance(&f, 4);
        assert_eq!(imp, vec![0.0; 4]);
        assert_eq!(rank_features(&imp), vec![0, 1, 2, 3]);
    }
}
