//! Gradient Boosted Trees on the DRF substrate (paper §1: "the proposed
//! algorithm can be applied to other DF models, notably Gradient
//! Boosted Trees (Ye et al., 2009)", and §2: "DRF can also be used to
//! train co-dependent sets of trees ... while trees cannot be trained
//! in parallel, the training of each individual tree is still
//! distributed").
//!
//! Binary classification with logistic loss and second-order (Newton)
//! split scoring (see [`crate::splits::regression`]). Trees are
//! regression trees over per-round gradient/hessian pairs; the extra
//! distributed cost relative to RF is one `(g, h)` refresh per sample
//! per round — a `2·f32`-per-sample broadcast, since column-partitioned
//! splitters cannot evaluate the ensemble themselves. The engine below
//! is single-process but charges that broadcast to an [`IoStats`] so
//! the complexity benches can put GBT's network cost next to RF's
//! 1 bit/sample/level.

use crate::data::column::{Column, SortedEntry};
use crate::data::io_stats::IoStats;
use crate::data::Dataset;
use crate::splits::regression::{
    best_categorical_regression, best_regression_split, GradStats, RegSplit,
};
use crate::tree::{CategorySet, Condition};
use crate::Result;

/// GBT hyperparameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GbtParams {
    pub num_rounds: usize,
    pub learning_rate: f64,
    pub max_depth: u32,
    /// L2 regularization on leaf weights (λ).
    pub lambda: f64,
    /// Minimum summed hessian per child.
    pub min_child_hess: f64,
}

impl Default for GbtParams {
    fn default() -> Self {
        Self {
            num_rounds: 50,
            learning_rate: 0.2,
            max_depth: 4,
            lambda: 1.0,
            min_child_hess: 1.0,
        }
    }
}

/// One regression-tree node.
#[derive(Debug, Clone, PartialEq)]
pub struct RegNode {
    pub condition: Option<Condition>,
    pub left: u32,
    pub right: u32,
    /// Leaf weight (logit contribution), meaningful for leaves.
    pub weight: f64,
}

/// A regression tree of the boosted ensemble.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RegTree {
    pub nodes: Vec<RegNode>,
}

impl RegTree {
    pub fn predict(&self, ds: &Dataset, row: usize) -> f64 {
        let mut id = 0usize;
        loop {
            let node = &self.nodes[id];
            match &node.condition {
                None => return node.weight,
                Some(Condition::NumLe { feature, threshold }) => {
                    id = if ds.column(*feature).as_numerical()[row] <= *threshold {
                        node.left as usize
                    } else {
                        node.right as usize
                    };
                }
                Some(Condition::CatIn { feature, set }) => {
                    id = if set.contains(ds.column(*feature).as_categorical()[row]) {
                        node.left as usize
                    } else {
                        node.right as usize
                    };
                }
            }
        }
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.condition.is_none()).count()
    }
}

/// A trained boosted ensemble (binary logistic).
#[derive(Debug, Clone, PartialEq)]
pub struct GbtModel {
    pub trees: Vec<RegTree>,
    pub learning_rate: f64,
    pub base_score: f64,
}

impl GbtModel {
    /// Raw logit for a row.
    pub fn logit(&self, ds: &Dataset, row: usize) -> f64 {
        self.base_score
            + self.learning_rate * self.trees.iter().map(|t| t.predict(ds, row)).sum::<f64>()
    }

    /// P(class 1).
    pub fn score(&self, ds: &Dataset, row: usize) -> f64 {
        1.0 / (1.0 + (-self.logit(ds, row)).exp())
    }

    pub fn predict_scores(&self, ds: &Dataset) -> Vec<f64> {
        (0..ds.num_rows()).map(|i| self.score(ds, i)).collect()
    }

    /// Mean logistic loss on a dataset.
    pub fn logloss(&self, ds: &Dataset) -> f64 {
        let mut sum = 0.0;
        for i in 0..ds.num_rows() {
            let p = self.score(ds, i).clamp(1e-12, 1.0 - 1e-12);
            let y = ds.labels()[i] as f64;
            sum -= y * p.ln() + (1.0 - y) * (1.0 - p).ln();
        }
        sum / ds.num_rows() as f64
    }
}

/// GBT trainer. Presorts numerical columns once (shared across rounds,
/// like DRF's dataset preparation).
pub struct GbtTrainer<'a> {
    ds: &'a Dataset,
    params: GbtParams,
    sorted: Vec<Option<Vec<SortedEntry>>>,
    stats: IoStats,
}

impl<'a> GbtTrainer<'a> {
    pub fn new(ds: &'a Dataset, params: GbtParams) -> Result<Self> {
        anyhow::ensure!(ds.num_classes() == 2, "GBT supports binary labels only");
        anyhow::ensure!(params.num_rounds > 0 && params.learning_rate > 0.0);
        let sorted = (0..ds.num_features())
            .map(|j| match ds.column(j) {
                Column::Numerical(_) => Some(ds.column(j).presort()),
                _ => None,
            })
            .collect();
        Ok(Self {
            ds,
            params,
            sorted,
            stats: IoStats::new(),
        })
    }

    pub fn stats(&self) -> &IoStats {
        &self.stats
    }

    /// Train the ensemble.
    pub fn train(&self) -> Result<GbtModel> {
        let ds = self.ds;
        let n = ds.num_rows();
        let p0 = (ds.class_counts()[1] as f64 / n as f64).clamp(1e-6, 1.0 - 1e-6);
        let base_score = (p0 / (1.0 - p0)).ln();
        let mut logits = vec![base_score; n];
        let mut model = GbtModel {
            trees: Vec::with_capacity(self.params.num_rounds),
            learning_rate: self.params.learning_rate,
            base_score,
        };
        let mut grads = vec![0.0f64; n];
        let mut hess = vec![0.0f64; n];
        for _round in 0..self.params.num_rounds {
            // Gradient refresh — the per-round 2-float-per-sample
            // broadcast in the distributed setting.
            for i in 0..n {
                let p = 1.0 / (1.0 + (-logits[i]).exp());
                grads[i] = p - ds.labels()[i] as f64;
                hess[i] = (p * (1.0 - p)).max(1e-16);
            }
            self.stats.add_broadcast(n as u64 * 8, 1);

            let tree = self.build_tree(&grads, &hess);
            for i in 0..n {
                logits[i] += self.params.learning_rate * tree.predict(ds, i);
            }
            model.trees.push(tree);
        }
        Ok(model)
    }

    /// One regression tree, breadth-first with row partitioning.
    fn build_tree(&self, grads: &[f64], hess: &[f64]) -> RegTree {
        let ds = self.ds;
        let n = ds.num_rows();
        let root_rows: Vec<u32> = (0..n as u32).collect();
        let mut root_stats = GradStats::default();
        for i in 0..n {
            root_stats.add(grads[i], hess[i]);
        }
        let mut tree = RegTree {
            nodes: vec![RegNode {
                condition: None,
                left: u32::MAX,
                right: u32::MAX,
                weight: root_stats.weight(self.params.lambda),
            }],
        };
        let mut open: Vec<(u32, Vec<u32>, GradStats)> = vec![(0, root_rows, root_stats)];
        let mut depth = 0u32;
        while !open.is_empty() && depth < self.params.max_depth {
            let mut next = Vec::new();
            for (node_id, rows, stats) in std::mem::take(&mut open) {
                let Some((cond, split)) = self.best_split(&rows, stats, grads, hess) else {
                    continue;
                };
                let (mut lrows, mut rrows) = (Vec::new(), Vec::new());
                match &cond {
                    Condition::NumLe { feature, threshold } => {
                        let vals = ds.column(*feature).as_numerical();
                        for &i in &rows {
                            if vals[i as usize] <= *threshold {
                                lrows.push(i);
                            } else {
                                rrows.push(i);
                            }
                        }
                    }
                    Condition::CatIn { feature, set } => {
                        let vals = ds.column(*feature).as_categorical();
                        for &i in &rows {
                            if set.contains(vals[i as usize]) {
                                lrows.push(i);
                            } else {
                                rrows.push(i);
                            }
                        }
                    }
                }
                let l = tree.nodes.len() as u32;
                let r = l + 1;
                tree.nodes.push(RegNode {
                    condition: None,
                    left: u32::MAX,
                    right: u32::MAX,
                    weight: split.left.weight(self.params.lambda),
                });
                tree.nodes.push(RegNode {
                    condition: None,
                    left: u32::MAX,
                    right: u32::MAX,
                    weight: split.right.weight(self.params.lambda),
                });
                let node = &mut tree.nodes[node_id as usize];
                node.condition = Some(cond);
                node.left = l;
                node.right = r;
                next.push((l, lrows, split.left));
                next.push((r, rrows, split.right));
            }
            open = next;
            depth += 1;
        }
        tree
    }

    /// Best regression split of a node across all features.
    fn best_split(
        &self,
        rows: &[u32],
        parent: GradStats,
        grads: &[f64],
        hess: &[f64],
    ) -> Option<(Condition, RegSplit)> {
        let ds = self.ds;
        let in_node: std::collections::HashSet<u32> = rows.iter().copied().collect();
        let mut best: Option<(Condition, RegSplit)> = None;
        for j in 0..ds.num_features() {
            let cand: Option<(Condition, RegSplit)> = match ds.column(j) {
                Column::Numerical(_) => {
                    let entries: Vec<SortedEntry> = self.sorted[j]
                        .as_ref()
                        .unwrap()
                        .iter()
                        .filter(|e| in_node.contains(&e.sample))
                        .copied()
                        .collect();
                    self.stats.add_disk_read(entries.len() as u64 * 8);
                    best_regression_split(
                        &entries,
                        grads,
                        hess,
                        parent,
                        self.params.lambda,
                        self.params.min_child_hess,
                    )
                    .map(|s| {
                        (
                            Condition::NumLe {
                                feature: j,
                                threshold: s.threshold,
                            },
                            s,
                        )
                    })
                }
                Column::Categorical { values, arity } => {
                    self.stats.add_disk_read(rows.len() as u64 * 4);
                    best_categorical_regression(
                        rows.iter().map(|&i| {
                            (values[i as usize], grads[i as usize], hess[i as usize])
                        }),
                        parent,
                        self.params.lambda,
                        self.params.min_child_hess,
                    )
                    .map(|s| {
                        (
                            Condition::CatIn {
                                feature: j,
                                set: CategorySet::from_values(*arity, s.values.iter().copied()),
                            },
                            RegSplit {
                                threshold: 0.0,
                                gain: s.gain,
                                left: s.left,
                                right: s.right,
                            },
                        )
                    })
                }
            };
            if let Some((c, s)) = cand {
                let better = match &best {
                    None => true,
                    Some((bc, bs)) => {
                        s.gain > bs.gain || (s.gain == bs.gain && c.feature() < bc.feature())
                    }
                };
                if better {
                    best = Some((c, s));
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
    use crate::metrics::auc;

    #[test]
    fn gbt_fits_xor() {
        // XOR needs interactions: single stumps fail, depth-2 boosting
        // succeeds.
        let train = SyntheticSpec::new(Family::Xor { informative: 2 }, 2000, 4, 1).generate();
        let test = SyntheticSpec::new(Family::Xor { informative: 2 }, 1000, 4, 2).generate();
        let model = GbtTrainer::new(
            &train,
            GbtParams {
                num_rounds: 40,
                max_depth: 3,
                ..Default::default()
            },
        )
        .unwrap()
        .train()
        .unwrap();
        let a = auc(&model.predict_scores(&test), test.labels());
        assert!(a > 0.95, "GBT should crack XOR, AUC {a}");
    }

    #[test]
    fn training_loss_decreases() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 5 }, 1500, 8, 3).generate();
        let short = GbtTrainer::new(
            &ds,
            GbtParams {
                num_rounds: 5,
                ..Default::default()
            },
        )
        .unwrap()
        .train()
        .unwrap();
        let long = GbtTrainer::new(
            &ds,
            GbtParams {
                num_rounds: 40,
                ..Default::default()
            },
        )
        .unwrap()
        .train()
        .unwrap();
        assert!(
            long.logloss(&ds) < short.logloss(&ds),
            "more rounds must reduce training loss: {} vs {}",
            long.logloss(&ds),
            short.logloss(&ds)
        );
    }

    #[test]
    fn gbt_handles_mixed_types() {
        let spec = LeoLikeSpec::new(6000, 4);
        let ds = spec.generate();
        let test = spec.generate_rows(6000, 3000);
        let model = GbtTrainer::new(
            &ds,
            GbtParams {
                num_rounds: 30,
                max_depth: 4,
                ..Default::default()
            },
        )
        .unwrap()
        .train()
        .unwrap();
        let a = auc(&model.predict_scores(&test), test.labels());
        assert!(a > 0.6, "GBT on leo-like mixed data, AUC {a}");
        // Gradient broadcasts accounted: one per round.
        // (net_broadcasts counter comes from the trainer stats.)
    }

    #[test]
    fn rejects_multiclass() {
        let ds = crate::data::Dataset::new(
            crate::data::Schema::new(vec![crate::data::ColumnSpec::numerical("x")], 3),
            vec![crate::data::Column::Numerical(vec![1.0, 2.0, 3.0])],
            vec![0, 1, 2],
        );
        assert!(GbtTrainer::new(&ds, GbtParams::default()).is_err());
    }

    #[test]
    fn gradient_broadcast_accounted() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 500, 4, 1).generate();
        let trainer = GbtTrainer::new(
            &ds,
            GbtParams {
                num_rounds: 7,
                ..Default::default()
            },
        )
        .unwrap();
        let _ = trainer.train().unwrap();
        assert_eq!(trainer.stats().net_broadcasts(), 7);
        assert_eq!(trainer.stats().net_bytes(), 7 * 500 * 8);
    }
}
