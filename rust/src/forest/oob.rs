//! Out-of-bag (OOB) evaluation — a free byproduct of DRF's seeded
//! bagging (§2.2): whether a sample is out-of-bag for a tree is a pure
//! function of `(seed, tree, sample)`, so OOB scores need no stored
//! masks and can be computed by any worker (here: the manager after
//! training).

use super::RandomForest;
use crate::data::Dataset;
use crate::rng::{Bagger, BaggingMode};

/// OOB score per training row: the mean P(class 1) over the trees for
/// which the row was out-of-bag. Rows that are in-bag everywhere get
/// `None`.
pub fn oob_scores(
    forest: &RandomForest,
    ds: &Dataset,
    seed: u64,
    bagging: BaggingMode,
) -> Vec<Option<f64>> {
    let bagger = Bagger::new(seed, bagging);
    (0..ds.num_rows())
        .map(|i| {
            let row = ds.row(i);
            let mut sum = 0.0;
            let mut count = 0u32;
            for (t, tree) in forest.trees.iter().enumerate() {
                if !bagger.in_bag(t as u32, i as u64) {
                    sum += tree.score(&row);
                    count += 1;
                }
            }
            (count > 0).then(|| sum / count as f64)
        })
        .collect()
}

/// OOB AUC over the rows that have at least one OOB tree.
pub fn oob_auc(
    forest: &RandomForest,
    ds: &Dataset,
    seed: u64,
    bagging: BaggingMode,
) -> Option<f64> {
    let scores = oob_scores(forest, ds, seed, bagging);
    let mut s = Vec::new();
    let mut y = Vec::new();
    for (i, sc) in scores.iter().enumerate() {
        if let Some(v) = sc {
            s.push(*v);
            y.push(ds.labels()[i]);
        }
    }
    (!s.is_empty()).then(|| crate::metrics::auc(&s, &y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ForestParams;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::metrics::auc;

    #[test]
    fn oob_estimates_generalization() {
        let train = SyntheticSpec::new(Family::Majority { informative: 5 }, 4000, 10, 1).generate();
        let test = SyntheticSpec::new(Family::Majority { informative: 5 }, 4000, 10, 2).generate();
        let params = ForestParams {
            num_trees: 20,
            max_depth: 10,
            seed: 7,
            ..Default::default()
        };
        let forest = crate::forest::RandomForest::train(&train, &params).unwrap();
        let oob = oob_auc(&forest, &train, params.seed, params.bagging).unwrap();
        let test_auc = auc(&forest.predict_scores(&test), test.labels());
        // OOB tracks held-out performance.
        assert!(
            (oob - test_auc).abs() < 0.06,
            "OOB {oob:.3} should estimate test {test_auc:.3}"
        );
    }

    #[test]
    fn without_bagging_no_oob() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 200, 4, 1).generate();
        let params = ForestParams {
            num_trees: 3,
            bagging: BaggingMode::None,
            seed: 7,
            ..Default::default()
        };
        let forest = crate::forest::RandomForest::train(&ds, &params).unwrap();
        assert!(oob_auc(&forest, &ds, params.seed, BaggingMode::None).is_none());
        assert!(oob_scores(&forest, &ds, params.seed, BaggingMode::None)
            .iter()
            .all(|s| s.is_none()));
    }

    #[test]
    fn oob_fraction_matches_poisson() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 5000, 4, 1).generate();
        let params = ForestParams {
            num_trees: 1,
            seed: 7,
            ..Default::default()
        };
        let forest = crate::forest::RandomForest::train(&ds, &params).unwrap();
        let scores = oob_scores(&forest, &ds, params.seed, params.bagging);
        let frac = scores.iter().filter(|s| s.is_some()).count() as f64 / 5000.0;
        assert!((frac - 0.368).abs() < 0.03, "single-tree OOB fraction {frac}");
    }
}
