//! Blocking TCP client for the prediction protocol.
//!
//! One persistent connection, one in-flight request at a time (matching
//! the RPC semantics of the training-side pools). The client stamps
//! every request with a monotonically increasing id and verifies the
//! server echoes it back.

use super::wire::{
    decode_response, encode_request_traced, read_frame, write_frame, ModelInfo, RowsBatch,
    ServeRequest, ServeResponse,
};
use crate::data::Dataset;
use crate::telemetry::{
    clock_sync_exchange, current_context, record_clock_sync, trace_enabled, TimeSyncReply,
};
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};

/// A connected prediction client.
pub struct PredictClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl PredictClient {
    /// Connect to a running [`super::server::PredictionServer`].
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<PredictClient> {
        let stream = TcpStream::connect(&addr)
            .with_context(|| format!("connecting to prediction server at {addr:?}"))?;
        stream.set_nodelay(true)?;
        let mut client = PredictClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            next_id: 1,
        };
        // When tracing, estimate the server's clock offset on the
        // fresh connection so `drf trace merge` can align timelines.
        if trace_enabled() {
            let peer = clock_sync_exchange(2, || -> Result<TimeSyncReply> {
                match client.call(&ServeRequest::TimeSync)? {
                    ServeResponse::TimeSync(t) => Ok(t),
                    r => bail!("unexpected response {r:?}"),
                }
            })?;
            record_clock_sync(&peer);
        }
        Ok(client)
    }

    fn call(&mut self, req: &ServeRequest) -> Result<ServeResponse> {
        let id = self.next_id;
        self.next_id += 1;
        let ctx = current_context();
        write_frame(&mut self.writer, &encode_request_traced(id, req, ctx.as_ref()))?;
        let frame = read_frame(&mut self.reader).context("reading server response")?;
        let (resp_id, resp) = decode_response(&frame)?;
        if let ServeResponse::Err(msg) = resp {
            bail!("server error: {msg}");
        }
        ensure!(
            resp_id == id,
            "response id {resp_id} does not match request id {id}"
        );
        Ok(resp)
    }

    /// Mean P(class 1) per row of the batch.
    pub fn score(&mut self, batch: RowsBatch) -> Result<Vec<f64>> {
        match self.call(&ServeRequest::Score(batch))? {
            ServeResponse::Scores(s) => Ok(s),
            r => bail!("unexpected response {r:?}"),
        }
    }

    /// Convenience: score a dataset's feature columns.
    pub fn score_dataset(&mut self, ds: &Dataset) -> Result<Vec<f64>> {
        self.score(RowsBatch::from_dataset(ds))
    }

    /// Majority-vote class per row of the batch.
    pub fn classify(&mut self, batch: RowsBatch) -> Result<Vec<u32>> {
        match self.call(&ServeRequest::Classify(batch))? {
            ServeResponse::Classes(c) => Ok(c),
            r => bail!("unexpected response {r:?}"),
        }
    }

    /// Convenience: classify a dataset's feature columns.
    pub fn classify_dataset(&mut self, ds: &Dataset) -> Result<Vec<u32>> {
        self.classify(RowsBatch::from_dataset(ds))
    }

    /// Describe the model the server is currently holding.
    pub fn model_info(&mut self) -> Result<ModelInfo> {
        match self.call(&ServeRequest::ModelInfo)? {
            ServeResponse::Info(i) => Ok(i),
            r => bail!("unexpected response {r:?}"),
        }
    }

    /// Hot-reload the served model from the server's startup path
    /// (`None`). Servers refuse `Some(path)` overrides from the
    /// network. Returns the reloaded model's tree count.
    pub fn reload(&mut self, path: Option<&str>) -> Result<u32> {
        let req = ServeRequest::Reload {
            path: path.map(str::to_string),
        };
        match self.call(&req)? {
            ServeResponse::Reloaded { num_trees } => Ok(num_trees),
            r => bail!("unexpected response {r:?}"),
        }
    }
}
