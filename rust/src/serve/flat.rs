//! `FlatForest` — the forest compiled for inference.
//!
//! Training produces pointer-rich [`Tree`]s: every node owns an
//! `Option<Condition>` (a heap-boxed enum whose categorical arm carries
//! its own `CategorySet` allocation) plus a `class_counts` vector that
//! [`crate::tree::Node::distribution`] re-materializes on every visit.
//! Row-at-a-time traversal therefore chases pointers and allocates on
//! the hot path.
//!
//! `FlatForest` re-lays the same forest out as **structure-of-arrays**
//! node storage so traversal touches only dense, contiguous arrays:
//!
//! * `threshold` — one `f64` per node (f32 thresholds widened; the
//!   widening is exact and order-preserving, so `x as f64 <= τ as f64`
//!   routes bit-identically to the reference `x <= τ` on f32);
//! * `left` / `right` / `feature` — `u32` per node, children stored as
//!   *flat* (forest-global) ids so no per-tree base is added per step;
//! * a shared **categorical-bitset arena**: all `CategorySet` words are
//!   concatenated into one `Vec<u64>` and nodes hold `(offset, nwords)`
//!   — replacing one heap allocation per categorical node;
//! * `leaf_score` / `leaf_major` — leaf outputs precomputed at compile
//!   time, so scoring performs zero allocations per row.
//!
//! Node ids are preserved: flat id = `tree_offsets[t] + node_id`, which
//! is what lets `tests/serving.rs` compare routing against
//! [`Tree::leaf_for`] node-for-node. Exactness is the repo's brand: the
//! compiled engine must route every row to the same leaf and produce
//! bit-identical scores to the reference traversal.

use crate::data::dataset::{Dataset, RowView};
use crate::forest::{winning_class, RandomForest};
use crate::tree::{Condition, Tree};
use crate::Result;
use anyhow::{bail, ensure};

/// Sentinel in `feature[]` marking a leaf node.
const LEAF: u32 = u32::MAX;
/// Sentinel in `cat_offset[]` marking a non-categorical node.
const NOT_CAT: u32 = u32::MAX;

/// How a feature index is used by the compiled forest — drives request
/// validation in the prediction server (a mismatched column type would
/// otherwise panic deep inside traversal).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureKind {
    /// No condition in the forest reads this feature.
    Unused,
    /// Read by `x ≤ τ` conditions: the column must be numerical.
    Numerical,
    /// Read by `x ∈ C` conditions: the column must be categorical.
    Categorical,
    /// Read both ways — only possible in a corrupt/hand-edited model
    /// (training types each column once). No dataset can satisfy it;
    /// [`FlatForest::check_dataset`] always rejects, so servers return
    /// a clean error instead of panicking mid-traversal.
    Conflicting,
}

/// A forest compiled to structure-of-arrays storage for fast inference.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatForest {
    num_classes: u32,
    /// `num_trees + 1` offsets; tree `t` owns flat ids
    /// `tree_offsets[t] .. tree_offsets[t + 1]` and its root is the
    /// first of them.
    tree_offsets: Vec<u32>,
    /// Split feature per node; [`LEAF`] for leaves.
    feature: Vec<u32>,
    /// Numerical threshold per node (f32 widened exactly; 0.0 for
    /// categorical nodes and leaves).
    threshold: Vec<f64>,
    /// Flat id of the condition-true child (undefined for leaves).
    left: Vec<u32>,
    /// Flat id of the condition-false child (undefined for leaves).
    right: Vec<u32>,
    /// Word offset into `cat_arena`; [`NOT_CAT`] for numerical nodes
    /// and leaves.
    cat_offset: Vec<u32>,
    /// Number of arena words backing this node's category set.
    cat_nwords: Vec<u32>,
    /// Shared bitset arena: every categorical node's `CategorySet`
    /// words, concatenated.
    cat_arena: Vec<u64>,
    /// Per node: `distribution()[1]` for leaves (P(class 1), the value
    /// [`Tree::score`] returns), 0.0 for internal nodes.
    leaf_score: Vec<f64>,
    /// Per node: majority class for leaves, 0 for internal nodes.
    leaf_major: Vec<u32>,
    /// Usage kind per feature index (length = highest feature + 1).
    feature_kinds: Vec<FeatureKind>,
}

impl FlatForest {
    /// Compile a trained forest. Linear in the number of nodes.
    pub fn compile(forest: &RandomForest) -> FlatForest {
        Self::from_trees(&forest.trees, forest.num_classes)
    }

    /// Compile a slice of trees (shared by [`Self::compile`] and tests
    /// that build trees directly).
    pub fn from_trees(trees: &[Tree], num_classes: u32) -> FlatForest {
        let total: usize = trees.iter().map(|t| t.nodes.len()).sum();
        let mut f = FlatForest {
            num_classes,
            tree_offsets: Vec::with_capacity(trees.len() + 1),
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
            cat_offset: Vec::with_capacity(total),
            cat_nwords: Vec::with_capacity(total),
            cat_arena: Vec::new(),
            leaf_score: Vec::with_capacity(total),
            leaf_major: Vec::with_capacity(total),
            feature_kinds: Vec::new(),
        };
        let mut offset = 0u32;
        for tree in trees {
            f.tree_offsets.push(offset);
            for node in &tree.nodes {
                match &node.condition {
                    None => {
                        f.feature.push(LEAF);
                        f.threshold.push(0.0);
                        f.left.push(0);
                        f.right.push(0);
                        f.cat_offset.push(NOT_CAT);
                        f.cat_nwords.push(0);
                        // Same arithmetic as the reference traversal
                        // (`distribution()[1]`) so scores stay
                        // bit-identical; 0.0 if the forest is
                        // single-class (the reference would panic on
                        // `score`, which never happens in practice:
                        // schemas require >= 2 classes).
                        let d = node.distribution();
                        f.leaf_score.push(d.get(1).copied().unwrap_or(0.0));
                        f.leaf_major.push(node.majority_class());
                    }
                    Some(Condition::NumLe { feature, threshold }) => {
                        f.note_feature(*feature, FeatureKind::Numerical);
                        f.feature.push(*feature as u32);
                        f.threshold.push(*threshold as f64);
                        f.left.push(offset + node.left);
                        f.right.push(offset + node.right);
                        f.cat_offset.push(NOT_CAT);
                        f.cat_nwords.push(0);
                        f.leaf_score.push(0.0);
                        f.leaf_major.push(0);
                    }
                    Some(Condition::CatIn { feature, set }) => {
                        f.note_feature(*feature, FeatureKind::Categorical);
                        f.feature.push(*feature as u32);
                        f.threshold.push(0.0);
                        f.left.push(offset + node.left);
                        f.right.push(offset + node.right);
                        f.cat_offset.push(f.cat_arena.len() as u32);
                        f.cat_nwords.push(set.words().len() as u32);
                        f.cat_arena.extend_from_slice(set.words());
                        f.leaf_score.push(0.0);
                        f.leaf_major.push(0);
                    }
                }
            }
            offset += tree.nodes.len() as u32;
        }
        f.tree_offsets.push(offset);
        f
    }

    fn note_feature(&mut self, feature: usize, kind: FeatureKind) {
        if self.feature_kinds.len() <= feature {
            self.feature_kinds.resize(feature + 1, FeatureKind::Unused);
        }
        // Training types each column once, but a hand-edited model can
        // split one feature both ways — record the conflict so
        // `check_dataset` rejects it instead of traversal panicking.
        let slot = &mut self.feature_kinds[feature];
        *slot = match *slot {
            FeatureKind::Unused => kind,
            prev if prev == kind => prev,
            _ => FeatureKind::Conflicting,
        };
    }

    pub fn num_trees(&self) -> usize {
        self.tree_offsets.len() - 1
    }

    pub fn num_nodes(&self) -> usize {
        self.feature.len()
    }

    pub fn num_classes(&self) -> u32 {
        self.num_classes
    }

    /// Bytes of the compiled representation (node arrays + arena).
    pub fn nbytes(&self) -> usize {
        self.feature.len() * (4 + 8 + 4 + 4 + 4 + 4 + 8 + 4)
            + self.cat_arena.len() * 8
            + self.tree_offsets.len() * 4
    }

    /// How each feature index is used ([`FeatureKind::Unused`] entries
    /// included); the length is the minimum feature count a dataset
    /// must provide.
    pub fn feature_kinds(&self) -> &[FeatureKind] {
        &self.feature_kinds
    }

    /// Check that `ds` can be scored: enough columns, and every column
    /// the forest reads has the type its conditions expect.
    pub fn check_dataset(&self, ds: &Dataset) -> Result<()> {
        ensure!(
            ds.num_features() >= self.feature_kinds.len(),
            "dataset has {} feature columns but the model reads feature {}",
            ds.num_features(),
            self.feature_kinds.len() - 1,
        );
        for (j, kind) in self.feature_kinds.iter().enumerate() {
            let ctype = &ds.schema().columns[j].ctype;
            match kind {
                FeatureKind::Unused => {}
                FeatureKind::Numerical if ctype.is_numerical() => {}
                FeatureKind::Categorical if ctype.is_categorical() => {}
                FeatureKind::Numerical => {
                    bail!("model splits feature {j} numerically but column {j} is categorical")
                }
                FeatureKind::Categorical => {
                    bail!("model tests feature {j} by category but column {j} is numerical")
                }
                FeatureKind::Conflicting => {
                    bail!(
                        "model splits feature {j} both numerically and by category \
                         (corrupt model); no dataset can satisfy it"
                    )
                }
            }
        }
        Ok(())
    }

    /// Flat id of the root of tree `t`.
    #[inline]
    pub fn root_of(&self, tree: usize) -> u32 {
        self.tree_offsets[tree]
    }

    /// Whether flat node `id` is a leaf.
    #[inline]
    pub fn is_leaf(&self, id: u32) -> bool {
        self.feature[id as usize] == LEAF
    }

    /// P(class 1) stored at flat leaf `id`.
    #[inline]
    pub fn leaf_score(&self, id: u32) -> f64 {
        self.leaf_score[id as usize]
    }

    /// Majority class stored at flat leaf `id`.
    #[inline]
    pub fn leaf_major(&self, id: u32) -> u32 {
        self.leaf_major[id as usize]
    }

    /// Advance one step from internal node `id` for the row whose
    /// feature values are read through `num` / `cat`. Returns the flat
    /// child id.
    #[inline(always)]
    pub(crate) fn step(
        &self,
        id: u32,
        num: impl Fn(usize) -> f32,
        cat: impl Fn(usize) -> u32,
    ) -> u32 {
        let i = id as usize;
        let f = self.feature[i] as usize;
        let go_left = if self.cat_offset[i] == NOT_CAT {
            // Exact: f32 → f64 widening is lossless and monotone, and
            // NaN is incomparable on both sides, so this routes
            // identically to the reference f32 compare.
            (num(f) as f64) <= self.threshold[i]
        } else {
            let v = cat(f);
            let w = (v >> 6) as usize;
            // Stored sets never contain bits >= arity, so the word
            // bound check alone reproduces `CategorySet::contains`
            // (out-of-range values fall in missing or zero words).
            w < self.cat_nwords[i] as usize
                && (self.cat_arena[self.cat_offset[i] as usize + w] >> (v & 63)) & 1 == 1
        };
        if go_left {
            self.left[i]
        } else {
            self.right[i]
        }
    }

    /// Walk one row down tree `t`; returns the **tree-local** leaf node
    /// id (directly comparable with [`Tree::leaf_for`]).
    pub fn leaf_for(&self, tree: usize, row: &RowView<'_>) -> u32 {
        let mut id = self.root_of(tree);
        while !self.is_leaf(id) {
            id = self.step(id, |f| row.numerical(f), |f| row.categorical(f));
        }
        id - self.tree_offsets[tree]
    }

    /// Forest score for one row: mean of per-tree P(class 1), summed in
    /// tree order — bit-identical to [`RandomForest::score`].
    pub fn score(&self, row: &RowView<'_>) -> f64 {
        if self.num_trees() == 0 {
            return 0.5;
        }
        let mut sum = 0.0;
        for t in 0..self.num_trees() {
            let mut id = self.root_of(t);
            while !self.is_leaf(id) {
                id = self.step(id, |f| row.numerical(f), |f| row.categorical(f));
            }
            sum += self.leaf_score[id as usize];
        }
        sum / self.num_trees() as f64
    }

    /// Majority-vote class for one row (ties to the lowest class id,
    /// see [`winning_class`]).
    pub fn predict_class(&self, row: &RowView<'_>) -> u32 {
        let mut votes = vec![0u32; self.num_classes as usize];
        for t in 0..self.num_trees() {
            let mut id = self.root_of(t);
            while !self.is_leaf(id) {
                id = self.step(id, |f| row.numerical(f), |f| row.categorical(f));
            }
            votes[self.leaf_major[id as usize] as usize] += 1;
        }
        winning_class(&votes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Column;
    use crate::data::schema::{ColumnSpec, Schema};
    use crate::tree::CategorySet;

    fn mixed_ds() -> Dataset {
        let schema = Schema::new(
            vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("c", 130),
            ],
            2,
        );
        Dataset::new(
            schema,
            vec![
                Column::Numerical(vec![0.2, 0.8, 0.4, 0.9, f32::NAN]),
                Column::Categorical {
                    values: vec![0, 64, 2, 129, 1],
                    arity: 130,
                },
            ],
            vec![0, 1, 0, 1, 0],
        )
    }

    fn mixed_tree() -> Tree {
        let mut t = Tree::new_root(vec![3, 2]);
        t.split_node(
            0,
            Condition::NumLe {
                feature: 0,
                threshold: 0.5,
            },
            0.2,
            vec![2, 0],
            vec![1, 2],
        );
        // Multi-word category set exercises the arena.
        t.split_node(
            2,
            Condition::CatIn {
                feature: 1,
                set: CategorySet::from_values(130, [64, 129]),
            },
            0.1,
            vec![0, 2],
            vec![1, 0],
        );
        t
    }

    #[test]
    fn routing_matches_reference_on_mixed_tree() {
        let ds = mixed_ds();
        let tree = mixed_tree();
        let flat = FlatForest::from_trees(std::slice::from_ref(&tree), 2);
        assert_eq!(flat.num_trees(), 1);
        assert_eq!(flat.num_nodes(), tree.num_nodes());
        for i in 0..ds.num_rows() {
            let row = ds.row(i);
            assert_eq!(
                flat.leaf_for(0, &row),
                tree.leaf_for(&row),
                "row {i} routed differently"
            );
        }
        // NaN goes right at the numerical root (x <= τ is false), same
        // as the reference.
        assert_ne!(flat.leaf_for(0, &ds.row(4)), 1);
    }

    #[test]
    fn scores_are_bit_identical_to_reference() {
        let ds = mixed_ds();
        let tree = mixed_tree();
        let flat = FlatForest::from_trees(std::slice::from_ref(&tree), 2);
        for i in 0..ds.num_rows() {
            let row = ds.row(i);
            assert_eq!(flat.score(&row).to_bits(), tree.score(&row).to_bits());
        }
    }

    #[test]
    fn empty_forest_scores_half() {
        let flat = FlatForest::from_trees(&[], 2);
        let ds = mixed_ds();
        assert_eq!(flat.score(&ds.row(0)), 0.5);
        assert_eq!(flat.predict_class(&ds.row(0)), 0);
    }

    #[test]
    fn arena_is_shared_and_offsets_preserved() {
        let t1 = mixed_tree();
        let t2 = mixed_tree();
        let flat = FlatForest::from_trees(&[t1.clone(), t2], 2);
        assert_eq!(flat.num_trees(), 2);
        assert_eq!(flat.root_of(1), t1.num_nodes() as u32);
        // Two categorical nodes × ceil(130 / 64) words each.
        assert_eq!(flat.cat_arena.len(), 2 * 3);
        assert!(flat.nbytes() > 0);
    }

    #[test]
    fn feature_kinds_and_dataset_check() {
        let flat = FlatForest::from_trees(&[mixed_tree()], 2);
        assert_eq!(
            flat.feature_kinds(),
            &[FeatureKind::Numerical, FeatureKind::Categorical]
        );
        let ds = mixed_ds();
        assert!(flat.check_dataset(&ds).is_ok());
        // Swap column types: both reads are now mistyped.
        let bad = Dataset::new(
            Schema::new(
                vec![
                    ColumnSpec::categorical("x", 4),
                    ColumnSpec::numerical("c"),
                ],
                2,
            ),
            vec![
                Column::Categorical {
                    values: vec![0],
                    arity: 4,
                },
                Column::Numerical(vec![1.0]),
            ],
            vec![0],
        );
        assert!(flat.check_dataset(&bad).is_err());
        // Too few columns.
        let narrow = Dataset::new(
            Schema::all_numerical(1),
            vec![Column::Numerical(vec![1.0])],
            vec![0],
        );
        assert!(flat.check_dataset(&narrow).is_err());
    }

    #[test]
    fn conflicting_feature_use_is_rejected_cleanly() {
        // A corrupt/hand-edited model splitting feature 0 numerically
        // in one tree and categorically in another: compiles, but no
        // dataset passes check_dataset (this is what keeps the server
        // from panicking mid-traversal on such a model).
        let mut num_tree = Tree::new_root(vec![1, 1]);
        num_tree.split_node(
            0,
            Condition::NumLe {
                feature: 0,
                threshold: 0.5,
            },
            0.0,
            vec![1, 0],
            vec![0, 1],
        );
        let mut cat_tree = Tree::new_root(vec![1, 1]);
        cat_tree.split_node(
            0,
            Condition::CatIn {
                feature: 0,
                set: CategorySet::from_values(4, [1]),
            },
            0.0,
            vec![1, 0],
            vec![0, 1],
        );
        let flat = FlatForest::from_trees(&[num_tree, cat_tree], 2);
        assert_eq!(flat.feature_kinds(), &[FeatureKind::Conflicting]);
        let numerical = Dataset::new(
            Schema::all_numerical(1),
            vec![Column::Numerical(vec![0.1])],
            vec![0],
        );
        let err = flat.check_dataset(&numerical).unwrap_err();
        assert!(format!("{err}").contains("both numerically and by category"));
    }
}
