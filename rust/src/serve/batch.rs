//! Blocked, breadth-first batch prediction over a [`FlatForest`].
//!
//! Row-at-a-time inference walks one row through all trees, touching a
//! cold node path per tree per row. Following the cache discipline of
//! breadth-first/depth-next traversal (arXiv 1910.06853), the batch
//! engine instead carries a **block** of rows through the forest
//! together: per block it keeps an active-node cursor per row and
//! advances every still-active row one level at a time, so the hot top
//! levels of each tree — and the block's column values — stay resident
//! in cache while they are reused.
//!
//! Blocks are independent, so they fan out across `std::thread` scoped
//! workers (the crate builds offline; no rayon) pulling block indices
//! from a shared queue. **Within** a block, trees are visited strictly
//! in forest order: the per-row score accumulation then performs the
//! exact same f64 additions, in the same order, as the reference
//! [`crate::forest::RandomForest::score`], keeping batched scores
//! bit-identical to the row-at-a-time path — exactness is the brand,
//! even in serving.

use super::flat::FlatForest;
use crate::data::Dataset;
use crate::forest::winning_class;
use std::sync::Mutex;

/// Tuning knobs for batched prediction.
#[derive(Debug, Clone, Copy)]
pub struct BatchOptions {
    /// Rows per block. The per-block working set (cursor + scores) is
    /// a few KiB at the default, sized to stay L1/L2-resident next to
    /// the forest's top levels.
    pub block_rows: usize,
    /// Worker threads; `0` = one per available core (capped at the
    /// number of blocks).
    pub threads: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            block_rows: 512,
            threads: 0,
        }
    }
}

impl BatchOptions {
    /// Single-threaded with the default block size (used by benches to
    /// isolate the layout win from the threading win).
    pub fn single_thread() -> Self {
        Self {
            threads: 1,
            ..Self::default()
        }
    }

    fn resolve_threads(&self, num_blocks: usize) -> usize {
        let t = if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        t.max(1).min(num_blocks.max(1))
    }
}

impl FlatForest {
    /// Mean P(class 1) for every row — the batched fast path behind
    /// [`crate::forest::RandomForest::predict_scores`]. Bit-identical
    /// to scoring each row with [`FlatForest::score`] (and hence to the
    /// reference traversal), at any thread count.
    pub fn predict_scores_batch(&self, ds: &Dataset, opts: &BatchOptions) -> Vec<f64> {
        let mut scores = vec![0.0f64; ds.num_rows()];
        let block = opts.block_rows.max(1);
        run_blocks(opts, &mut scores, block, |bi, out| {
            self.score_block(ds, bi * block, out)
        });
        scores
    }

    /// Majority-vote class for every row (ties to the lowest class id)
    /// — the batched fast path behind
    /// [`crate::forest::RandomForest::predict_classes`].
    pub fn predict_classes_batch(&self, ds: &Dataset, opts: &BatchOptions) -> Vec<u32> {
        let mut classes = vec![0u32; ds.num_rows()];
        let block = opts.block_rows.max(1);
        run_blocks(opts, &mut classes, block, |bi, out| {
            self.classify_block(ds, bi * block, out)
        });
        classes
    }

    /// Advance every still-active cursor of a block one level down its
    /// current tree. Returns whether any row is still at an internal
    /// node.
    #[inline]
    fn advance_level(&self, ds: &Dataset, start: usize, cur: &mut [u32]) -> bool {
        let mut active = false;
        for (i, c) in cur.iter_mut().enumerate() {
            if !self.is_leaf(*c) {
                let row = start + i;
                *c = self.step(
                    *c,
                    |f| ds.column(f).as_numerical()[row],
                    |f| ds.column(f).as_categorical()[row],
                );
                active = !self.is_leaf(*c) || active;
            }
        }
        active
    }

    /// Score one block of rows: `out[i]` = forest score of row
    /// `start + i`.
    fn score_block(&self, ds: &Dataset, start: usize, out: &mut [f64]) {
        let num_trees = self.num_trees();
        if num_trees == 0 {
            out.fill(0.5); // same prior as the reference empty-forest score
            return;
        }
        out.fill(0.0);
        let mut cur = vec![0u32; out.len()];
        for t in 0..num_trees {
            cur.fill(self.root_of(t));
            while self.advance_level(ds, start, &mut cur) {}
            for (o, &c) in out.iter_mut().zip(cur.iter()) {
                *o += self.leaf_score(c);
            }
        }
        for o in out.iter_mut() {
            *o /= num_trees as f64;
        }
    }

    /// Classify one block of rows: `out[i]` = majority-vote class of
    /// row `start + i`.
    fn classify_block(&self, ds: &Dataset, start: usize, out: &mut [u32]) {
        let k = self.num_classes() as usize;
        let n = out.len();
        let mut votes = vec![0u32; n * k];
        let mut cur = vec![0u32; n];
        for t in 0..self.num_trees() {
            cur.fill(self.root_of(t));
            while self.advance_level(ds, start, &mut cur) {}
            for (i, &c) in cur.iter().enumerate() {
                votes[i * k + self.leaf_major(c) as usize] += 1;
            }
        }
        for (i, o) in out.iter_mut().enumerate() {
            *o = winning_class(&votes[i * k..(i + 1) * k]);
        }
    }
}

/// Split `out` into `block`-sized chunks and process each with
/// `work(block_index, chunk)`, fanning out over scoped worker threads
/// when more than one is warranted. Chunks are disjoint, so workers
/// never contend on output.
fn run_blocks<T: Send>(
    opts: &BatchOptions,
    out: &mut [T],
    block: usize,
    work: impl Fn(usize, &mut [T]) + Sync,
) {
    if out.is_empty() {
        return;
    }
    let num_blocks = out.len().div_ceil(block);
    let threads = opts.resolve_threads(num_blocks);
    if threads <= 1 {
        for (bi, chunk) in out.chunks_mut(block).enumerate() {
            work(bi, chunk);
        }
        return;
    }
    let queue = Mutex::new(out.chunks_mut(block).enumerate());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let next = queue.lock().unwrap().next();
                match next {
                    Some((bi, chunk)) => work(bi, chunk),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::forest::{ForestParams, RandomForest};

    fn trained() -> (RandomForest, Dataset) {
        let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 700, 6, 9).generate();
        let params = ForestParams {
            num_trees: 5,
            max_depth: 7,
            seed: 4,
            ..Default::default()
        };
        (RandomForest::train(&ds, &params).unwrap(), ds)
    }

    #[test]
    fn batched_scores_match_rowwise_bitwise() {
        let (forest, ds) = trained();
        let flat = FlatForest::compile(&forest);
        let rowwise: Vec<f64> = (0..ds.num_rows()).map(|i| flat.score(&ds.row(i))).collect();
        for opts in [
            BatchOptions::single_thread(),
            BatchOptions {
                block_rows: 64,
                threads: 3,
            },
            BatchOptions {
                block_rows: 1, // degenerate block size still correct
                threads: 2,
            },
        ] {
            let batched = flat.predict_scores_batch(&ds, &opts);
            assert_eq!(batched.len(), rowwise.len());
            for (i, (a, b)) in batched.iter().zip(&rowwise).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i} with {opts:?}");
            }
        }
    }

    #[test]
    fn batched_classes_match_rowwise() {
        let (forest, ds) = trained();
        let flat = FlatForest::compile(&forest);
        let rowwise: Vec<u32> = (0..ds.num_rows())
            .map(|i| flat.predict_class(&ds.row(i)))
            .collect();
        let batched = flat.predict_classes_batch(
            &ds,
            &BatchOptions {
                block_rows: 100,
                threads: 2,
            },
        );
        assert_eq!(batched, rowwise);
    }

    #[test]
    fn empty_dataset_and_empty_forest() {
        let (forest, ds) = trained();
        let flat = FlatForest::compile(&forest);
        let none = ds.head(0);
        assert!(flat
            .predict_scores_batch(&none, &BatchOptions::default())
            .is_empty());
        let empty = FlatForest::from_trees(&[], 2);
        let scores = empty.predict_scores_batch(&ds.head(3), &BatchOptions::default());
        assert_eq!(scores, vec![0.5; 3]);
    }
}
