//! `drf::serve` — the inference subsystem: a flattened forest engine
//! and a TCP prediction server.
//!
//! Training optimizes for exactness bookkeeping; serving optimizes for
//! rows/second. The pipeline is:
//!
//! 1. [`flat`] — compile a trained [`crate::forest::RandomForest`] into
//!    a [`FlatForest`]: structure-of-arrays nodes plus a shared
//!    categorical-bitset arena, bit-identical in routing and scores to
//!    the reference [`crate::tree::Tree::leaf_for`] traversal (enforced
//!    by `tests/serving.rs` across every synthetic family);
//! 2. [`batch`] — blocked, breadth-first batch prediction with
//!    `std::thread` scoped workers, reached transparently through
//!    `RandomForest::predict_scores` / `predict_classes`;
//! 3. [`server`] / [`client`] — a threaded TCP prediction service
//!    speaking the length-prefixed binary protocol of [`wire`]
//!    (magic bytes, version, request ids) with `Score`, `Classify`,
//!    `ModelInfo`, and hot `Reload` RPCs; the CLI front ends are
//!    `drf serve` and `drf predict`.
//!
//! Throughput across the three rungs (reference → flat → flat+threads)
//! is tracked by `benches/serve_throughput.rs`, which records
//! `BENCH_serve.json` for the perf trajectory.

pub mod batch;
pub mod client;
pub mod flat;
pub mod server;
pub mod wire;

pub use batch::BatchOptions;
pub use client::PredictClient;
pub use flat::{FeatureKind, FlatForest};
pub use server::PredictionServer;
pub use wire::{ModelInfo, RowsBatch, ServeRequest, ServeResponse};
