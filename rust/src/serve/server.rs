//! The TCP prediction server.
//!
//! Same shape as the training-side [`crate::coordinator::tcp`] engine:
//! a blocking accept loop, one thread per connection, length-prefixed
//! binary frames — but speaking the serving protocol
//! ([`super::wire`]) and holding a [`FlatForest`] behind an `RwLock`
//! so **hot model reload** swaps the compiled forest without dropping
//! connections: in-flight requests finish on the old model, later
//! requests see the new one.

use super::batch::BatchOptions;
use super::flat::FlatForest;
use super::wire::{
    decode_request_traced, encode_response, read_frame, write_frame, ModelInfo, ServeRequest,
    ServeResponse,
};
use crate::telemetry::{adopt_remote_context, time_sync_reply};
use crate::forest::RandomForest;
use crate::Result;
use anyhow::Context;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

/// A model compiled for serving (immutable once built; reload installs
/// a fresh one).
struct ServedModel {
    flat: FlatForest,
    info: ModelInfo,
}

impl ServedModel {
    fn build(forest: &RandomForest) -> ServedModel {
        ServedModel {
            flat: FlatForest::compile(forest),
            info: ModelInfo {
                num_trees: forest.num_trees() as u32,
                num_classes: forest.num_classes,
                num_nodes: forest.num_nodes() as u64,
            },
        }
    }
}

/// A running prediction server. Dropping it stops accepting new
/// connections (open connections end when their peer disconnects).
pub struct PredictionServer {
    addr: std::net::SocketAddr,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl PredictionServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `forest`. `model_path` is the file `Reload { path: None }`
    /// re-reads — pass the path the model was loaded from.
    pub fn spawn(
        forest: &RandomForest,
        addr: &str,
        model_path: Option<PathBuf>,
    ) -> Result<PredictionServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding prediction server to {addr}"))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServerState {
            model: RwLock::new(Arc::new(ServedModel::build(forest))),
            model_path,
            batch: BatchOptions::default(),
        });
        let shutdown = Arc::new(AtomicBool::new(false));
        let shutdown2 = shutdown.clone();
        let accept_handle = std::thread::Builder::new()
            .name("drf-serve-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if shutdown2.load(Ordering::SeqCst) {
                        break;
                    }
                    // Transient accept errors (ECONNABORTED, fd
                    // pressure) must not kill the accept loop — unlike
                    // the short-lived training-side SplitterServer,
                    // this server is long-running. Back off briefly so
                    // a persistent error cannot spin hot.
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            continue;
                        }
                    };
                    let state = state.clone();
                    // One thread per connection; clients keep one
                    // persistent connection, like tree builders do on
                    // the training side.
                    let _ = std::thread::Builder::new()
                        .name("drf-serve-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(&state, stream);
                        });
                }
            })?;
        Ok(PredictionServer {
            addr,
            accept_handle: Some(accept_handle),
            shutdown,
        })
    }

    /// The bound address (useful with an ephemeral port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for PredictionServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Poke the listener so the accept loop wakes and exits.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

struct ServerState {
    model: RwLock<Arc<ServedModel>>,
    model_path: Option<PathBuf>,
    batch: BatchOptions,
}

/// Handle one connection's request loop. Malformed frames get an `Err`
/// response with request id 0 and close the connection (the peer is
/// speaking another protocol); well-framed but invalid requests get an
/// `Err` response and the loop continues.
fn serve_connection(state: &ServerState, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        let (id, response) = match decode_request_traced(&frame) {
            Err(e) => {
                let resp = ServeResponse::Err(format!("bad request frame: {e}"));
                write_frame(&mut writer, &encode_response(0, &resp))?;
                return Ok(());
            }
            Ok((id, req, ctx)) => {
                let rpc = match &req {
                    ServeRequest::Score(_) => "score",
                    ServeRequest::Classify(_) => "classify",
                    ServeRequest::ModelInfo => "model_info",
                    ServeRequest::Reload { .. } => "reload",
                    ServeRequest::TimeSync => "time_sync",
                };
                let _trace = adopt_remote_context(ctx.as_ref());
                let start = std::time::Instant::now();
                let resp = handle(state, req);
                crate::telemetry::counter_with("drf_serve_requests_total", &[("rpc", rpc)])
                    .inc();
                crate::telemetry::histogram_with("drf_serve_request_us", &[("rpc", rpc)])
                    .observe(start.elapsed().as_micros() as u64);
                (id, resp)
            }
        };
        write_frame(&mut writer, &encode_response(id, &response))?;
    }
}

/// Decode a batch against the current model and run `predict` on it;
/// shared by `Score` and `Classify` so validation can never drift
/// between the two.
fn predict_batch(
    state: &ServerState,
    what: &str,
    batch: super::wire::RowsBatch,
    predict: impl FnOnce(&ServedModel, &crate::data::Dataset) -> ServeResponse,
) -> ServeResponse {
    let model = state.model.read().unwrap().clone();
    match batch
        .into_dataset(model.info.num_classes)
        .and_then(|ds| model.flat.check_dataset(&ds).map(|()| ds))
    {
        Ok(ds) => {
            crate::telemetry::histogram("drf_serve_batch_rows").observe(ds.num_rows() as u64);
            predict(&model, &ds)
        }
        Err(e) => ServeResponse::Err(format!("{what}: {e}")),
    }
}

fn handle(state: &ServerState, req: ServeRequest) -> ServeResponse {
    match req {
        ServeRequest::TimeSync => ServeResponse::TimeSync(time_sync_reply()),
        ServeRequest::Score(batch) => predict_batch(state, "score", batch, |m, ds| {
            ServeResponse::Scores(m.flat.predict_scores_batch(ds, &state.batch))
        }),
        ServeRequest::Classify(batch) => predict_batch(state, "classify", batch, |m, ds| {
            ServeResponse::Classes(m.flat.predict_classes_batch(ds, &state.batch))
        }),
        ServeRequest::ModelInfo => ServeResponse::Info(state.model.read().unwrap().info),
        ServeRequest::Reload { path } => {
            // Remote path overrides are refused: an unauthenticated
            // peer must not be able to point the server at arbitrary
            // server-side files (read oracle / memory DoS). Reload
            // always re-reads the operator-configured startup path.
            if path.is_some() {
                return ServeResponse::Err(
                    "reload: remote path overrides are not permitted; \
                     the server reloads its startup --model path"
                        .into(),
                );
            }
            let path = match &state.model_path {
                Some(p) => p.clone(),
                None => {
                    return ServeResponse::Err(
                        "reload: the server was not started from a model file".into(),
                    )
                }
            };
            match RandomForest::load(&path) {
                Ok(forest) => {
                    let served = Arc::new(ServedModel::build(&forest));
                    let num_trees = served.info.num_trees;
                    *state.model.write().unwrap() = served;
                    ServeResponse::Reloaded { num_trees }
                }
                Err(e) => ServeResponse::Err(format!("reload: {e:#}")),
            }
        }
    }
}
