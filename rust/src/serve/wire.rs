//! Binary wire codec for the prediction protocol.
//!
//! Same idiom as the coordinator codec ([`crate::coordinator::wire`]):
//! length-prefixed frames carrying a compact little-endian body — no
//! serde/bincode. Serving frames additionally start with **magic
//! bytes**, a **protocol version**, and a caller-chosen **request id**
//! that the server echoes back, so clients can detect protocol
//! mismatches and correlate responses. Round-trips and malformed-frame
//! rejection are covered below and in `tests/serving.rs`.
//!
//! Frame body layout (after the 4-byte length prefix of the shared
//! [`crate::util::wire`] frame helpers):
//!
//! ```text
//! "DRFS" | version u8 | request_id u64 | tag u8 | payload…
//! ```

use crate::coordinator::wire::{get_time_sync, put_time_sync};
use crate::telemetry::{TimeSyncReply, TraceContext};
use crate::util::wire::{get_trace_context, put_trace_context, Reader, Writer};
pub use crate::util::wire::{read_frame, write_frame};
use crate::data::column::Column;
use crate::data::schema::{ColumnSpec, Schema};
use crate::data::Dataset;
use crate::Result;
use anyhow::{bail, ensure};

/// Magic bytes opening every serving frame.
pub const MAGIC: [u8; 4] = *b"DRFS";
/// Protocol version (bumped on incompatible changes).
pub const WIRE_VERSION: u8 = 1;

/// A batch of feature rows shipped column-wise — the same columnar shape
/// the engine consumes, so the server decodes straight into a
/// [`Dataset`] without transposing.
#[derive(Debug, Clone, PartialEq)]
pub struct RowsBatch {
    pub columns: Vec<Column>,
}

impl RowsBatch {
    /// Package a dataset's feature columns (labels are not shipped).
    pub fn from_dataset(ds: &Dataset) -> RowsBatch {
        RowsBatch {
            columns: ds.columns().to_vec(),
        }
    }

    pub fn num_rows(&self) -> usize {
        self.columns.first().map_or(0, |c| c.len())
    }

    /// Validate shape invariants and build a scorable [`Dataset`]
    /// (placeholder labels — prediction never reads them).
    /// `num_classes` comes from the served model.
    pub fn into_dataset(self, num_classes: u32) -> Result<Dataset> {
        ensure!(!self.columns.is_empty(), "batch has no feature columns");
        let n = self.columns[0].len();
        let mut specs = Vec::with_capacity(self.columns.len());
        for (j, col) in self.columns.iter().enumerate() {
            ensure!(
                col.len() == n,
                "batch column {j} has {} rows, expected {n}",
                col.len()
            );
            match col {
                Column::Numerical(_) => specs.push(ColumnSpec::numerical(format!("f{j}"))),
                Column::Categorical { values, arity } => {
                    ensure!(*arity > 0, "batch column {j} has zero arity");
                    if let Some(&v) = values.iter().find(|&&v| v >= *arity) {
                        bail!("batch column {j} has value {v} >= arity {arity}");
                    }
                    specs.push(ColumnSpec::categorical(format!("f{j}"), *arity));
                }
            }
        }
        Ok(Dataset::new(
            Schema::new(specs, num_classes.max(2)),
            self.columns,
            vec![0; n],
        ))
    }
}

/// Summary of the model a server is holding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelInfo {
    pub num_trees: u32,
    pub num_classes: u32,
    pub num_nodes: u64,
}

/// A prediction RPC request.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeRequest {
    /// Mean P(class 1) per row.
    Score(RowsBatch),
    /// Majority-vote class per row.
    Classify(RowsBatch),
    /// Describe the currently served model.
    ModelInfo,
    /// Hot-reload the model. `path: None` re-reads the path the server
    /// was started with; servers refuse `Some(path)` overrides from
    /// the network (arbitrary-file read oracle) — the field exists for
    /// future operator-side allowlists.
    Reload { path: Option<String> },
    /// Clock-sync probe: the server replies with its identity and its
    /// monotonic clock reading taken at handling time. Used by tracing
    /// clients to estimate clock offsets (see [`crate::telemetry`]).
    TimeSync,
}

/// A prediction RPC response.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeResponse {
    Scores(Vec<f64>),
    Classes(Vec<u32>),
    Info(ModelInfo),
    Reloaded { num_trees: u32 },
    Err(String),
    TimeSync(TimeSyncReply),
}

fn put_header(w: &mut Writer, request_id: u64) {
    w.magic(MAGIC);
    w.u8(WIRE_VERSION);
    w.u64(request_id);
}

fn get_header(r: &mut Reader<'_>) -> Result<u64> {
    r.expect_magic(MAGIC, "DRF serving")?;
    let version = r.u8()?;
    ensure!(
        version == WIRE_VERSION,
        "unsupported serving protocol version {version} (want {WIRE_VERSION})"
    );
    r.u64()
}

/// Serving frames come from **untrusted peers**: every length prefix
/// goes through the allocation-bounded [`Reader::len_checked`].
fn len_checked(r: &mut Reader<'_>, elem_bytes: usize) -> Result<usize> {
    r.len_checked(elem_bytes)
}

fn put_columns(w: &mut Writer, batch: &RowsBatch) {
    w.usize_u32(batch.columns.len());
    for col in &batch.columns {
        match col {
            Column::Numerical(values) => {
                w.u8(0);
                w.usize_u32(values.len());
                for &v in values {
                    w.f32(v);
                }
            }
            Column::Categorical { values, arity } => {
                w.u8(1);
                w.u32(*arity);
                w.usize_u32(values.len());
                for &v in values {
                    w.u32(v);
                }
            }
        }
    }
}

fn get_columns(r: &mut Reader<'_>) -> Result<RowsBatch> {
    // Each column costs at least tag + length prefix = 5 bytes.
    let nc = len_checked(r, 5)?;
    let mut columns = Vec::with_capacity(nc);
    for _ in 0..nc {
        columns.push(match r.u8()? {
            0 => {
                let n = len_checked(r, 4)?;
                Column::Numerical((0..n).map(|_| r.f32()).collect::<Result<_>>()?)
            }
            1 => {
                let arity = r.u32()?;
                let n = len_checked(r, 4)?;
                Column::Categorical {
                    values: (0..n).map(|_| r.u32()).collect::<Result<_>>()?,
                    arity,
                }
            }
            t => bail!("bad column tag {t}"),
        });
    }
    Ok(RowsBatch { columns })
}

fn put_string(w: &mut Writer, s: &str) {
    w.str(s);
}

fn get_string(r: &mut Reader<'_>) -> Result<String> {
    r.str()
}

/// Encode a request frame body (pass to [`write_frame`]).
pub fn encode_request(request_id: u64, req: &ServeRequest) -> Vec<u8> {
    encode_request_traced(request_id, req, None)
}

/// Encode a request frame body with an optional trace-context trailer.
///
/// Context-free frames are byte-identical to [`encode_request`] output,
/// so [`WIRE_VERSION`] stays unchanged: servers read the trailer only
/// when trailing bytes exist, and old servers never see one unless the
/// client is tracing.
pub fn encode_request_traced(
    request_id: u64,
    req: &ServeRequest,
    ctx: Option<&TraceContext>,
) -> Vec<u8> {
    let mut w = Writer::new();
    put_header(&mut w, request_id);
    match req {
        ServeRequest::Score(batch) => {
            w.u8(0);
            put_columns(&mut w, batch);
        }
        ServeRequest::Classify(batch) => {
            w.u8(1);
            put_columns(&mut w, batch);
        }
        ServeRequest::ModelInfo => w.u8(2),
        ServeRequest::Reload { path } => {
            w.u8(3);
            match path {
                None => w.bool(false),
                Some(p) => {
                    w.bool(true);
                    put_string(&mut w, p);
                }
            }
        }
        ServeRequest::TimeSync => w.u8(4),
    }
    put_trace_context(&mut w, ctx);
    w.into_bytes()
}

/// Decode a request frame body into `(request_id, request)`,
/// discarding any trace-context trailer.
pub fn decode_request(buf: &[u8]) -> Result<(u64, ServeRequest)> {
    let (id, req, _) = decode_request_traced(buf)?;
    Ok((id, req))
}

/// Decode a request frame body plus its optional trace context.
pub fn decode_request_traced(buf: &[u8]) -> Result<(u64, ServeRequest, Option<TraceContext>)> {
    let mut r = Reader::new(buf);
    let id = get_header(&mut r)?;
    let req = match r.u8()? {
        0 => ServeRequest::Score(get_columns(&mut r)?),
        1 => ServeRequest::Classify(get_columns(&mut r)?),
        2 => ServeRequest::ModelInfo,
        3 => ServeRequest::Reload {
            path: if r.bool()? {
                Some(get_string(&mut r)?)
            } else {
                None
            },
        },
        4 => ServeRequest::TimeSync,
        t => bail!("bad request tag {t}"),
    };
    let ctx = get_trace_context(&mut r)?;
    r.done()?;
    Ok((id, req, ctx))
}

/// Encode a response frame body echoing the request id.
pub fn encode_response(request_id: u64, resp: &ServeResponse) -> Vec<u8> {
    let mut w = Writer::new();
    put_header(&mut w, request_id);
    match resp {
        ServeResponse::Scores(scores) => {
            w.u8(0);
            w.usize_u32(scores.len());
            for &s in scores {
                w.f64(s);
            }
        }
        ServeResponse::Classes(classes) => {
            w.u8(1);
            w.usize_u32(classes.len());
            for &c in classes {
                w.u32(c);
            }
        }
        ServeResponse::Info(info) => {
            w.u8(2);
            w.u32(info.num_trees);
            w.u32(info.num_classes);
            w.u64(info.num_nodes);
        }
        ServeResponse::Reloaded { num_trees } => {
            w.u8(3);
            w.u32(*num_trees);
        }
        ServeResponse::Err(msg) => {
            w.u8(4);
            put_string(&mut w, msg);
        }
        ServeResponse::TimeSync(t) => {
            w.u8(5);
            put_time_sync(&mut w, t);
        }
    }
    w.into_bytes()
}

/// Decode a response frame body into `(request_id, response)`.
pub fn decode_response(buf: &[u8]) -> Result<(u64, ServeResponse)> {
    let mut r = Reader::new(buf);
    let id = get_header(&mut r)?;
    let resp = match r.u8()? {
        0 => {
            let n = len_checked(&mut r, 8)?;
            ServeResponse::Scores((0..n).map(|_| r.f64()).collect::<Result<_>>()?)
        }
        1 => {
            let n = len_checked(&mut r, 4)?;
            ServeResponse::Classes((0..n).map(|_| r.u32()).collect::<Result<_>>()?)
        }
        2 => ServeResponse::Info(ModelInfo {
            num_trees: r.u32()?,
            num_classes: r.u32()?,
            num_nodes: r.u64()?,
        }),
        3 => ServeResponse::Reloaded {
            num_trees: r.u32()?,
        },
        4 => ServeResponse::Err(get_string(&mut r)?),
        5 => ServeResponse::TimeSync(get_time_sync(&mut r)?),
        t => bail!("bad response tag {t}"),
    };
    r.done()?;
    Ok((id, resp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;

    fn random_batch(rng: &mut crate::util::proptest::CaseRng) -> RowsBatch {
        let n = rng.usize(0, 20);
        let columns = (1..=rng.usize(1, 4))
            .map(|_| {
                if rng.bool(0.5) {
                    Column::Numerical((0..n).map(|_| rng.f32() * 4.0 - 2.0).collect())
                } else {
                    let arity = rng.usize(1, 40) as u32;
                    Column::Categorical {
                        values: (0..n).map(|_| rng.u64(arity as u64) as u32).collect(),
                        arity,
                    }
                }
            })
            .collect();
        RowsBatch { columns }
    }

    #[test]
    fn request_roundtrip_random() {
        run_cases(0x5E41, 40, |rng| {
            let req = match rng.usize(0, 4) {
                0 => ServeRequest::Score(random_batch(rng)),
                1 => ServeRequest::Classify(random_batch(rng)),
                2 => ServeRequest::ModelInfo,
                3 => ServeRequest::Reload {
                    path: rng.bool(0.5).then(|| "/tmp/forest.json".to_string()),
                },
                _ => ServeRequest::TimeSync,
            };
            let id = rng.u64(u64::MAX);
            let bytes = encode_request(id, &req);
            let (back_id, back) = decode_request(&bytes).unwrap();
            assert_eq!((back_id, back), (id, req.clone()));
            // Traced encoding: exactly one 16-byte trailer, and both
            // decoders accept it.
            let ctx = TraceContext {
                trace_id: rng.u64(1 << 52).max(1),
                parent_span: rng.u64(u64::MAX >> 12),
            };
            let traced = encode_request_traced(id, &req, Some(&ctx));
            assert_eq!(traced.len(), bytes.len() + 16);
            let (tid, treq, tctx) = decode_request_traced(&traced).unwrap();
            assert_eq!((tid, treq, tctx), (id, req.clone(), Some(ctx)));
            let (oid, oreq) = decode_request(&traced).unwrap();
            assert_eq!((oid, oreq), (id, req));
        });
    }

    #[test]
    fn context_free_frames_are_byte_identical() {
        let plain = encode_request(9, &ServeRequest::ModelInfo);
        let traced = encode_request_traced(9, &ServeRequest::ModelInfo, None);
        assert_eq!(plain, traced);
        let (_, _, ctx) = decode_request_traced(&plain).unwrap();
        assert_eq!(ctx, None);
    }

    #[test]
    fn response_roundtrip_random() {
        run_cases(0x5E42, 40, |rng| {
            let resp = match rng.usize(0, 5) {
                0 => ServeResponse::Scores(
                    (0..rng.usize(0, 30)).map(|_| rng.f64()).collect(),
                ),
                1 => ServeResponse::Classes(
                    (0..rng.usize(0, 30)).map(|_| rng.u64(5) as u32).collect(),
                ),
                2 => ServeResponse::Info(ModelInfo {
                    num_trees: rng.u64(500) as u32,
                    num_classes: rng.u64(10) as u32 + 2,
                    num_nodes: rng.u64(1 << 40),
                }),
                3 => ServeResponse::Reloaded {
                    num_trees: rng.u64(500) as u32,
                },
                4 => ServeResponse::Err("model reload failed: no such file".into()),
                _ => ServeResponse::TimeSync(TimeSyncReply {
                    role: "serve".into(),
                    shard: rng.bool(0.5).then(|| rng.u64(16)),
                    pid: rng.u64(1 << 22),
                    t_us: rng.u64(1 << 50),
                }),
            };
            let id = rng.u64(u64::MAX);
            let bytes = encode_response(id, &resp);
            let (back_id, back) = decode_response(&bytes).unwrap();
            assert_eq!((back_id, back), (id, resp));
        });
    }

    #[test]
    fn malformed_frames_rejected() {
        // Too short / wrong magic / wrong version / bad tag / trailing.
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(b"NOPE\x01\0\0\0\0\0\0\0\0\x02").is_err());
        assert!(decode_request(b"DRFS\x63\0\0\0\0\0\0\0\0\x02").is_err());
        let mut bytes = encode_request(7, &ServeRequest::ModelInfo);
        let tag = bytes.len() - 1;
        bytes[tag] = 99;
        assert!(decode_request(&bytes).is_err());
        let mut bytes = encode_request(7, &ServeRequest::ModelInfo);
        bytes.push(0);
        assert!(decode_request(&bytes).is_err());
        // Forged length prefix: a tiny Score frame claiming u32::MAX
        // columns must be rejected before any allocation.
        let mut forged = Vec::new();
        forged.extend_from_slice(&MAGIC);
        forged.push(WIRE_VERSION);
        forged.extend_from_slice(&7u64.to_le_bytes());
        forged.push(0); // Score tag
        forged.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_request(&forged).is_err());
        // A coordinator frame is not a serving frame.
        assert!(decode_response(&crate::coordinator::wire::encode_response(
            &crate::coordinator::wire::Response::Ok
        ))
        .is_err());
    }

    #[test]
    fn batch_dataset_validation() {
        // Ragged columns rejected.
        let ragged = RowsBatch {
            columns: vec![
                Column::Numerical(vec![1.0, 2.0]),
                Column::Numerical(vec![1.0]),
            ],
        };
        assert!(ragged.into_dataset(2).is_err());
        // Out-of-arity categorical value rejected.
        let bad = RowsBatch {
            columns: vec![Column::Categorical {
                values: vec![5],
                arity: 3,
            }],
        };
        assert!(bad.into_dataset(2).is_err());
        // Empty batch rejected.
        assert!(RowsBatch { columns: vec![] }.into_dataset(2).is_err());
        // A good batch round-trips into a scorable dataset.
        let good = RowsBatch {
            columns: vec![
                Column::Numerical(vec![0.5, -1.0]),
                Column::Categorical {
                    values: vec![2, 0],
                    arity: 3,
                },
            ],
        };
        assert_eq!(good.num_rows(), 2);
        let ds = good.into_dataset(2).unwrap();
        assert_eq!(ds.num_rows(), 2);
        assert_eq!(ds.num_features(), 2);
        assert_eq!(ds.row(0).categorical(1), 2);
    }
}
