//! Deterministic pseudo-randomness for DRF.
//!
//! DRF's central networking trick (paper §2.2) is that *bagging* and
//! *feature sampling* are pure functions of `(forest seed, tree index,
//! sample/node index)`. Every worker evaluates the same function locally,
//! so the manager never ships sample-index lists or per-node feature sets
//! over the network — one 8-byte seed replaces `Θ(n)` indices.
//!
//! Two generators are provided:
//!
//! * [`SplitMix64`] — a tiny stateless-friendly mixer used to derive
//!   independent streams from composite keys (its output is also the
//!   recommended seeder for xoshiro-family generators);
//! * [`Xoshiro256pp`] — the sequential generator used where a stream of
//!   variates is needed (synthetic data generation, shuffles).

/// SplitMix64 PRNG (Steele, Lea & Flood 2014). One `u64` of state; each
/// `next` is a single add + mix, and `mix(key)` is usable as a stateless
/// hash — this is what makes seed-only bagging possible.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next u64 variate.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        Self::finalize(self.state)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) via Lemire's multiply-shift (slightly
    /// biased for astronomically large bounds; fine for our index ranges).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// The SplitMix64 finalizer: a high-quality 64->64 bit mixer.
    #[inline]
    pub fn finalize(mut z: u64) -> u64 {
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Stateless hash of a composite key — the workhorse of deterministic
    /// bagging/feature-sampling. Mixes each component in sequence.
    #[inline]
    pub fn hash_key(parts: &[u64]) -> u64 {
        let mut acc = 0x243F6A8885A308D3u64; // pi digits
        for &p in parts {
            acc = Self::finalize(acc ^ p).wrapping_add(0x9E3779B97F4A7C15);
        }
        Self::finalize(acc)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna). Used for longer variate streams.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64, as recommended by the authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (one variate per call; simple and
    /// deterministic, speed is irrelevant here).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

/// How records are bagged for each tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaggingMode {
    /// No bagging: every record has weight 1 in every tree.
    None,
    /// Poisson(1) bootstrap: each record's multiplicity in tree `t` is an
    /// independent Poisson(1) draw keyed by `(seed, t, i)`. This is the
    /// standard distributed approximation of n-out-of-n sampling with
    /// replacement (identical marginal expectation, and — crucially —
    /// evaluable *per record* with zero communication, which is the whole
    /// point of paper §2.2).
    Poisson,
}

impl Default for BaggingMode {
    fn default() -> Self {
        BaggingMode::Poisson
    }
}

impl BaggingMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            BaggingMode::None => "none",
            BaggingMode::Poisson => "poisson",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "none" => BaggingMode::None,
            "poisson" => BaggingMode::Poisson,
            _ => anyhow::bail!("unknown bagging mode '{s}'"),
        })
    }
}

/// Deterministic bagging: `weight(tree, sample)` is a pure function of the
/// key, so every splitter / tree builder agrees without any communication.
#[derive(Debug, Clone, Copy)]
pub struct Bagger {
    seed: u64,
    mode: BaggingMode,
}

impl Bagger {
    pub fn new(seed: u64, mode: BaggingMode) -> Self {
        Self { seed, mode }
    }

    pub fn mode(&self) -> BaggingMode {
        self.mode
    }

    /// Bag multiplicity of `sample` in `tree` (paper Alg. 1's `bag(i, p)`).
    #[inline]
    pub fn weight(&self, tree: u32, sample: u64) -> u32 {
        match self.mode {
            BaggingMode::None => 1,
            BaggingMode::Poisson => {
                // Inverse-CDF Poisson(1) from one uniform variate.
                // P(k) = e^-1 / k!; cumulative thresholds precomputed.
                let u = Self::uniform(self.seed, tree, sample);
                poisson1_icdf(u)
            }
        }
    }

    /// Is the sample in-bag (weight > 0)?
    #[inline]
    pub fn in_bag(&self, tree: u32, sample: u64) -> bool {
        self.weight(tree, sample) > 0
    }

    #[inline]
    fn uniform(seed: u64, tree: u32, sample: u64) -> f64 {
        let h = SplitMix64::hash_key(&[seed, 0xBA66_1D6 ^ tree as u64, sample]);
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Poisson(1) inverse CDF. Thresholds are cumulative probabilities of
/// k = 0, 1, 2, ... under Poisson(1): e^-1 * sum 1/j!.
#[inline]
fn poisson1_icdf(u: f64) -> u32 {
    // e^-1 * cumsum(1/k!) for k = 0..8; beyond 8 the tail is < 1e-6.
    const CDF: [f64; 9] = [
        0.36787944117144233,
        0.7357588823428847,
        0.9196986029286058,
        0.9810118431238462,
        0.9963401531726563,
        0.9994058151824183,
        0.9999167588507119,
        0.9999897508033253,
        0.9999988747974021,
    ];
    for (k, &c) in CDF.iter().enumerate() {
        if u < c {
            return k as u32;
        }
    }
    9
}

/// Per-node feature sampling policy (paper §3.1-3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureSampling {
    /// Classical RF: an independent set of `m'` features per node
    /// (`z` = number of open nodes).
    PerNode,
    /// USB (unique set of bagged features per depth, paper §3.2): all
    /// nodes of a depth level share one set of `m'` features (`z = 1`).
    /// Big win for distributed complexity; explored by XGBoost.
    PerDepth,
    /// All features are candidates everywhere (plain bagged trees).
    All,
}

impl Default for FeatureSampling {
    fn default() -> Self {
        FeatureSampling::PerNode
    }
}

impl FeatureSampling {
    pub fn as_str(&self) -> &'static str {
        match self {
            FeatureSampling::PerNode => "per_node",
            FeatureSampling::PerDepth => "per_depth",
            FeatureSampling::All => "all",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "per_node" => FeatureSampling::PerNode,
            "per_depth" | "usb" => FeatureSampling::PerDepth,
            "all" => FeatureSampling::All,
            _ => anyhow::bail!("unknown feature sampling '{s}'"),
        })
    }
}

/// Deterministic candidate-feature sampler. Like bagging, the candidate
/// set for `(tree, depth, node)` is a pure function of the key, so every
/// splitter can evaluate "is feature j a candidate at (j, h, p)?" (paper
/// Alg. 1) locally with zero communication.
#[derive(Debug, Clone, Copy)]
pub struct FeatureSampler {
    seed: u64,
    num_features: usize,
    num_candidates: usize,
    policy: FeatureSampling,
}

impl FeatureSampler {
    /// `num_candidates` is the paper's `m'` (typically `⌈√m⌉`; clamped to
    /// `[1, m]`). Ignored for [`FeatureSampling::All`].
    pub fn new(
        seed: u64,
        num_features: usize,
        num_candidates: usize,
        policy: FeatureSampling,
    ) -> Self {
        assert!(num_features > 0, "feature sampler over empty schema");
        let num_candidates = num_candidates.clamp(1, num_features);
        Self {
            seed,
            num_features,
            num_candidates,
            policy,
        }
    }

    /// Default `m' = ⌈√m⌉`.
    pub fn sqrt_default(seed: u64, num_features: usize, policy: FeatureSampling) -> Self {
        let mp = (num_features as f64).sqrt().ceil() as usize;
        Self::new(seed, num_features, mp, policy)
    }

    pub fn num_candidates(&self) -> usize {
        match self.policy {
            FeatureSampling::All => self.num_features,
            _ => self.num_candidates,
        }
    }

    pub fn policy(&self) -> FeatureSampling {
        self.policy
    }

    /// The stream key for a node: USB collapses all nodes of one depth
    /// onto one key (z = 1).
    #[inline]
    fn node_key(&self, tree: u32, depth: u32, node_id: u32) -> u64 {
        match self.policy {
            FeatureSampling::PerNode => {
                SplitMix64::hash_key(&[self.seed, 0xFEA7 ^ tree as u64, node_id as u64])
            }
            FeatureSampling::PerDepth => {
                SplitMix64::hash_key(&[self.seed, 0xFEA7 ^ tree as u64, 0x0DE9 ^ depth as u64])
            }
            FeatureSampling::All => 0,
        }
    }

    /// Sorted candidate feature set for a node. Uses a Fisher-Yates
    /// partial shuffle on a per-key generator: exact sampling without
    /// replacement of `m'` features out of `m`.
    pub fn candidates(&self, tree: u32, depth: u32, node_id: u32) -> Vec<usize> {
        if matches!(self.policy, FeatureSampling::All) {
            return (0..self.num_features).collect();
        }
        let mut rng = SplitMix64::new(self.node_key(tree, depth, node_id));
        let m = self.num_features;
        let k = self.num_candidates;
        // Partial Fisher-Yates over an index vector. m is small (features,
        // not samples) so materializing it is fine.
        let mut idx: Vec<usize> = (0..m).collect();
        for i in 0..k {
            let j = i + rng.next_below((m - i) as u64) as usize;
            idx.swap(i, j);
        }
        let mut out = idx[..k].to_vec();
        out.sort_unstable();
        out
    }

    /// Membership test used in splitters' inner loop (Alg. 1's
    /// `candidate feature (j, h, p)`). O(m') but m' is tiny; splitters
    /// precompute sets per level anyway.
    pub fn is_candidate(&self, tree: u32, depth: u32, node_id: u32, feature: usize) -> bool {
        if matches!(self.policy, FeatureSampling::All) {
            return feature < self.num_features;
        }
        self.candidates(tree, depth, node_id).contains(&feature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for SplitMix64 with seed 1234567.
        let mut r = SplitMix64::new(1234567);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(a, r2.next_u64());
        assert_eq!(b, r2.next_u64());
    }

    #[test]
    fn splitmix_f64_in_unit_interval() {
        let mut r = SplitMix64::new(99);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds() {
        let mut r = SplitMix64::new(7);
        for bound in [1u64, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..100 {
            let v = a.next_u64();
            assert_eq!(v, b.next_u64());
            distinct.insert(v);
        }
        assert_eq!(distinct.len(), 100);
    }

    #[test]
    fn bagger_deterministic_across_instances() {
        let b1 = Bagger::new(5, BaggingMode::Poisson);
        let b2 = Bagger::new(5, BaggingMode::Poisson);
        for t in 0..3 {
            for i in 0..500 {
                assert_eq!(b1.weight(t, i), b2.weight(t, i));
            }
        }
    }

    #[test]
    fn bagger_poisson_mean_about_one() {
        let b = Bagger::new(11, BaggingMode::Poisson);
        let n = 200_000u64;
        let total: u64 = (0..n).map(|i| b.weight(0, i) as u64).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "poisson mean {mean}");
        // ~36.8% of samples should be out-of-bag.
        let oob = (0..n).filter(|&i| !b.in_bag(0, i)).count() as f64 / n as f64;
        assert!((oob - 0.3679).abs() < 0.02, "oob fraction {oob}");
    }

    #[test]
    fn bagger_trees_independent() {
        let b = Bagger::new(5, BaggingMode::Poisson);
        let same = (0..10_000)
            .filter(|&i| b.weight(0, i) == b.weight(1, i))
            .count();
        // Two independent Poisson(1) draws collide ~ sum p_k^2 ~ 0.31 of
        // the time; equality everywhere would indicate broken keying.
        assert!(same < 6_000, "trees look correlated: {same}");
    }

    #[test]
    fn bagging_none_all_ones() {
        let b = Bagger::new(5, BaggingMode::None);
        assert!((0..100).all(|i| b.weight(3, i) == 1));
    }

    #[test]
    fn feature_sampler_size_and_range() {
        let fs = FeatureSampler::new(9, 20, 5, FeatureSampling::PerNode);
        for node in 0..50 {
            let c = fs.candidates(0, 3, node);
            assert_eq!(c.len(), 5);
            assert!(c.windows(2).all(|w| w[0] < w[1]), "sorted unique");
            assert!(c.iter().all(|&f| f < 20));
        }
    }

    #[test]
    fn feature_sampler_usb_shares_per_depth() {
        let fs = FeatureSampler::new(9, 20, 5, FeatureSampling::PerDepth);
        let a = fs.candidates(0, 3, 10);
        let b = fs.candidates(0, 3, 99);
        assert_eq!(a, b, "USB: same set for all nodes at a depth");
        let c = fs.candidates(0, 4, 10);
        assert_ne!(a, c, "different depth -> different set (w.h.p.)");
    }

    #[test]
    fn feature_sampler_per_node_varies() {
        let fs = FeatureSampler::new(9, 100, 10, FeatureSampling::PerNode);
        let a = fs.candidates(0, 3, 10);
        let b = fs.candidates(0, 3, 11);
        assert_ne!(a, b);
    }

    #[test]
    fn feature_sampler_all() {
        let fs = FeatureSampler::new(9, 7, 3, FeatureSampling::All);
        assert_eq!(fs.candidates(0, 0, 0), (0..7).collect::<Vec<_>>());
        assert!(fs.is_candidate(0, 0, 0, 6));
        assert!(!fs.is_candidate(0, 0, 0, 7));
    }

    #[test]
    fn feature_sampler_clamps_num_candidates() {
        let fs = FeatureSampler::new(9, 4, 100, FeatureSampling::PerNode);
        assert_eq!(fs.num_candidates(), 4);
        let fs = FeatureSampler::sqrt_default(9, 82, FeatureSampling::PerNode);
        assert_eq!(fs.num_candidates(), 10); // ceil(sqrt(82)) = 10
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::new(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
