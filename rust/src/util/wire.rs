//! Shared binary wire substrate.
//!
//! Both wire codecs in this crate — the coordinator RPC protocol
//! ([`crate::coordinator::wire`]) and the serving protocol
//! ([`crate::serve::wire`]) — speak length-prefixed frames carrying a
//! compact little-endian body. The scalar writer/reader, the frame
//! read/write helpers, the allocation bounds on untrusted length
//! prefixes, and the magic/string helpers live here once; the two
//! protocol modules only define their message encodings.

use crate::telemetry::TraceContext;
use crate::Result;
use anyhow::ensure;

/// Hard cap on a single frame body (256 MiB) — both protocols reject
/// anything larger before allocating.
pub const MAX_FRAME_BYTES: usize = 1 << 28;

/// Growable little-endian writer.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize_u32(&mut self, v: usize) {
        self.u32(v as u32);
    }

    pub fn u64_slice(&mut self, v: &[u64]) {
        self.usize_u32(v.len());
        for &x in v {
            self.u64(x);
        }
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Raw magic bytes (no length prefix).
    pub fn magic(&mut self, m: [u8; 4]) {
        self.buf.extend_from_slice(&m);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.usize_u32(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Cursor-based reader with explicit errors.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "trailing {} bytes in frame",
            self.buf.len() - self.pos
        );
        Ok(())
    }

    /// Bytes left in the frame. Decoders facing untrusted peers use
    /// this to bound length prefixes by element size before allocating
    /// (see [`Self::len_checked`]).
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Borrow the next `n` raw bytes of the frame (consuming them).
    /// Callers reading variable-length payloads must bound `n` first
    /// (see [`Self::len_checked`]).
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "frame truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn len_u32(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        // Cheap sanity bound: even 1-byte elements cannot outnumber the
        // remaining frame bytes.
        ensure!(
            n <= self.buf.len().saturating_sub(self.pos) * 8 + 8,
            "length prefix {n} exceeds frame"
        );
        Ok(n)
    }

    /// Read a length prefix and require the claimed `n` elements of at
    /// least `elem_bytes` each to actually fit in the rest of the
    /// frame. [`Self::len_u32`]'s own bound is sized for u64 payloads;
    /// frames from **untrusted peers** must use this instead, or a
    /// forged count could drive multi-GiB `with_capacity` calls from a
    /// small frame.
    pub fn len_checked(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.len_u32()?;
        ensure!(
            n <= self.remaining() / elem_bytes.max(1),
            "length prefix {n} exceeds frame"
        );
        Ok(n)
    }

    pub fn u64_vec(&mut self) -> Result<Vec<u64>> {
        // Strict bound: the claimed u64s must actually fit in the rest
        // of the frame (fuzz finding: the loose `len_u32` bound let a
        // 20-byte frame claim a 64×-larger vec before the read failed).
        let n = self.len_checked(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    /// Require the next 4 bytes to equal `m` (`what` names the protocol
    /// in the error).
    pub fn expect_magic(&mut self, m: [u8; 4], what: &str) -> Result<()> {
        let got: [u8; 4] = self.take(4)?.try_into().unwrap();
        ensure!(got == m, "bad magic {got:02x?} (not a {what} frame)");
        Ok(())
    }

    /// Length-prefixed UTF-8 string (allocation-bounded).
    pub fn str(&mut self) -> Result<String> {
        let n = self.len_checked(1)?;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }
}

/// Byte width of the optional trace-context trailer
/// ([`put_trace_context`]): `trace_id` + `parent_span`, both u64 LE.
pub const TRACE_CONTEXT_BYTES: usize = 16;

/// Append the optional distributed-tracing trailer to a request body.
/// `None` writes nothing, keeping the frame byte-identical to the
/// pre-tracing encoding — which is what makes context optional on every
/// protocol without a second wire format.
pub fn put_trace_context(w: &mut Writer, ctx: Option<&TraceContext>) {
    if let Some(c) = ctx {
        w.u64(c.trace_id);
        w.u64(c.parent_span);
    }
}

/// Read the optional trace-context trailer: `Ok(None)` when the body
/// ended exactly at the cursor (a context-free peer), the decoded
/// context when [`TRACE_CONTEXT_BYTES`] more follow. Any other
/// remainder is a framing error, surfaced by the failed scalar read
/// here or by the caller's final `done()`.
pub fn get_trace_context(r: &mut Reader<'_>) -> Result<Option<TraceContext>> {
    if r.remaining() == 0 {
        return Ok(None);
    }
    Ok(Some(TraceContext {
        trace_id: r.u64()?,
        parent_span: r.u64()?,
    }))
}

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut impl std::io::Write, body: &[u8]) -> Result<()> {
    stream.write_all(&(body.len() as u32).to_le_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Incremental read granularity for frame bodies. Bodies are read (and
/// the buffer grown) in steps of this size, so a forged length prefix
/// can only force this much allocation beyond the bytes the peer
/// actually sent.
const FRAME_READ_CHUNK: usize = 64 * 1024;

/// Read one length-prefixed frame (cap: [`MAX_FRAME_BYTES`]).
///
/// The body buffer grows as bytes actually arrive rather than being
/// allocated up front from the untrusted prefix: a peer claiming a
/// 256 MiB frame but sending 10 bytes costs one read chunk, not
/// 256 MiB, before the truncation error surfaces.
pub fn read_frame(stream: &mut impl std::io::Read) -> Result<Vec<u8>> {
    let mut len_bytes = [0u8; 4];
    stream.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    ensure!(len <= MAX_FRAME_BYTES, "frame too large: {len}");
    let mut body = Vec::with_capacity(len.min(FRAME_READ_CHUNK));
    while body.len() < len {
        let step = (len - body.len()).min(FRAME_READ_CHUNK);
        let start = body.len();
        body.resize(start + step, 0);
        stream.read_exact(&mut body[start..])?;
    }
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(1 << 40);
        w.f32(1.5);
        w.f64(-2.25);
        w.bool(true);
        w.u64_slice(&[3, 4]);
        w.str("héllo");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert!(r.bool().unwrap());
        assert_eq!(r.u64_vec().unwrap(), vec![3, 4]);
        assert_eq!(r.str().unwrap(), "héllo");
        r.done().unwrap();
    }

    #[test]
    fn truncation_and_trailing_rejected() {
        let mut w = Writer::new();
        w.u32(1);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.u64().is_err(), "truncated");
        let mut r = Reader::new(&bytes);
        r.u8().unwrap();
        assert!(r.done().is_err(), "trailing bytes");
    }

    #[test]
    fn forged_length_prefixes_bounded() {
        let mut w = Writer::new();
        w.u32(u32::MAX);
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).len_u32().is_err());
        assert!(Reader::new(&bytes).len_checked(4).is_err());
        // A claimed 2-element u64 vec with only 1 element of payload.
        let mut w = Writer::new();
        w.u32(2);
        w.u64(1);
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).len_checked(8).is_err());
    }

    #[test]
    fn magic_helpers() {
        let mut w = Writer::new();
        w.magic(*b"DRFX");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.expect_magic(*b"DRFX", "test").is_ok());
        let mut r = Reader::new(&bytes);
        let err = r.expect_magic(*b"NOPE", "test").unwrap_err();
        assert!(format!("{err}").contains("test"));
    }

    #[test]
    fn frame_io_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap(), b"hello");
        assert_eq!(read_frame(&mut cursor).unwrap(), b"");
        assert!(read_frame(&mut cursor).is_err(), "EOF");
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_BYTES as u32 + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn truncated_body_rejected() {
        // The prefix claims 1 MiB; the peer sent 3 bytes. The chunked
        // reader must hit EOF after at most one read chunk instead of
        // allocating the full claimed body up front.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(1u32 << 20).to_le_bytes());
        buf.extend_from_slice(b"abc");
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
        // Same for a frame claiming the maximum legal size.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_BYTES as u32).to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn take_is_bounded() {
        let bytes = [1u8, 2, 3];
        let mut r = Reader::new(&bytes);
        assert_eq!(r.take(2).unwrap(), &[1, 2]);
        assert!(r.take(2).is_err(), "frame truncated");
    }

    #[test]
    fn forged_string_length_rejected() {
        // A str claiming 1 GiB inside a 4-byte frame must error before
        // allocating.
        let mut w = Writer::new();
        w.u32(1 << 30);
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).str().is_err());
    }

    #[test]
    fn trace_context_trailer_roundtrip() {
        // Absent context writes zero bytes.
        let mut w = Writer::new();
        put_trace_context(&mut w, None);
        let bytes = w.into_bytes();
        assert!(bytes.is_empty());
        let mut r = Reader::new(&bytes);
        assert_eq!(get_trace_context(&mut r).unwrap(), None);
        r.done().unwrap();
        // Present context is exactly TRACE_CONTEXT_BYTES and round-trips.
        let ctx = TraceContext {
            trace_id: 0xABCD_EF01_2345,
            parent_span: 0x1122_3344_5566,
        };
        let mut w = Writer::new();
        put_trace_context(&mut w, Some(&ctx));
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), TRACE_CONTEXT_BYTES);
        let mut r = Reader::new(&bytes);
        assert_eq!(get_trace_context(&mut r).unwrap(), Some(ctx));
        r.done().unwrap();
        // A torn trailer (half the bytes) is a framing error.
        let mut r = Reader::new(&bytes[..8]);
        assert!(get_trace_context(&mut r).is_err());
    }

    #[test]
    fn truncated_magic_rejected() {
        let bytes = [b'D', b'R'];
        let mut r = Reader::new(&bytes);
        assert!(r.expect_magic(*b"DRFX", "test").is_err());
    }
}
