//! Tiny command-line argument parser (in-tree replacement for `clap`;
//! this project builds fully offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Unknown flags are an error, listing the valid
//! set.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name). `known` lists the
    /// value-taking flags; names prefixed with `!` declare boolean
    /// switches that never consume the next token (e.g. `"!quick"`).
    pub fn parse(argv: &[String], known: &[&str]) -> Result<Args> {
        let value_flags: Vec<&str> = known
            .iter()
            .filter(|n| !n.starts_with('!'))
            .copied()
            .collect();
        let switch_flags: Vec<&str> = known
            .iter()
            .filter_map(|n| n.strip_prefix('!'))
            .collect();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let is_switch = switch_flags.contains(&name.as_str());
                if !is_switch && !value_flags.contains(&name.as_str()) {
                    bail!("unknown flag --{name}; known flags: {known:?}");
                }
                let value = match inline_val {
                    Some(v) => v,
                    None if is_switch => "true".to_string(),
                    None => {
                        // Next token is the value unless it is another flag
                        // or the end (then treat as boolean true).
                        if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                            i += 1;
                            argv[i].clone()
                        } else {
                            "true".to_string()
                        }
                    }
                };
                flags.insert(name, value);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { flags, positional })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// A flag that must be present (clean error instead of a default).
    pub fn require(&self, name: &str) -> Result<&str> {
        match self.get(name) {
            Some(v) => Ok(v),
            None => bail!("--{name} is required"),
        }
    }

    pub fn get_string(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u32(&self, name: &str, default: u32) -> Result<u32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(
            &argv(&["train", "--rows", "100", "--deep=5", "--quick", "x.json"]),
            &["rows", "deep", "!quick"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["train", "x.json"]);
        assert_eq!(a.get_usize("rows", 0).unwrap(), 100);
        assert_eq!(a.get_u32("deep", 0).unwrap(), 5);
        assert!(a.get_bool("quick"));
        assert!(!a.get_bool("absent"));
        assert_eq!(a.get_string("absent", "d"), "d");
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse(&argv(&["--nope"]), &["yes"]).is_err());
    }

    #[test]
    fn require_present_and_missing() {
        let a = Args::parse(&argv(&["--model", "f.json"]), &["model", "addr"]).unwrap();
        assert_eq!(a.require("model").unwrap(), "f.json");
        let err = a.require("addr").unwrap_err();
        assert!(format!("{err}").contains("--addr"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&argv(&["--rows", "abc"]), &["rows"]).unwrap();
        assert!(a.get_usize("rows", 0).is_err());
    }
}
