//! Minimal JSON: a value model, a recursive-descent parser, and a
//! writer. Built in-tree because this project builds fully offline from
//! a small vendored crate set (no serde). Covers the full JSON grammar
//! except for `\u` surrogate pairs outside the BMP (sufficient for our
//! model/config/report files, which are ASCII).

use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- constructors ----------------

    pub fn object() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_u64(v: u64) -> Json {
        Json::Num(v as f64)
    }

    pub fn from_usize(v: usize) -> Json {
        Json::Num(v as f64)
    }

    pub fn from_slice_u64(v: &[u64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::from_u64(x)).collect())
    }

    pub fn from_slice_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------------- object helpers ----------------

    /// Insert into an object (panics on non-objects — builder misuse).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key '{key}'")),
            _ => bail!("get('{key}') on non-object"),
        }
    }

    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // ---------------- typed accessors ----------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let v = self.as_f64()?;
        ensure!(
            v >= 0.0 && v.fract() == 0.0 && v <= 2f64.powi(53),
            "expected unsigned integer, got {v}"
        );
        Ok(v as u64)
    }

    pub fn as_u32(&self) -> Result<u32> {
        let v = self.as_u64()?;
        ensure!(v <= u32::MAX as u64, "u32 overflow: {v}");
        Ok(v as u32)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_vec_u64(&self) -> Result<Vec<u64>> {
        self.as_arr()?.iter().map(|v| v.as_u64()).collect()
    }

    pub fn as_vec_f64(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---------------- writer ----------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(*v, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------------- parser ----------------

    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }
}

fn write_num(v: f64, out: &mut String) {
    if v.is_finite() {
        if v.fract() == 0.0 && v.abs() < 2f64.powi(53) {
            let _ = write!(out, "{}", v as i64);
        } else {
            // Roundtrip-exact float formatting (Rust's default is
            // shortest-roundtrip).
            let _ = write!(out, "{v}");
        }
    } else {
        // JSON has no NaN/inf; encode as null (we never store these).
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the parser accepts. The recursive-descent
/// parser's stack frames are proportional to nesting depth, so without
/// a cap a ~100 KiB document of `[[[[…` from an untrusted peer
/// overflows the thread stack — an uncatchable abort, not an `Err`
/// (fuzz finding). Real manifests nest 4–5 levels.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .context("unexpected end of JSON")
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        ensure!(
            self.peek()? == b,
            "expected '{}' at byte {}, found '{}'",
            b as char,
            self.pos,
            self.peek()? as char
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' | b'[' => {
                self.depth += 1;
                ensure!(
                    self.depth <= MAX_DEPTH,
                    "JSON nested deeper than {MAX_DEPTH} levels at byte {}",
                    self.pos
                );
                let v = if self.peek()? == b'{' {
                    self.object()
                } else {
                    self.array()
                }?;
                self.depth -= 1;
                Ok(v)
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.keyword("true", Json::Bool(true)),
            b'f' => self.keyword("false", Json::Bool(false)),
            b'n' => self.keyword("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.pos),
        }
    }

    fn keyword(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad keyword at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.bytes.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).context("bad \\u escape")?);
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the byte slice.
                    let start = self.pos - 1;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .context("invalid UTF-8 in string")?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let v: f64 = text
            .parse()
            .with_context(|| format!("bad number '{text}'"))?;
        // `f64::from_str` turns overflowing literals (`1e999`) into
        // infinity; accepting that would silently rewrite the value to
        // `null` on the next save (fuzz finding). JSON has no
        // non-finite numbers — reject instead.
        ensure!(v.is_finite(), "number '{text}' out of range");
        Ok(Json::Num(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut obj = Json::object();
        obj.set("null", Json::Null)
            .set("b", Json::Bool(true))
            .set("i", Json::Num(42.0))
            .set("f", Json::Num(0.125))
            .set("neg", Json::Num(-7.0))
            .set("s", Json::Str("he\"llo\n\\ wörld".into()))
            .set(
                "arr",
                Json::Arr(vec![Json::Num(1.0), Json::Str("x".into()), Json::Null]),
            )
            .set("nested", {
                let mut o = Json::object();
                o.set("k", Json::Num(1e-9));
                o
            });
        let text = obj.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(obj, back);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let v = Json::parse(" { \"a\" : [ 1 , 2.5 , \"\\u0041\\t\" ] } ").unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64().unwrap(), 1);
        assert_eq!(arr[1].as_f64().unwrap(), 2.5);
        assert_eq!(arr[2].as_str().unwrap(), "A\t");
    }

    #[test]
    fn float_roundtrip_is_exact() {
        for v in [0.1f64, 1.0 / 3.0, 1e300, -2.5e-10, f64::MIN_POSITIVE] {
            let text = Json::Num(v).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(v, back, "roundtrip of {v}");
        }
    }

    #[test]
    fn large_integers_exact() {
        let v = (1u64 << 53) - 1;
        let text = Json::from_u64(v).to_string();
        assert_eq!(Json::parse(&text).unwrap().as_u64().unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\":01x}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("{\"a\": -1}").unwrap();
        assert!(v.get("a").unwrap().as_u64().is_err());
        assert!(v.get("b").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(Json::Num(1.5).as_u64().is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let mut a = Json::object();
        a.set("z", Json::Num(1.0)).set("a", Json::Num(2.0));
        assert_eq!(a.to_string(), "{\"a\":2,\"z\":1}");
    }
}
