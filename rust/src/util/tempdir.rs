//! Self-deleting temporary directories (in-tree replacement for the
//! `tempfile` crate; this project builds fully offline).

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed (recursively) on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh directory under the system temp dir.
    pub fn new() -> Result<TempDir> {
        let id = COUNTER.fetch_add(1, Ordering::SeqCst);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let path = std::env::temp_dir().join(format!(
            "drf-{}-{}-{}",
            std::process::id(),
            id,
            nanos
        ));
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating temp dir {}", path.display()))?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// `crate::util::tempdir()`-compatible shorthand.
pub fn tempdir() -> Result<TempDir> {
    TempDir::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_removes() {
        let p;
        {
            let d = tempdir().unwrap();
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("x"), b"hi").unwrap();
        }
        assert!(!p.exists(), "directory should be removed on drop");
    }

    #[test]
    fn two_dirs_distinct() {
        let a = tempdir().unwrap();
        let b = tempdir().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
