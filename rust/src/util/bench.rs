//! Tiny benchmark harness (in-tree replacement for `criterion`; this
//! project builds fully offline). Benches are `harness = false` mains
//! that time closures with warmup + repeated measurement and print
//! aligned tables — each bench binary regenerates one of the paper's
//! tables/figures.
//!
//! Every bench persists its results as `BENCH_<name>.json` in the
//! working directory ([`write_bench_json`] / [`Table::write_json`]) so
//! the perf trajectory accumulates machine-readable datapoints; CI's
//! bench-smoke job runs the benches in [`smoke_mode`] (env
//! `DRF_BENCH_SMOKE=1`, shrunken inputs) and uploads the JSONs as
//! artifacts.

use crate::metrics::Stopwatch;
use crate::util::Json;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn per_iter_label(&self) -> String {
        format_seconds(self.mean_s)
    }
}

/// Human-friendly seconds.
pub fn format_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Time `f` with one warmup run and up to `max_iters` measured runs
/// (stops early after `budget_s` of measurement).
pub fn bench(max_iters: u32, budget_s: f64, mut f: impl FnMut()) -> Timing {
    f(); // warmup
    let mut times = Vec::new();
    let total = Stopwatch::start();
    for _ in 0..max_iters.max(1) {
        let sw = Stopwatch::start();
        f();
        times.push(sw.seconds());
        if total.seconds() > budget_s {
            break;
        }
    }
    let n = times.len() as f64;
    Timing {
        iters: times.len() as u32,
        mean_s: times.iter().sum::<f64>() / n,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// The table as JSON: `{"headers": [...], "rows": [{h: cell}...]}`.
    /// Cells stay strings — benches that want typed fields build their
    /// own payload and call [`write_bench_json`] directly.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set(
            "headers",
            Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        )
        .set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|row| {
                        let mut rj = Json::object();
                        for (h, c) in self.headers.iter().zip(row) {
                            rj.set(h.as_str(), Json::Str(c.clone()));
                        }
                        rj
                    })
                    .collect(),
            ),
        );
        o
    }

    /// Emit this table as `BENCH_<name>.json` (the one-call path for
    /// table-shaped benches).
    pub fn write_json(&self, name: &str) {
        write_bench_json(name, self.to_json());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Persist a bench payload as `BENCH_<name>.json` in the working
/// directory, stamping the bench name and smoke flag in. Benches call
/// this (or [`Table::write_json`]) unconditionally so the perf
/// trajectory always has machine-readable output.
pub fn write_bench_json(name: &str, mut payload: Json) {
    if let Json::Obj(_) = payload {
        payload
            .set("bench", Json::Str(name.into()))
            .set("smoke_mode", Json::Bool(smoke_mode()));
    }
    let path = format!("BENCH_{name}.json");
    match std::fs::write(&path, payload.to_string()) {
        Ok(()) => println!("\nsummary written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// CI smoke mode (`DRF_BENCH_SMOKE=1`): benches shrink their inputs so
/// the whole suite finishes in seconds — the JSON artifacts keep
/// flowing, the absolute numbers are not comparable to full runs
/// (`smoke_mode: true` is stamped into the payload).
pub fn smoke_mode() -> bool {
    std::env::var("DRF_BENCH_SMOKE").map_or(false, |v| v == "1" || v == "true")
}

/// `full` normally, `smoke` under [`smoke_mode`] — for sizing inputs.
pub fn sized(full: usize, smoke: usize) -> usize {
    if smoke_mode() {
        smoke
    } else {
        full
    }
}

/// Helpers shared by bench mains.
pub fn fmt_count(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}e9", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}e6", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}e3", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

pub fn fmt_bytes(b: u64) -> String {
    let b = b as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        let mut count = 0;
        let t = bench(5, 10.0, || {
            count += 1;
        });
        assert_eq!(t.iters, 5);
        assert_eq!(count, 6, "warmup + 5 measured");
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
    }

    #[test]
    fn formatting() {
        assert_eq!(format_seconds(2.5), "2.500 s");
        assert_eq!(format_seconds(0.0025), "2.500 ms");
        assert_eq!(format_seconds(2.5e-6), "2.5 µs");
        assert_eq!(fmt_bytes(1500), "1.50 KB");
        assert_eq!(fmt_count(1234.0), "1.2e3");
        assert_eq!(fmt_count(17.3e9), "17.30e9");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
