//! Tiny benchmark harness (in-tree replacement for `criterion`; this
//! project builds fully offline). Benches are `harness = false` mains
//! that time closures with warmup + repeated measurement and print
//! aligned tables — each bench binary regenerates one of the paper's
//! tables/figures.

use crate::metrics::Stopwatch;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn per_iter_label(&self) -> String {
        format_seconds(self.mean_s)
    }
}

/// Human-friendly seconds.
pub fn format_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Time `f` with one warmup run and up to `max_iters` measured runs
/// (stops early after `budget_s` of measurement).
pub fn bench(max_iters: u32, budget_s: f64, mut f: impl FnMut()) -> Timing {
    f(); // warmup
    let mut times = Vec::new();
    let total = Stopwatch::start();
    for _ in 0..max_iters.max(1) {
        let sw = Stopwatch::start();
        f();
        times.push(sw.seconds());
        if total.seconds() > budget_s {
            break;
        }
    }
    let n = times.len() as f64;
    Timing {
        iters: times.len() as u32,
        mean_s: times.iter().sum::<f64>() / n,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Helpers shared by bench mains.
pub fn fmt_count(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}e9", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}e6", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}e3", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

pub fn fmt_bytes(b: u64) -> String {
    let b = b as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        let mut count = 0;
        let t = bench(5, 10.0, || {
            count += 1;
        });
        assert_eq!(t.iters, 5);
        assert_eq!(count, 6, "warmup + 5 measured");
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
    }

    #[test]
    fn formatting() {
        assert_eq!(format_seconds(2.5), "2.500 s");
        assert_eq!(format_seconds(0.0025), "2.500 ms");
        assert_eq!(format_seconds(2.5e-6), "2.5 µs");
        assert_eq!(fmt_bytes(1500), "1.50 KB");
        assert_eq!(fmt_count(1234.0), "1.2e3");
        assert_eq!(fmt_count(17.3e9), "17.30e9");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
