//! Minimal property-testing helper (in-tree replacement for `proptest`;
//! this project builds fully offline).
//!
//! A property test runs a closure over `cases` seeded inputs; on
//! failure it reports the failing case seed so the case can be replayed
//! deterministically (`CaseRng::new(seed)` regenerates the exact input).

use crate::rng::Xoshiro256pp;

/// Per-case random generator handed to properties.
pub struct CaseRng {
    rng: Xoshiro256pp,
    seed: u64,
}

impl CaseRng {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256pp::new(seed),
            seed,
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn u64(&mut self, bound: u64) -> u64 {
        self.rng.next_below(bound.max(1))
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn f32(&mut self) -> f32 {
        self.rng.next_f64() as f32
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.next_f64() < p_true
    }

    /// A full-range 64-bit value (wire tests want forged bit patterns
    /// and extreme ids, not just bounded indices).
    pub fn raw_u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// An ASCII string of length in [lo, hi] (printable range, so it
    /// survives any text codec under test unchanged).
    pub fn string(&mut self, lo: usize, hi: usize) -> String {
        let n = self.usize(lo, hi);
        (0..n)
            .map(|_| char::from_u32(0x20 + self.u64(0x5f) as u32).unwrap())
            .collect()
    }

    /// A vector of length in [lo, hi] filled by `gen`.
    pub fn vec<T>(&mut self, lo: usize, hi: usize, mut gen: impl FnMut(&mut Self) -> T) -> Vec<T> {
        let n = self.usize(lo, hi);
        (0..n).map(|_| gen(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }
}

/// Run `property` over `cases` deterministic cases derived from
/// `test_seed`. Panics (with the case seed) on the first failure.
pub fn run_cases(test_seed: u64, cases: u32, mut property: impl FnMut(&mut CaseRng)) {
    for case in 0..cases {
        let case_seed = crate::rng::SplitMix64::hash_key(&[test_seed, case as u64]);
        let mut rng = CaseRng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed on case {case} (replay with CaseRng::new({case_seed:#x}))"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut first_run = Vec::new();
        run_cases(1, 5, |rng| first_run.push(rng.u64(1000)));
        let mut second_run = Vec::new();
        run_cases(1, 5, |rng| second_run.push(rng.u64(1000)));
        assert_eq!(first_run, second_run);
        assert!(first_run.iter().collect::<std::collections::HashSet<_>>().len() > 1);
    }

    #[test]
    fn generators_respect_bounds() {
        run_cases(2, 50, |rng| {
            let v = rng.usize(3, 7);
            assert!((3..=7).contains(&v));
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            let vec = rng.vec(0, 4, |r| r.bool(0.5));
            assert!(vec.len() <= 4);
            let c = *rng.choose(&[10, 20, 30]);
            assert!([10, 20, 30].contains(&c));
            let s = rng.string(2, 6);
            assert!((2..=6).contains(&s.len()));
            assert!(s.chars().all(|ch| (' '..='~').contains(&ch)), "{s:?}");
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        run_cases(3, 10, |rng| {
            assert!(rng.u64(100) < 101); // always true
            assert!(rng.u64(10) > 100); // always false -> must panic
        });
    }
}
