//! In-tree utility substrates. This project builds fully offline from a
//! small vendored crate set (`xla` + `anyhow`), so the usual helpers are
//! implemented here instead of pulled from crates.io:
//!
//! * [`json`] — JSON value model, parser, writer (replaces serde_json);
//! * [`mod@tempdir`] — self-deleting temp dirs (replaces tempfile);
//! * [`mod@bench`] — timing harness + table printer (replaces criterion);
//! * [`proptest`] — seeded property-testing loops (replaces proptest);
//! * [`wire`] — shared binary wire substrate (little-endian
//!   writer/reader, length-prefixed frames, allocation bounds) used by
//!   both the coordinator and serving protocols.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod tempdir;
pub mod wire;

pub use json::Json;
pub use tempdir::{tempdir, TempDir};
