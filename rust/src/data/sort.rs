//! Presorting of numerical columns (paper §2.1).
//!
//! "The most expensive operation when preparing the dataset is the
//! sorting of the numerical attributes. In case of large datasets, this
//! operation is done using external sorting."
//!
//! Two implementations:
//! * [`presort_in_memory`] — sorts the column directly (small columns);
//! * [`ExternalSorter`] — classic external merge sort: the column is cut
//!   into runs that fit in a memory budget, each run is sorted and
//!   spilled to disk as a sorted-column file, and the runs are k-way
//!   merged into the final presorted file. All spill I/O is charged to
//!   the worker's [`IoStats`], which is how the `PS` (presort) terms of
//!   Table 1 get measured.

use super::column::{Column, SortedEntry};
use super::disk::{write_sorted_with, ColumnReader, ColumnWriter, FileKind, Layout};
use super::io_stats::IoStats;
use crate::Result;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::path::{Path, PathBuf};

/// Deterministic ordering for sorted entries: by value, ties by sample
/// index. NaNs sort last (the generators never emit them, but external
/// data might).
#[inline]
fn entry_cmp(a: &SortedEntry, b: &SortedEntry) -> Ordering {
    a.value
        .partial_cmp(&b.value)
        .unwrap_or(Ordering::Equal)
        .then(a.sample.cmp(&b.sample))
}

/// Sort a numerical column in memory into Alg. 1's `q(j)`.
pub fn presort_in_memory(col: &Column) -> Vec<SortedEntry> {
    col.presort()
}

/// External merge sorter for numerical columns larger than RAM.
pub struct ExternalSorter {
    /// Directory for spill runs.
    spill_dir: PathBuf,
    /// Maximum entries held in memory at once.
    run_capacity: usize,
    /// Container layout of the **final** output file (spill runs are
    /// always v1 — they are deleted after the merge).
    out_layout: Layout,
    stats: IoStats,
}

impl ExternalSorter {
    /// `run_capacity` is the in-memory budget in *entries* (8 bytes each).
    pub fn new(spill_dir: &Path, run_capacity: usize, stats: IoStats) -> Self {
        assert!(run_capacity >= 2, "run capacity too small");
        Self {
            spill_dir: spill_dir.to_path_buf(),
            run_capacity,
            out_layout: Layout::V1,
            stats,
        }
    }

    /// Emit the final presorted file in `layout` (e.g. the chunk-tabled
    /// DRFC v2 used by [`super::store::DiskV2Store`]).
    pub fn with_output_layout(mut self, layout: Layout) -> Self {
        self.out_layout = layout;
        self
    }

    /// Sort `values` (row order) into a presorted file at `out`.
    /// Returns the number of spill runs used (1 = in-memory fast path).
    pub fn sort_column(&self, values: &[f32], out: &Path) -> Result<usize> {
        let entries_iter = values.iter().enumerate().map(|(i, &v)| SortedEntry {
            value: v,
            sample: i as u32,
        });
        self.sort_stream(entries_iter, values.len(), out)
    }

    /// Sort an arbitrary entry stream of known length.
    pub fn sort_stream(
        &self,
        entries: impl Iterator<Item = SortedEntry>,
        len: usize,
        out: &Path,
    ) -> Result<usize> {
        // Phase 1: cut into sorted runs.
        let mut runs: Vec<PathBuf> = Vec::new();
        let mut buf: Vec<SortedEntry> = Vec::with_capacity(self.run_capacity.min(len.max(1)));
        let mut entries = entries.peekable();
        while entries.peek().is_some() {
            buf.clear();
            while buf.len() < self.run_capacity {
                match entries.next() {
                    Some(e) => buf.push(e),
                    None => break,
                }
            }
            buf.sort_by(entry_cmp);
            if runs.is_empty() && entries.peek().is_none() {
                // Single run: write final output directly.
                write_sorted_with(out, &buf, self.out_layout, self.stats.clone())?;
                return Ok(1);
            }
            let run_path = self.spill_dir.join(format!("run_{}.drfc", runs.len()));
            write_sorted_with(&run_path, &buf, Layout::V1, self.stats.clone())?;
            runs.push(run_path);
        }
        if runs.is_empty() {
            // Empty input.
            write_sorted_with(out, &[], self.out_layout, self.stats.clone())?;
            return Ok(1);
        }

        // Phase 2: k-way merge with a min-heap over run heads.
        self.merge_runs(&runs, len, out)?;
        for r in &runs {
            let _ = std::fs::remove_file(r);
        }
        Ok(runs.len())
    }

    fn merge_runs(&self, runs: &[PathBuf], len: usize, out: &Path) -> Result<()> {
        struct HeapItem {
            entry: SortedEntry,
            run: usize,
        }
        impl PartialEq for HeapItem {
            fn eq(&self, other: &Self) -> bool {
                entry_cmp(&self.entry, &other.entry) == Ordering::Equal && self.run == other.run
            }
        }
        impl Eq for HeapItem {}
        impl PartialOrd for HeapItem {
            fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
                Some(self.cmp(other))
            }
        }
        impl Ord for HeapItem {
            fn cmp(&self, other: &Self) -> Ordering {
                // Reverse for a min-heap; tie-break on run index for
                // determinism.
                entry_cmp(&other.entry, &self.entry).then(other.run.cmp(&self.run))
            }
        }

        let mut readers: Vec<ColumnReader> = runs
            .iter()
            .map(|p| ColumnReader::open(p, self.stats.clone()))
            .collect::<Result<_>>()?;
        let mut heap = BinaryHeap::new();
        for (i, r) in readers.iter_mut().enumerate() {
            if r.remaining() > 0 {
                heap.push(HeapItem {
                    entry: r.next_sorted()?,
                    run: i,
                });
            }
        }
        let mut w = ColumnWriter::create_with(
            out,
            FileKind::SortedNumerical,
            len as u64,
            self.out_layout,
            self.stats.clone(),
        )?;
        while let Some(item) = heap.pop() {
            w.write_sorted(item.entry)?;
            let r = &mut readers[item.run];
            if r.remaining() > 0 {
                heap.push(HeapItem {
                    entry: r.next_sorted()?,
                    run: item.run,
                });
            }
        }
        for r in &readers {
            r.end_pass();
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn check_sorted(entries: &[SortedEntry]) {
        for w in entries.windows(2) {
            assert!(
                entry_cmp(&w[0], &w[1]) != Ordering::Greater,
                "out of order: {:?} > {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn external_sort_matches_in_memory() {
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let mut rng = Xoshiro256pp::new(1);
        let values: Vec<f32> = (0..10_000).map(|_| rng.next_f64() as f32).collect();
        let col = Column::Numerical(values.clone());
        let expect = presort_in_memory(&col);

        let sorter = ExternalSorter::new(dir.path(), 700, stats.clone());
        let out = dir.path().join("sorted.drfc");
        let runs = sorter.sort_column(&values, &out).unwrap();
        assert!(runs > 1, "should need multiple runs, got {runs}");
        let got = ColumnReader::open(&out, stats).unwrap().read_all_sorted().unwrap();
        assert_eq!(got, expect);
        check_sorted(&got);
    }

    #[test]
    fn single_run_fast_path() {
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let values = vec![3.0f32, 1.0, 2.0];
        let sorter = ExternalSorter::new(dir.path(), 100, stats.clone());
        let out = dir.path().join("s.drfc");
        let runs = sorter.sort_column(&values, &out).unwrap();
        assert_eq!(runs, 1);
        let got = ColumnReader::open(&out, stats).unwrap().read_all_sorted().unwrap();
        assert_eq!(
            got.iter().map(|e| e.sample).collect::<Vec<_>>(),
            vec![1, 2, 0]
        );
    }

    #[test]
    fn empty_column() {
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let sorter = ExternalSorter::new(dir.path(), 10, stats.clone());
        let out = dir.path().join("e.drfc");
        sorter.sort_column(&[], &out).unwrap();
        let got = ColumnReader::open(&out, stats).unwrap().read_all_sorted().unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn duplicate_values_stable_by_sample() {
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let values = vec![1.0f32; 50];
        let sorter = ExternalSorter::new(dir.path(), 7, stats.clone());
        let out = dir.path().join("d.drfc");
        sorter.sort_column(&values, &out).unwrap();
        let got = ColumnReader::open(&out, stats).unwrap().read_all_sorted().unwrap();
        let samples: Vec<u32> = got.iter().map(|e| e.sample).collect();
        assert_eq!(samples, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn v2_output_layout_roundtrips() {
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let mut rng = Xoshiro256pp::new(9);
        let values: Vec<f32> = (0..3000).map(|_| rng.next_f64() as f32).collect();
        let expect = presort_in_memory(&Column::Numerical(values.clone()));
        let sorter = ExternalSorter::new(dir.path(), 500, stats.clone())
            .with_output_layout(Layout::V2 { chunk_rows: 256 });
        let out = dir.path().join("v2.drfc");
        let runs = sorter.sort_column(&values, &out).unwrap();
        assert!(runs > 1);
        let r = ColumnReader::open(&out, stats).unwrap();
        assert_eq!(r.header().version, 2);
        assert_eq!(r.header().chunks.len(), 3000usize.div_ceil(256));
        assert_eq!(r.read_all_sorted().unwrap(), expect);
    }

    #[test]
    fn spill_io_is_accounted() {
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let values: Vec<f32> = (0..1000).map(|i| (999 - i) as f32).collect();
        let sorter = ExternalSorter::new(dir.path(), 100, stats.clone());
        let out = dir.path().join("s.drfc");
        sorter.sort_column(&values, &out).unwrap();
        // Each entry written twice (run + final) at 8 bytes.
        assert!(stats.disk_write_bytes() >= 2 * 8 * 1000);
        assert!(stats.disk_read_bytes() >= 8 * 1000);
    }
}
