//! `drf objstore` — a minimal object-store server for DRFC shard packs.
//!
//! The paper's large-scale runs assume the dataset lives on **remote
//! storage** served to the splitter workers, not on each worker's local
//! disk (§5: workers stream their columns; nothing requires the bytes
//! to be local). This module provides the serving half of that setup: a
//! tiny single-binary object store that exposes **byte-range reads**
//! over files under one root directory, speaking length-prefixed
//! [`crate::util::wire`] frames — the same substrate as the splitter
//! and serving protocols, no new crates.
//!
//! The protocol is deliberately S3-shaped but minimal — exactly what a
//! chunk-aligned [`RemoteStore`](super::remote::RemoteStore) scan
//! needs:
//!
//! * `Stat { path }` → `{ len }` — object size (the truncation check
//!   at open runs against this);
//! * `Read { path, offset, len }` → `{ bytes }` — one contiguous
//!   range, rejected (never silently shortened) if it leaves the file
//!   or exceeds [`MAX_RANGE_BYTES`].
//!
//! Paths are relative to the served root and sanitized (no absolute
//! paths, no `..`, no `\`); a request for anything else gets an error
//! response, not a file. Every served byte is charged to the server's
//! [`IoStats`] as a disk read, so the objstore's own I/O is measurable
//! the same way a splitter's is.
//!
//! **Failure injection** for the "preempted worker / dying storage"
//! tests: [`ObjStoreOptions::fail_after_reads`] makes the server stop
//! serving (close every connection, stop accepting) right *before*
//! answering the Nth `Read` — from the client's point of view an
//! unannounced crash mid-pass. The `drf objstore --fail-after N` CLI
//! additionally exits the process so a supervisor (or a test) can
//! observe the death and restart it.

use super::io_stats::{IoSnapshot, IoStats};
use crate::coordinator::wire::{get_time_sync, put_time_sync};
use crate::telemetry::{adopt_remote_context, time_sync_reply, TimeSyncReply, TraceContext};
use crate::util::wire::{get_trace_context, put_trace_context, read_frame, write_frame, Reader, Writer};
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::io::{BufReader, BufWriter, Read as _, Seek, SeekFrom};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Frame magic of the object-store protocol ("DRF Object").
pub const OBJ_MAGIC: [u8; 4] = *b"DRFO";
/// Object-store protocol version.
pub const OBJ_PROTOCOL: u32 = 1;
/// Hard cap on a single range read. Larger logical fetches are split
/// into multiple requests by the client ([`super::remote`]), so this
/// bounds both server-side allocation and frame sizes well below the
/// wire substrate's frame cap.
pub const MAX_RANGE_BYTES: u32 = 32 * 1024 * 1024;

const OP_STAT: u8 = 1;
const OP_READ: u8 = 2;
const OP_TIMESYNC: u8 = 3;
const RESP_STAT: u8 = 1;
const RESP_DATA: u8 = 2;
const RESP_TIMESYNC: u8 = 3;
const RESP_ERR: u8 = 0xFF;

/// One object-store request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ObjRequest {
    /// Object size of `path` (relative to the served root).
    Stat {
        /// Object name, relative to the served root.
        path: String,
    },
    /// `len` bytes of `path` starting at `offset` (exact — a range
    /// that leaves the object is an error, never a short reply).
    Read {
        /// Object name, relative to the served root.
        path: String,
        /// Byte offset of the range start.
        offset: u64,
        /// Range length in bytes (capped by [`MAX_RANGE_BYTES`]).
        len: u32,
    },
    /// The store's trace clock + identity (clock alignment for
    /// `drf trace merge`).
    TimeSync,
}

/// One object-store response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ObjResponse {
    /// Answer to [`ObjRequest::Stat`].
    Stat {
        /// Object size in bytes.
        len: u64,
    },
    /// Answer to [`ObjRequest::Read`]: exactly the requested bytes.
    Data(Vec<u8>),
    /// Answer to [`ObjRequest::TimeSync`].
    TimeSync(TimeSyncReply),
    /// The request could not be served (bad path, bad range, I/O
    /// error). Permanent — clients must not retry these.
    Err(String),
}

/// Encode a request frame body (no trace context).
pub fn encode_request(req: &ObjRequest) -> Vec<u8> {
    encode_request_traced(req, None)
}

/// Encode a request frame body with the optional trace-context
/// trailer. A `None` context is byte-identical to [`encode_request`] —
/// clients attach context only while tracing is on, so a fleet that
/// never traces speaks exactly the v1 bytes and the protocol version
/// stays 1.
pub fn encode_request_traced(req: &ObjRequest, ctx: Option<&TraceContext>) -> Vec<u8> {
    let mut w = Writer::new();
    w.magic(OBJ_MAGIC);
    w.u32(OBJ_PROTOCOL);
    match req {
        ObjRequest::Stat { path } => {
            w.u8(OP_STAT);
            w.str(path);
        }
        ObjRequest::Read { path, offset, len } => {
            w.u8(OP_READ);
            w.str(path);
            w.u64(*offset);
            w.u32(*len);
        }
        ObjRequest::TimeSync => w.u8(OP_TIMESYNC),
    }
    put_trace_context(&mut w, ctx);
    w.into_bytes()
}

/// Decode a request frame body, discarding any trace context.
pub fn decode_request(frame: &[u8]) -> Result<ObjRequest> {
    Ok(decode_request_traced(frame)?.0)
}

/// Decode a request frame body plus its optional trace-context trailer.
pub fn decode_request_traced(frame: &[u8]) -> Result<(ObjRequest, Option<TraceContext>)> {
    let mut r = Reader::new(frame);
    r.expect_magic(OBJ_MAGIC, "drf objstore")?;
    let protocol = r.u32()?;
    ensure!(
        protocol == OBJ_PROTOCOL,
        "objstore protocol mismatch: peer speaks v{protocol}, this build v{OBJ_PROTOCOL}"
    );
    let req = match r.u8()? {
        OP_STAT => ObjRequest::Stat { path: r.str()? },
        OP_READ => ObjRequest::Read {
            path: r.str()?,
            offset: r.u64()?,
            len: r.u32()?,
        },
        OP_TIMESYNC => ObjRequest::TimeSync,
        op => bail!("unknown objstore opcode {op}"),
    };
    let ctx = get_trace_context(&mut r)?;
    r.done()?;
    Ok((req, ctx))
}

/// Encode a response frame body.
pub fn encode_response(resp: &ObjResponse) -> Vec<u8> {
    let mut w = Writer::new();
    w.magic(OBJ_MAGIC);
    w.u32(OBJ_PROTOCOL);
    match resp {
        ObjResponse::Stat { len } => {
            w.u8(RESP_STAT);
            w.u64(*len);
        }
        ObjResponse::Data(bytes) => {
            w.u8(RESP_DATA);
            w.usize_u32(bytes.len());
            let mut b = w.into_bytes();
            b.extend_from_slice(bytes);
            return b;
        }
        ObjResponse::TimeSync(t) => {
            w.u8(RESP_TIMESYNC);
            put_time_sync(&mut w, t);
        }
        ObjResponse::Err(msg) => {
            w.u8(RESP_ERR);
            w.str(msg);
        }
    }
    w.into_bytes()
}

/// Decode a response frame body.
pub fn decode_response(frame: &[u8]) -> Result<ObjResponse> {
    let mut r = Reader::new(frame);
    r.expect_magic(OBJ_MAGIC, "drf objstore")?;
    let protocol = r.u32()?;
    ensure!(
        protocol == OBJ_PROTOCOL,
        "objstore protocol mismatch: peer speaks v{protocol}, this build v{OBJ_PROTOCOL}"
    );
    let resp = match r.u8()? {
        RESP_STAT => ObjResponse::Stat { len: r.u64()? },
        RESP_DATA => {
            let n = r.len_checked(1)?;
            ObjResponse::Data(r.take(n)?.to_vec())
        }
        RESP_TIMESYNC => ObjResponse::TimeSync(get_time_sync(&mut r)?),
        RESP_ERR => ObjResponse::Err(r.str()?),
        op => bail!("unknown objstore response code {op}"),
    };
    r.done()?;
    Ok(resp)
}

/// Resolve a client-supplied relative path against the served root,
/// rejecting anything that could escape it (absolute paths, `..`/`.`
/// components, backslashes, NULs).
pub fn sanitize_path(root: &Path, path: &str) -> Result<PathBuf> {
    ensure!(!path.is_empty(), "empty object path");
    ensure!(
        !path.starts_with('/') && !path.contains('\\') && !path.contains('\0'),
        "invalid object path {path:?}"
    );
    let mut out = root.to_path_buf();
    for comp in path.split('/') {
        ensure!(
            !comp.is_empty() && comp != "." && comp != "..",
            "invalid object path {path:?}"
        );
        out.push(comp);
    }
    Ok(out)
}

/// Knobs of an object-store server.
#[derive(Debug, Clone, Default)]
pub struct ObjStoreOptions {
    /// Crash-simulation: stop serving (drop every connection, stop
    /// accepting) right before answering the N-th `Read` request —
    /// exactly `N - 1` reads succeed. `None` = serve forever.
    pub fail_after_reads: Option<u64>,
    /// With `fail_after_reads`: additionally exit the whole process
    /// (exit code 0) when the limit fires — only sensible for the
    /// standalone `drf objstore` binary, never for in-process servers.
    pub exit_process_on_limit: bool,
}

/// Shared server state.
struct ObjStoreState {
    root: PathBuf,
    stats: IoStats,
    opts: ObjStoreOptions,
    /// `Read` requests answered so far (drives `fail_after_reads`).
    reads_served: AtomicU64,
    shutdown: AtomicBool,
    /// Live connections (by id), so a simulated crash (or Drop) can
    /// sever them; each connection thread removes its own entry on
    /// exit, so the list stays bounded by *live* connections.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    next_conn_id: AtomicU64,
}

impl ObjStoreState {
    /// Sever every live connection and stop accepting — the simulated
    /// (or real) end of the server.
    fn crash(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for (_, c) in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }

    fn serve_request(&self, req: ObjRequest, conn_io: &IoStats) -> ObjResponse {
        match self.try_serve(req, conn_io) {
            Ok(resp) => resp,
            Err(e) => ObjResponse::Err(format!("{e:#}")),
        }
    }

    fn try_serve(&self, req: ObjRequest, conn_io: &IoStats) -> Result<ObjResponse> {
        match req {
            ObjRequest::TimeSync => Ok(ObjResponse::TimeSync(time_sync_reply())),
            ObjRequest::Stat { path } => {
                let p = sanitize_path(&self.root, &path)?;
                let len = std::fs::metadata(&p)
                    .with_context(|| format!("stat {path}"))?
                    .len();
                Ok(ObjResponse::Stat { len })
            }
            ObjRequest::Read { path, offset, len } => {
                ensure!(
                    len <= MAX_RANGE_BYTES,
                    "range of {len} bytes exceeds the {MAX_RANGE_BYTES}-byte cap"
                );
                let p = sanitize_path(&self.root, &path)?;
                let mut f =
                    std::fs::File::open(&p).with_context(|| format!("opening {path}"))?;
                let flen = f.metadata()?.len();
                ensure!(
                    offset.checked_add(len as u64).is_some_and(|end| end <= flen),
                    "range {offset}+{len} leaves {path} ({flen} bytes)"
                );
                f.seek(SeekFrom::Start(offset))?;
                let mut buf = vec![0u8; len as usize];
                f.read_exact(&mut buf)?;
                // Dual-charge: the process totals live (visible on
                // /metrics mid-connection) and the connection's own
                // counters (summarized at disconnect).
                self.stats.add_disk_read(len as u64);
                conn_io.add_disk_read(len as u64);
                Ok(ObjResponse::Data(buf))
            }
        }
    }
}

/// A running object-store server over one root directory. Dropping it
/// severs every connection and stops the accept loop.
pub struct ObjStoreServer {
    addr: std::net::SocketAddr,
    state: Arc<ObjStoreState>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl ObjStoreServer {
    /// Bind `addr` (`host:0` picks an ephemeral port — see
    /// [`ObjStoreServer::addr`]) and serve byte ranges of the files
    /// under `root`.
    pub fn spawn(
        root: &Path,
        addr: &str,
        stats: IoStats,
        opts: ObjStoreOptions,
    ) -> Result<ObjStoreServer> {
        ensure!(root.is_dir(), "objstore root {} is not a directory", root.display());
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding objstore to {addr}"))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ObjStoreState {
            root: root.to_path_buf(),
            stats,
            opts,
            reads_served: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            next_conn_id: AtomicU64::new(0),
        });
        // Process-level observability: I/O totals (every connection's
        // traffic folds into `state.stats` live), plus live-connection
        // and range-read gauges sampled at scrape time.
        crate::telemetry::register_io_gauges("drf_objstore_io", &state.stats);
        {
            let st = state.clone();
            crate::telemetry::register_gauge_fn("drf_objstore_live_conns", &[], move || {
                st.conns.lock().unwrap().len() as u64
            });
        }
        {
            let st = state.clone();
            crate::telemetry::register_gauge_fn("drf_objstore_reads_served", &[], move || {
                st.reads_served.load(Ordering::SeqCst)
            });
        }
        let state2 = state.clone();
        let accept_handle = std::thread::Builder::new()
            .name("drf-objstore".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if state2.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => {
                            // Transient accept failures must not take
                            // the store down.
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            continue;
                        }
                    };
                    let id = state2.next_conn_id.fetch_add(1, Ordering::SeqCst);
                    if let Ok(clone) = stream.try_clone() {
                        state2.conns.lock().unwrap().push((id, clone));
                    }
                    let state = state2.clone();
                    let _ = std::thread::Builder::new()
                        .name("drf-objstore-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(&state, stream);
                            state.conns.lock().unwrap().retain(|(i, _)| *i != id);
                        });
                }
            })?;
        Ok(ObjStoreServer {
            addr,
            state,
            accept_handle: Some(accept_handle),
        })
    }

    /// The actually bound address (resolves `:0` bindings).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// `Read` requests received so far (including ones answered with
    /// an error, and — under `fail_after_reads` — the final one the
    /// simulated crash left unanswered).
    pub fn reads_served(&self) -> u64 {
        self.state.reads_served.load(Ordering::SeqCst)
    }

    /// Simulate a crash now: sever every connection, stop accepting.
    pub fn crash(&self) {
        self.state.crash();
    }

    /// Process-total I/O counters: disk bytes served plus the wire
    /// traffic of every connection, live (nothing waits for
    /// disconnect).
    pub fn io_totals(&self) -> IoSnapshot {
        self.state.stats.snapshot()
    }
}

impl Drop for ObjStoreServer {
    fn drop(&mut self) {
        self.state.crash();
        // Poke the listener so the accept loop wakes and exits.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// One connection's request loop plus its accounting: the connection
/// gets its own [`IoStats`] whose totals are folded into the telemetry
/// registry when it closes (historically those counts were simply
/// dropped on disconnect).
fn serve_connection(state: &ObjStoreState, stream: TcpStream) -> Result<()> {
    let conn_io = IoStats::new();
    let mut requests = 0u64;
    let result = serve_requests(state, stream, &conn_io, &mut requests);
    let s = conn_io.snapshot();
    crate::telemetry::counter("drf_objstore_conns_closed_total").inc();
    crate::telemetry::histogram("drf_objstore_conn_net_bytes").observe(s.net_bytes);
    crate::telemetry::histogram("drf_objstore_conn_disk_read_bytes").observe(s.disk_read_bytes);
    crate::telemetry::histogram("drf_objstore_conn_requests").observe(requests);
    result
}

fn serve_requests(
    state: &ObjStoreState,
    stream: TcpStream,
    conn_io: &IoStats,
    requests: &mut u64,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        if state.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        // Frame accounting mirrors the client side: each direction is
        // one message of payload + 4 length-prefix bytes, charged both
        // to the process totals and to this connection.
        state.stats.add_net(frame.len() as u64 + 4);
        conn_io.add_net(frame.len() as u64 + 4);
        *requests += 1;
        let req_start = std::time::Instant::now();
        let mut op = "invalid";
        let response = match decode_request_traced(&frame) {
            Err(e) => ObjResponse::Err(format!("bad request: {e}")),
            Ok((req, ctx)) => {
                op = match req {
                    ObjRequest::Stat { .. } => "stat",
                    ObjRequest::Read { .. } => "read",
                    ObjRequest::TimeSync => "timesync",
                };
                // Serve under the caller's span (if it sent context) so
                // objstore time shows up inside the fetch that caused
                // it in the merged timeline.
                let _trace = adopt_remote_context(ctx.as_ref());
                let _span = match op {
                    "stat" => Some(crate::span!("obj_stat")),
                    "read" => Some(crate::span!("obj_read")),
                    _ => None,
                };
                if matches!(req, ObjRequest::Read { .. }) {
                    // This is range read number `k` (1-based) across
                    // all connections.
                    let k = state.reads_served.fetch_add(1, Ordering::SeqCst) + 1;
                    if let Some(limit) = state.opts.fail_after_reads {
                        // Die right before the limit-th read is
                        // answered: exactly `limit - 1` reads succeed.
                        if k >= limit {
                            // Die *before* answering — from the client's
                            // point of view, an unannounced crash.
                            state.crash();
                            if state.opts.exit_process_on_limit {
                                println!(
                                    "drf objstore: --fail-after limit reached, exiting"
                                );
                                let _ = std::io::Write::flush(&mut std::io::stdout());
                                std::process::exit(0);
                            }
                            return Ok(());
                        }
                    }
                }
                state.serve_request(req, conn_io)
            }
        };
        crate::telemetry::counter_with("drf_objstore_requests_total", &[("op", op)]).inc();
        crate::telemetry::histogram_with("drf_objstore_request_us", &[("op", op)])
            .observe(req_start.elapsed().as_micros() as u64);
        let resp_bytes = encode_response(&response);
        state.stats.add_net(resp_bytes.len() as u64 + 4);
        conn_io.add_net(resp_bytes.len() as u64 + 4);
        write_frame(&mut writer, &resp_bytes)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(stream: &TcpStream, req: &ObjRequest) -> ObjResponse {
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        write_frame(&mut w, &encode_request(req)).unwrap();
        decode_response(&read_frame(&mut r).unwrap()).unwrap()
    }

    #[test]
    fn codec_roundtrips() {
        let ctx = TraceContext {
            trace_id: 0x5EED,
            parent_span: 0xFACE,
        };
        for req in [
            ObjRequest::Stat { path: "a/b.drfc".into() },
            ObjRequest::Read { path: "x".into(), offset: 7, len: 9 },
            ObjRequest::TimeSync,
        ] {
            assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
            // Context-free traced frames are byte-identical; contextful
            // ones round-trip and stay decodable context-obliviously.
            assert_eq!(encode_request_traced(&req, None), encode_request(&req));
            let traced = encode_request_traced(&req, Some(&ctx));
            assert_eq!(
                decode_request_traced(&traced).unwrap(),
                (req.clone(), Some(ctx))
            );
            assert_eq!(decode_request(&traced).unwrap(), req);
        }
        for resp in [
            ObjResponse::Stat { len: 1 << 40 },
            ObjResponse::Data(vec![1, 2, 3]),
            ObjResponse::TimeSync(TimeSyncReply {
                role: "objstore".into(),
                shard: None,
                pid: 99,
                t_us: 1234,
            }),
            ObjResponse::Err("nope".into()),
        ] {
            assert_eq!(decode_response(&encode_response(&resp)).unwrap(), resp);
        }
    }

    #[test]
    fn path_sanitization() {
        let root = Path::new("/srv/data");
        assert!(sanitize_path(root, "col_0.drfc").is_ok());
        assert!(sanitize_path(root, "shard_1/col_0.drfc").is_ok());
        for bad in ["", "/etc/passwd", "../x", "a/../b", "a/./b", "a//b", "a\\b", "a\0b"] {
            assert!(sanitize_path(root, bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn serves_stats_and_ranges() {
        let dir = crate::util::tempdir().unwrap();
        std::fs::write(dir.path().join("obj"), b"0123456789").unwrap();
        let stats = IoStats::new();
        let server = ObjStoreServer::spawn(
            dir.path(),
            "127.0.0.1:0",
            stats.clone(),
            ObjStoreOptions::default(),
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();

        match roundtrip(&stream, &ObjRequest::Stat { path: "obj".into() }) {
            ObjResponse::Stat { len } => assert_eq!(len, 10),
            r => panic!("expected Stat, got {r:?}"),
        }
        match roundtrip(&stream, &ObjRequest::Read { path: "obj".into(), offset: 3, len: 4 }) {
            ObjResponse::Data(b) => assert_eq!(b, b"3456"),
            r => panic!("expected Data, got {r:?}"),
        }
        assert_eq!(stats.disk_read_bytes(), 4);
        assert_eq!(server.reads_served(), 1);
        // Wire traffic aggregates into the process totals live (it
        // used to vanish with the connection): 2 frames per exchange,
        // 2 exchanges so far.
        assert!(stats.net_bytes() > 0);
        let totals = server.io_totals();
        assert_eq!(totals.disk_read_bytes, 4);
        assert_eq!(totals.net_bytes, stats.net_bytes());
        assert_eq!(totals.net_messages, 4);

        // A range leaving the object is an error, never a short reply.
        match roundtrip(&stream, &ObjRequest::Read { path: "obj".into(), offset: 8, len: 4 }) {
            ObjResponse::Err(msg) => assert!(msg.contains("leaves"), "{msg}"),
            r => panic!("expected Err, got {r:?}"),
        }
        // Traversal is refused at the protocol layer.
        match roundtrip(&stream, &ObjRequest::Read { path: "../obj".into(), offset: 0, len: 1 }) {
            ObjResponse::Err(msg) => assert!(msg.contains("invalid object path"), "{msg}"),
            r => panic!("expected Err, got {r:?}"),
        }
        // Missing objects error cleanly.
        match roundtrip(&stream, &ObjRequest::Stat { path: "missing".into() }) {
            ObjResponse::Err(msg) => assert!(msg.contains("stat"), "{msg}"),
            r => panic!("expected Err, got {r:?}"),
        }
    }

    #[test]
    fn fail_after_reads_severs_connections() {
        let dir = crate::util::tempdir().unwrap();
        std::fs::write(dir.path().join("obj"), vec![7u8; 64]).unwrap();
        let server = ObjStoreServer::spawn(
            dir.path(),
            "127.0.0.1:0",
            IoStats::new(),
            ObjStoreOptions {
                fail_after_reads: Some(3),
                exit_process_on_limit: false,
            },
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        // Reads 1 and 2 are answered (fail-after 3 = die before the
        // 3rd, as the docs promise).
        for _ in 0..2 {
            match roundtrip(&stream, &ObjRequest::Read { path: "obj".into(), offset: 0, len: 8 }) {
                ObjResponse::Data(b) => assert_eq!(b.len(), 8),
                r => panic!("expected Data, got {r:?}"),
            }
        }
        // The third read hits the limit: the server dies without
        // answering; the client sees a dead connection.
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        write_frame(
            &mut w,
            &encode_request(&ObjRequest::Read { path: "obj".into(), offset: 0, len: 8 }),
        )
        .unwrap();
        let mut r = BufReader::new(stream);
        assert!(read_frame(&mut r).is_err(), "crashed server must not answer");
    }
}
