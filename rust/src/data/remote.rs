//! Remote object-store [`ColumnStore`] backend.
//!
//! The paper's 18B-example deployments do not copy the dataset onto
//! every splitter's local disk — shards live on remote storage and are
//! **streamed** to the workers, which only ever read their columns
//! sequentially (§2). [`RemoteStore`] implements exactly that access
//! pattern over the wire: each scan is a sequence of **chunk-aligned
//! byte-range reads** against a [`drf objstore`](super::objserve)
//! server, driven by the DRFC header's own chunk table, so a pass over
//! an arbitrarily large remote column runs in constant memory and
//! fetches each byte exactly once.
//!
//! What the backend guarantees:
//!
//! * **Validation at open** — like every other backend, the DRFC
//!   header (magic/version/kind/chunk table) is fetched and validated
//!   before any scan, and the remote object's size must cover the
//!   declared rows ([`Header::ensure_untruncated`] against the
//!   server's `Stat`).
//! * **Checksummed passes** — when opened with the shard manifest's
//!   FNV-1a checksums (the cluster path), every *complete* pass folds
//!   the fetched bytes through the same streaming FNV-1a as
//!   [`checksum_file`](crate::cluster::manifest::checksum_file) and
//!   rejects the pass on mismatch — a corrupted or tampered fetch
//!   cannot silently train.
//! * **Exact range replies** — a reply shorter (or longer) than the
//!   requested range is a protocol violation and is rejected
//!   immediately, never padded or silently accepted.
//! * **Bounded retry with backoff** — transient fetch failures
//!   (connection refused/reset, a restarting objstore) are retried
//!   with exponential backoff up to [`RemoteOptions::retries`]
//!   attempts; because every chunk is an independent range read, a
//!   retried pass **resumes at the chunk boundary it had reached** —
//!   nothing already visited is re-fetched or re-visited.
//! * **Resumable passes** — [`RemoteStore::scan_raw_from`] /
//!   [`RemoteStore::scan_sorted_from`] start a pass at any chunk
//!   boundary of the v2 chunk table: the "preempted worker" scenario,
//!   where a worker dies mid-column and its replacement continues from
//!   the last completed chunk instead of re-reading the prefix.
//! * **Prefetch pipeline** — with
//!   [`RemoteStore::with_prefetch`]` > 0`, a background fetcher pulls
//!   chunk `N+1` over the wire while the visitor consumes chunk `N`
//!   (bounded channel, order-preserving, hence deterministic) — the
//!   same double-buffering discipline as the streaming disk backends.
//!
//! Accounting mirrors the disk backends so the Table 1 columns stay
//! comparable: the header is charged to [`IoStats`] disk reads at
//! open, each record byte once per pass, one read pass per completed
//! scan. Additionally every wire frame is charged to the *network*
//! counters (`net_bytes`/`net_messages`) — the paper's network-cost
//! column, measured instead of modeled.

use super::column::SortedEntry;
use super::disk::{self, FileKind, Header};
use super::io_stats::IoStats;
use super::objserve::{
    decode_response, encode_request, encode_request_traced, ObjRequest, ObjResponse,
    MAX_RANGE_BYTES,
};
use super::schema::{ColumnType, Schema};
use super::store::{ColumnStore, RawChunk};
use crate::cluster::manifest::{checksum_update, CHECKSUM_INIT};
use crate::telemetry::{
    clock_sync_exchange, current_context, record_clock_sync, trace_enabled, TimeSyncReply,
};
use crate::util::wire::{read_frame, write_frame};
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ---------------------------------------------------------------------
// Client: address, retry policy, per-pass sessions
// ---------------------------------------------------------------------

/// Retry/backoff policy of a [`RemoteClient`].
#[derive(Debug, Clone)]
pub struct RemoteOptions {
    /// Maximum attempts per range read (min 1). Transient transport
    /// errors reconnect and re-issue the request; server-side `Err`
    /// responses are permanent and never retried.
    pub retries: u32,
    /// Delay before the first retry; doubles per attempt.
    pub backoff: Duration,
    /// Cap on the per-attempt delay.
    pub max_backoff: Duration,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        // Total retry budget ~6.5s (25ms doubling to a 1s cap): long
        // enough for a supervisor to restart a crashed objstore on the
        // same address (the crash drill in tests/storage_backends.rs
        // allows the restart up to 5s), short enough that a genuinely
        // dead store still fails the pass promptly.
        Self {
            retries: 12,
            backoff: Duration::from_millis(25),
            max_backoff: Duration::from_millis(1000),
        }
    }
}

struct ClientInner {
    /// Objstore replica set + index of the active replica. A `Mutex`
    /// so a supervisor can redirect in-flight stores to a rescheduled
    /// server ([`RemoteClient::set_addr`]) and so failed requests can
    /// rotate to the next replica ([`ClientInner::advance`]).
    addrs: Mutex<(Vec<String>, usize)>,
    opts: RemoteOptions,
    /// Network accounting (every request/response frame).
    stats: IoStats,
}

impl ClientInner {
    /// Rotate the active replica after a failed attempt. A no-op with a
    /// single address (the classic retry-the-same-store behavior);
    /// with replicas, each failed attempt moves the shared pointer one
    /// step around the ring so the very next reconnect — on every
    /// session of this client — tries a different store.
    fn advance(&self) {
        let mut g = self.addrs.lock().unwrap();
        let n = g.0.len();
        if n > 1 {
            g.1 = (g.1 + 1) % n;
            crate::telemetry::counter("drf_remote_failovers_total").inc();
        }
    }
}

/// Handle to one objstore replica set: addresses + retry policy + net
/// accounting. Cheap to clone; all clones share the replica pointer
/// (and follow redirects and failovers together).
#[derive(Clone)]
pub struct RemoteClient {
    inner: Arc<ClientInner>,
}

/// Split a comma-separated `host:port[,host:port...]` list.
fn parse_addr_list(addr: &str) -> Vec<String> {
    let list: Vec<String> = addr
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    if list.is_empty() {
        // Preserve the old single-address behavior for odd input: the
        // connect attempt reports the real error.
        vec![addr.to_string()]
    } else {
        list
    }
}

impl RemoteClient {
    /// A client for the objstore(s) at `addr` — a `host:port` address
    /// or a comma-separated replica list in failover order — charging
    /// wire traffic to `stats`.
    pub fn new(addr: &str, opts: RemoteOptions, stats: IoStats) -> RemoteClient {
        RemoteClient {
            inner: Arc::new(ClientInner {
                addrs: Mutex::new((parse_addr_list(addr), 0)),
                opts,
                stats,
            }),
        }
    }

    /// Redirect every session (current and future) to a new objstore
    /// address (or comma-separated replica list) — the storage analog
    /// of the cluster pool's `set_worker_addr` for rescheduled
    /// workers. Live sessions pick the new address up on their next
    /// reconnect.
    pub fn set_addr(&self, addr: &str) {
        *self.inner.addrs.lock().unwrap() = (parse_addr_list(addr), 0);
    }

    /// The currently-active objstore address.
    pub fn addr(&self) -> String {
        let g = self.inner.addrs.lock().unwrap();
        g.0[g.1].clone()
    }

    /// The full replica list, in failover order.
    pub fn addrs(&self) -> Vec<String> {
        self.inner.addrs.lock().unwrap().0.clone()
    }

    /// Open a session (one connection, lazily established). Scans use
    /// one session per pass so concurrent column scans never serialize
    /// on a shared socket.
    pub fn session(&self) -> RemoteSession {
        RemoteSession {
            client: self.clone(),
            conn: None,
        }
    }
}

/// One connection's request/response loop, with reconnect-and-retry.
pub struct RemoteSession {
    client: RemoteClient,
    conn: Option<(BufReader<TcpStream>, BufWriter<TcpStream>)>,
}

impl RemoteSession {
    /// One request/response exchange on the current connection
    /// (establishing it if needed). Any transport error invalidates
    /// the connection.
    fn try_request(&mut self, body: &[u8]) -> Result<Vec<u8>> {
        if self.conn.is_none() {
            let addr = self.client.addr();
            let stream = TcpStream::connect(&addr)
                .with_context(|| format!("connecting to objstore at {addr}"))?;
            let _ = stream.set_nodelay(true);
            self.conn = Some((BufReader::new(stream.try_clone()?), BufWriter::new(stream)));
            // With tracing active, estimate the store's clock offset on
            // every fresh connection (a restarted store has a fresh
            // clock epoch) so `drf trace merge` can align its spans.
            if trace_enabled() {
                let sync_body = encode_request(&ObjRequest::TimeSync);
                let stats = self.client.inner.stats.clone();
                let (reader, writer) = self.conn.as_mut().expect("connected above");
                let peer = clock_sync_exchange(2, || -> Result<TimeSyncReply> {
                    write_frame(writer, &sync_body)?;
                    let frame = read_frame(reader)?;
                    stats.add_net(sync_body.len() as u64 + 4);
                    stats.add_net(frame.len() as u64 + 4);
                    match decode_response(&frame)? {
                        ObjResponse::TimeSync(t) => Ok(t),
                        r => bail!("protocol confusion: {r:?} reply to a TimeSync"),
                    }
                })?;
                record_clock_sync(&peer);
            }
        }
        let (reader, writer) = self.conn.as_mut().expect("connected above");
        write_frame(writer, body)?;
        read_frame(reader)
    }

    /// Issue `req`, retrying transient transport failures with bounded
    /// exponential backoff (each retry reconnects, so a restarted — or
    /// redirected — objstore is picked up transparently). With tracing
    /// active the request carries this thread's trace context, so
    /// store-side spans parent under the span doing the fetch.
    fn request(&mut self, req: &ObjRequest) -> Result<ObjResponse> {
        let ctx = current_context();
        let body = encode_request_traced(req, ctx.as_ref());
        let (retries, backoff, max_backoff) = {
            let o = &self.client.inner.opts;
            (o.retries.max(1), o.backoff, o.max_backoff)
        };
        let mut delay = backoff;
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..retries {
            if attempt > 0 {
                crate::telemetry::counter("drf_remote_retries_total").inc();
                std::thread::sleep(delay);
                delay = (delay * 2).min(max_backoff);
            }
            let attempt_start = std::time::Instant::now();
            match self.try_request(&body) {
                Ok(frame) => {
                    let stats = &self.client.inner.stats;
                    stats.add_net(body.len() as u64 + 4);
                    stats.add_net(frame.len() as u64 + 4);
                    crate::telemetry::histogram("drf_remote_fetch_us")
                        .observe(attempt_start.elapsed().as_micros() as u64);
                    return decode_response(&frame);
                }
                Err(e) => {
                    self.conn = None;
                    // With a replica set, a failed attempt moves the
                    // shared pointer to the next store before the
                    // retry reconnects.
                    self.client.inner.advance();
                    last = Some(e);
                }
            }
        }
        Err(last.expect("at least one attempt ran")).with_context(|| {
            format!(
                "objstore at {} unreachable after {retries} attempts",
                self.client.addr()
            )
        })
    }

    /// Object size of `path`.
    pub fn stat(&mut self, path: &str) -> Result<u64> {
        match self.request(&ObjRequest::Stat { path: path.to_string() })? {
            ObjResponse::Stat { len } => Ok(len),
            ObjResponse::Err(msg) => bail!("objstore error stating {path}: {msg}"),
            ObjResponse::Data(_) => bail!("protocol confusion: Data reply to a Stat"),
        }
    }

    /// Fetch exactly `len` bytes of `path` starting at `offset`,
    /// splitting into [`MAX_RANGE_BYTES`] range reads as needed. A
    /// reply of the wrong length is rejected as a protocol violation
    /// (never retried, never padded).
    pub fn fetch_exact(&mut self, path: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        let mut off = offset;
        let mut remaining = len;
        while remaining > 0 {
            let step = remaining.min(MAX_RANGE_BYTES as u64) as u32;
            match self.request(&ObjRequest::Read {
                path: path.to_string(),
                offset: off,
                len: step,
            })? {
                ObjResponse::Data(b) => {
                    ensure!(
                        b.len() == step as usize,
                        "{path}: truncated range reply — asked for {step} bytes \
                         at offset {off}, got {}",
                        b.len()
                    );
                    out.extend_from_slice(&b);
                }
                ObjResponse::Err(msg) => bail!("objstore error reading {path} at {off}: {msg}"),
                ObjResponse::Stat { .. } => bail!("protocol confusion: Stat reply to a Read"),
            }
            off += step as u64;
            remaining -= step as u64;
        }
        Ok(out)
    }

    /// Fetch a whole object (stat, then ranged reads).
    pub fn fetch_all(&mut self, path: &str) -> Result<Vec<u8>> {
        let len = self.stat(path)?;
        self.fetch_exact(path, 0, len)
    }
}

// ---------------------------------------------------------------------
// RemoteStore
// ---------------------------------------------------------------------

/// What a [`RemoteStore`] needs to know about one column before
/// opening it: remote object names, the declared type, and (for
/// manifest-backed packs) the expected whole-file checksums.
#[derive(Debug, Clone)]
pub struct RemoteColumnSpec {
    /// Global column index (the schema's numbering).
    pub index: usize,
    /// Remote object name of the raw column file.
    pub raw: String,
    /// Remote object name of the presorted file (numerical columns).
    pub sorted: Option<String>,
    /// Declared column type (validated against the fetched header).
    pub ctype: ColumnType,
    /// Expected FNV-1a of the raw file; `None` skips verification.
    pub raw_checksum: Option<u64>,
    /// Expected FNV-1a of the presorted file.
    pub sorted_checksum: Option<u64>,
}

/// Byte/record location of one chunk of a remote file.
#[derive(Debug, Clone, Copy)]
struct ChunkLoc {
    records: usize,
    byte_off: u64,
    base_row: usize,
}

/// One remote DRFC file, header-validated at open.
struct RemoteFile {
    path: String,
    header: Header,
    /// The exact serialized header bytes (seed of the whole-file
    /// checksum fold — FNV covers the header too).
    header_bytes: Vec<u8>,
    /// Expected whole-file FNV-1a (`None` = no verification).
    checksum: Option<u64>,
    chunks: Vec<ChunkLoc>,
}

struct RemoteColumn {
    ctype: ColumnType,
    raw: RemoteFile,
    sorted: Option<RemoteFile>,
}

/// [`ColumnStore`] over a `drf objstore`: chunk-aligned range reads,
/// checksummed complete passes, bounded retry, resumable scans, and an
/// optional background prefetch pipeline. See the module docs for the
/// guarantees.
pub struct RemoteStore {
    client: RemoteClient,
    columns: BTreeMap<usize, RemoteColumn>,
    stats: IoStats,
    prefetch_chunks: usize,
}

/// Fetch and validate the DRFC header of `path`: magic, version, kind
/// (against `expected`), chunk-table consistency, and the truncation
/// check against the server-reported object size. Returns the parsed
/// header and its exact serialized bytes (the seed of whole-file
/// checksum folds).
fn fetch_header(
    sess: &mut RemoteSession,
    path: &str,
    expected: FileKind,
) -> Result<(Header, Vec<u8>)> {
    let file_len = sess.stat(path)?;
    ensure!(
        file_len >= 20,
        "{path}: {file_len} bytes is too short for a DRFC header"
    );
    let mut head = sess.fetch_exact(path, 0, 20)?;
    let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if version == 2 {
        ensure!(file_len >= 24, "{path}: v2 header truncated");
        let nb = sess.fetch_exact(path, 20, 4)?;
        let n = u32::from_le_bytes(nb[0..4].try_into().unwrap()) as u64;
        // The table must physically fit in the object — reject forged
        // counts before fetching (Header::parse re-validates the sums).
        ensure!(
            24u64.checked_add(n * 4).is_some_and(|end| end <= file_len),
            "{path}: chunk table of {n} entries does not fit in {file_len} bytes"
        );
        head.extend_from_slice(&nb);
        if n > 0 {
            let table = sess.fetch_exact(path, 24, n * 4)?;
            head.extend_from_slice(&table);
        }
    }
    let header = Header::parse(&head)
        .with_context(|| format!("parsing remote header of {path}"))?;
    header.ensure_untruncated(file_len, Path::new(path))?;
    ensure!(
        header.kind == expected,
        "{path}: object holds {:?} records, caller expects {expected:?}",
        header.kind
    );
    Ok((header, head))
}

/// Precompute the byte/record location of every chunk of `header`'s
/// full-pass plan.
fn chunk_locs(header: &Header) -> Vec<ChunkLoc> {
    let rb = header.kind.record_bytes() as u64;
    let mut off = header.nbytes();
    let mut base = 0usize;
    header
        .chunk_plan()
        .into_iter()
        .map(|records| {
            let c = ChunkLoc {
                records,
                byte_off: off,
                base_row: base,
            };
            off += records as u64 * rb;
            base += records;
            c
        })
        .collect()
}

impl RemoteStore {
    /// Open the columns described by `specs` against `client`'s
    /// objstore: every header is fetched and validated up front
    /// (charged to `stats` like a local open); scans then stream the
    /// objects by chunk-aligned range reads.
    pub fn open(
        client: RemoteClient,
        specs: Vec<RemoteColumnSpec>,
        stats: IoStats,
    ) -> Result<RemoteStore> {
        let mut sess = client.session();
        let mut columns = BTreeMap::new();
        for s in specs {
            let expected = match s.ctype {
                ColumnType::Numerical => FileKind::Numerical,
                ColumnType::Categorical { .. } => FileKind::Categorical,
            };
            let (header, header_bytes) = fetch_header(&mut sess, &s.raw, expected)?;
            stats.add_disk_read(header.nbytes());
            let raw = RemoteFile {
                chunks: chunk_locs(&header),
                path: s.raw,
                header,
                header_bytes,
                checksum: s.raw_checksum,
            };
            let sorted = match s.sorted {
                None => None,
                Some(path) => {
                    let (header, header_bytes) =
                        fetch_header(&mut sess, &path, FileKind::SortedNumerical)?;
                    stats.add_disk_read(header.nbytes());
                    Some(RemoteFile {
                        chunks: chunk_locs(&header),
                        path,
                        header,
                        header_bytes,
                        checksum: s.sorted_checksum,
                    })
                }
            };
            columns.insert(
                s.index,
                RemoteColumn {
                    ctype: s.ctype,
                    raw,
                    sorted,
                },
            );
        }
        Ok(RemoteStore {
            client,
            columns,
            stats,
            prefetch_chunks: 0,
        })
    }

    /// Enable the background prefetch pipeline: a fetcher thread pulls
    /// up to `chunks` range reads ahead of the scan visitor (0
    /// disables). Order-preserving, so results and accounting are
    /// unchanged.
    pub fn with_prefetch(mut self, chunks: usize) -> Self {
        self.prefetch_chunks = chunks;
        self
    }

    /// Redirect to a rescheduled objstore (see [`RemoteClient::set_addr`]).
    pub fn set_addr(&self, addr: &str) {
        self.client.set_addr(addr);
    }

    fn col(&self, j: usize) -> Result<&RemoteColumn> {
        self.columns
            .get(&j)
            .ok_or_else(|| anyhow::anyhow!("store lacks column {j}"))
    }

    /// Per-chunk record counts of column `j`'s raw file — the resume
    /// coordinates for [`Self::scan_raw_from`].
    pub fn chunk_table(&self, j: usize) -> Result<Vec<usize>> {
        Ok(self.col(j)?.raw.chunks.iter().map(|c| c.records).collect())
    }

    /// Per-chunk record counts of column `j`'s presorted file.
    pub fn sorted_chunk_table(&self, j: usize) -> Result<Vec<usize>> {
        let col = self.col(j)?;
        let f = col
            .sorted
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("column {j} has no presorted object"))?;
        Ok(f.chunks.iter().map(|c| c.records).collect())
    }

    /// One pass over `file` starting at `start_chunk`: fetch each
    /// chunk (optionally through the prefetch pipeline), decode,
    /// visit. Complete passes (`start_chunk == 0`) fold the FNV-1a of
    /// header + payload and reject a checksum mismatch at the end of
    /// the pass; resumed passes skip verification (they never see the
    /// prefix). Reaching the end of the file counts one read pass.
    fn scan_records<T>(
        &self,
        file: &RemoteFile,
        start_chunk: usize,
        decode: impl Fn(&[u8], &mut Vec<T>),
        mut visit: impl FnMut(usize, &[T]) -> Result<()>,
    ) -> Result<()> {
        ensure!(
            start_chunk <= file.chunks.len(),
            "{}: resume chunk {start_chunk} beyond the {}-chunk table",
            file.path,
            file.chunks.len()
        );
        let record_bytes = file.header.kind.record_bytes();
        let verify = start_chunk == 0 && file.checksum.is_some();
        let mut hash = checksum_update(CHECKSUM_INIT, &file.header_bytes);
        let chunks = &file.chunks[start_chunk..];
        let mut buf: Vec<T> = Vec::new();
        let mut consume = |bytes: Vec<u8>, loc: &ChunkLoc| -> Result<()> {
            if verify {
                hash = checksum_update(hash, &bytes);
            }
            self.stats.add_disk_read(bytes.len() as u64);
            decode(&bytes, &mut buf);
            visit(loc.base_row, &buf)
        };
        if self.prefetch_chunks == 0 {
            let mut sess = self.client.session();
            for loc in chunks {
                let bytes =
                    sess.fetch_exact(&file.path, loc.byte_off, (loc.records * record_bytes) as u64)?;
                consume(bytes, loc)?;
            }
        } else {
            // Background fetcher: pull chunk N+1 over the wire while
            // the visitor consumes chunk N (bounded, order-preserving,
            // hence deterministic — the remote twin of the disk
            // backends' prefetch pipeline).
            std::thread::scope(|scope| -> Result<()> {
                let (tx, rx) = std::sync::mpsc::sync_channel::<Result<Vec<u8>>>(
                    self.prefetch_chunks.max(1),
                );
                let client = &self.client;
                let path = &file.path;
                scope.spawn(move || {
                    let mut sess = client.session();
                    for loc in chunks {
                        let fetched = sess.fetch_exact(
                            path,
                            loc.byte_off,
                            (loc.records * record_bytes) as u64,
                        );
                        let failed = fetched.is_err();
                        if tx.send(fetched).is_err() || failed {
                            return; // consumer bailed, or the fetch died
                        }
                    }
                });
                // Drain in chunk order (zip-with-`rx` semantics: stop
                // if the fetcher is gone), probing non-blockingly first
                // so the prefetch hit rate is observable: a chunk that
                // is already buffered when the visitor wants it is a
                // hit, one the visitor must wait for is a miss.
                for loc in chunks {
                    let msg = match rx.try_recv() {
                        Ok(m) => {
                            crate::telemetry::counter("drf_remote_prefetch_hits_total").inc();
                            m
                        }
                        Err(std::sync::mpsc::TryRecvError::Empty) => {
                            crate::telemetry::counter("drf_remote_prefetch_misses_total").inc();
                            match rx.recv() {
                                Ok(m) => m,
                                Err(_) => break,
                            }
                        }
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
                    };
                    consume(msg?, loc)?;
                }
                Ok(())
            })?;
        }
        if verify {
            let expected = file.checksum.expect("verify implies Some");
            ensure!(
                hash == expected,
                "{}: remote column failed its manifest checksum \
                 (fetched {hash:016x}, manifest says {expected:016x})",
                file.path
            );
        }
        // The scan reached the end of the object: one completed pass.
        self.stats.add_read_pass();
        Ok(())
    }

    /// Resume a raw-column pass at chunk boundary `start_chunk` of the
    /// chunk table (0 = full pass; see [`Self::chunk_table`]). The
    /// visitor's `base_row` values are the true row offsets, so a
    /// preempted pass's consumer state composes seamlessly.
    pub fn scan_raw_from(
        &self,
        j: usize,
        start_chunk: usize,
        visit: &mut dyn FnMut(usize, RawChunk<'_>) -> Result<()>,
    ) -> Result<()> {
        let col = self.col(j)?;
        match col.ctype {
            ColumnType::Numerical => self.scan_records(
                &col.raw,
                start_chunk,
                disk::decode_f32,
                |base, chunk: &[f32]| visit(base, RawChunk::Numerical(chunk)),
            ),
            ColumnType::Categorical { .. } => self.scan_records(
                &col.raw,
                start_chunk,
                disk::decode_u32,
                |base, chunk: &[u32]| visit(base, RawChunk::Categorical(chunk)),
            ),
        }
    }

    /// Resume a presorted pass at chunk boundary `start_chunk` (see
    /// [`Self::sorted_chunk_table`]).
    pub fn scan_sorted_from(
        &self,
        j: usize,
        start_chunk: usize,
        visit: &mut dyn FnMut(&[SortedEntry]) -> Result<()>,
    ) -> Result<()> {
        let col = self.col(j)?;
        let f = col
            .sorted
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("column {j} has no presorted object"))?;
        self.scan_records(f, start_chunk, disk::decode_sorted, |_base, chunk| {
            visit(chunk)
        })
    }
}

impl ColumnStore for RemoteStore {
    fn columns(&self) -> Vec<usize> {
        self.columns.keys().copied().collect()
    }

    fn column_type(&self, j: usize) -> Result<ColumnType> {
        Ok(self.col(j)?.ctype)
    }

    fn scan_raw(
        &self,
        j: usize,
        visit: &mut dyn FnMut(usize, RawChunk<'_>) -> Result<()>,
    ) -> Result<()> {
        self.scan_raw_from(j, 0, visit)
    }

    fn scan_sorted(
        &self,
        j: usize,
        visit: &mut dyn FnMut(&[SortedEntry]) -> Result<()>,
    ) -> Result<()> {
        self.scan_sorted_from(j, 0, visit)
    }
}

/// Remote store for `columns` of a dataset-directory layout
/// (`col_<j>.drfc` / `col_<j>.sorted.drfc`, as written by
/// [`save_dataset`](super::store::save_dataset) and served by
/// `drf objstore --dir`): the storage the manager builds for
/// `--storage remote`. No manifest, so no checksums — the cluster
/// worker path ([`crate::cluster::load_shard_remote`]) is the
/// checksummed one.
pub fn remote_store_for(
    addr: &str,
    schema: &Schema,
    columns: &[usize],
    stats: IoStats,
    prefetch_chunks: usize,
) -> Result<Arc<dyn ColumnStore>> {
    let specs = columns
        .iter()
        .map(|&j| {
            let spec = schema
                .columns
                .get(j)
                .ok_or_else(|| anyhow::anyhow!("column {j} is not in the schema"))?;
            Ok(RemoteColumnSpec {
                index: j,
                raw: format!("col_{j}.drfc"),
                sorted: spec
                    .ctype
                    .is_numerical()
                    .then(|| format!("col_{j}.sorted.drfc")),
                ctype: spec.ctype,
                raw_checksum: None,
                sorted_checksum: None,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let client = RemoteClient::new(addr, RemoteOptions::default(), stats.clone());
    Ok(Arc::new(
        RemoteStore::open(client, specs, stats)?.with_prefetch(prefetch_chunks),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::objserve::{ObjStoreOptions, ObjStoreServer};
    use crate::data::store::save_dataset_with;
    use crate::data::synthetic::LeoLikeSpec;
    use crate::data::Dataset;
    use crate::util::TempDir;

    fn fast_opts() -> RemoteOptions {
        RemoteOptions {
            retries: 3,
            backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(20),
        }
    }

    /// A served v2 dataset directory + objstore over it.
    fn served_dataset(chunk_rows: u32) -> (Dataset, TempDir, ObjStoreServer) {
        let ds = LeoLikeSpec::new(350, 9).generate();
        let dir = crate::util::tempdir().unwrap();
        save_dataset_with(
            &ds,
            dir.path(),
            disk::Layout::V2 { chunk_rows },
            IoStats::new(),
        )
        .unwrap();
        let server = ObjStoreServer::spawn(
            dir.path(),
            "127.0.0.1:0",
            IoStats::new(),
            ObjStoreOptions::default(),
        )
        .unwrap();
        (ds, dir, server)
    }

    fn store_over(
        server: &ObjStoreServer,
        ds: &Dataset,
        cols: &[usize],
        stats: IoStats,
        prefetch: usize,
    ) -> Arc<dyn ColumnStore> {
        remote_store_for(
            &server.addr().to_string(),
            ds.schema(),
            cols,
            stats,
            prefetch,
        )
        .unwrap()
    }

    #[test]
    fn remote_scans_match_the_dataset() {
        let (ds, _dir, server) = served_dataset(64);
        let cols: Vec<usize> = vec![0, 1, 3];
        for prefetch in [0usize, 2] {
            let stats = IoStats::new();
            let store = store_over(&server, &ds, &cols, stats.clone(), prefetch);
            assert_eq!(store.columns(), cols);
            for &j in &cols {
                assert_eq!(store.column_type(j).unwrap(), ds.schema().columns[j].ctype);
                assert_eq!(&store.read_raw(j).unwrap(), ds.column(j), "column {j}");
                if ds.column(j).is_numerical() {
                    assert_eq!(store.read_sorted(j).unwrap(), ds.column(j).presort());
                }
            }
            // Chunks arrive in row order with correct base offsets.
            let mut seen = 0usize;
            store
                .scan_raw(cols[0], &mut |base, chunk| {
                    assert_eq!(base, seen);
                    seen += chunk.len();
                    Ok(())
                })
                .unwrap();
            assert_eq!(seen, ds.num_rows());
            // Missing column errors.
            assert!(store.scan_raw(2, &mut |_, _| Ok(())).is_err());
            // Bytes actually crossed the wire.
            assert!(stats.net_bytes() > 0);
            assert!(stats.disk_read_bytes() > 0);
        }
    }

    #[test]
    fn resume_at_chunk_boundary_completes_the_pass() {
        let (ds, _dir, server) = served_dataset(48);
        let stats = IoStats::new();
        let client = RemoteClient::new(&server.addr().to_string(), fast_opts(), stats.clone());
        let spec = RemoteColumnSpec {
            index: 0,
            raw: "col_0.drfc".into(),
            sorted: Some("col_0.sorted.drfc".into()),
            ctype: ColumnType::Numerical,
            raw_checksum: None,
            sorted_checksum: None,
        };
        let store = RemoteStore::open(client, vec![spec], stats.clone()).unwrap();
        let table = store.chunk_table(0).unwrap();
        assert!(table.len() >= 3, "need several chunks: {table:?}");

        // A "preempted" pass: visit 2 chunks, then die.
        let mut prefix: Vec<f32> = Vec::new();
        let mut chunks_seen = 0usize;
        let err = store.scan_raw_from(0, 0, &mut |_base, chunk| {
            if chunks_seen == 2 {
                anyhow::bail!("preempted");
            }
            chunks_seen += 1;
            match chunk {
                RawChunk::Numerical(v) => prefix.extend_from_slice(v),
                _ => unreachable!(),
            }
            Ok(())
        });
        assert!(err.is_err());
        assert_eq!(prefix.len(), table[0] + table[1]);

        // The replacement resumes at the chunk boundary; only the tail
        // is fetched (stats delta covers exactly the remaining bytes).
        let before = stats.snapshot();
        let mut tail: Vec<f32> = Vec::new();
        store
            .scan_raw_from(0, 2, &mut |base, chunk| {
                assert_eq!(base, tail.len() + prefix.len());
                match chunk {
                    RawChunk::Numerical(v) => tail.extend_from_slice(v),
                    _ => unreachable!(),
                }
                Ok(())
            })
            .unwrap();
        let d = stats.snapshot().delta_since(&before);
        assert_eq!(d.disk_read_bytes, (tail.len() * 4) as u64, "tail bytes only");
        assert_eq!(d.disk_read_passes, 1);
        prefix.extend_from_slice(&tail);
        match ds.column(0) {
            crate::data::Column::Numerical(v) => assert_eq!(&prefix, v),
            _ => unreachable!(),
        }

        // Resuming past the table is an error; resuming exactly at the
        // end is an empty (but valid) pass.
        assert!(store
            .scan_raw_from(0, table.len() + 1, &mut |_, _| Ok(()))
            .is_err());
        store
            .scan_raw_from(0, table.len(), &mut |_, _| panic!("no chunks left"))
            .unwrap();
    }

    #[test]
    fn checksum_mismatch_rejected_on_complete_pass() {
        let (ds, dir, server) = served_dataset(64);
        let stats = IoStats::new();
        let client = RemoteClient::new(&server.addr().to_string(), fast_opts(), stats.clone());
        let good = crate::cluster::manifest::checksum_file(&dir.path().join("col_0.drfc")).unwrap();
        let make_spec = |checksum: u64| RemoteColumnSpec {
            index: 0,
            raw: "col_0.drfc".into(),
            sorted: None,
            ctype: ColumnType::Numerical,
            raw_checksum: Some(checksum),
            sorted_checksum: None,
        };

        // The right checksum passes.
        let store = RemoteStore::open(client.clone(), vec![make_spec(good)], stats.clone()).unwrap();
        assert_eq!(&store.read_raw(0).unwrap(), ds.column(0));

        // A wrong checksum (i.e. corrupted/tampered fetched bytes) is
        // rejected at the end of the complete pass...
        let store =
            RemoteStore::open(client.clone(), vec![make_spec(good ^ 1)], stats.clone()).unwrap();
        let err = store.read_raw(0).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        // ...for the prefetching pipeline too.
        let store = RemoteStore::open(client, vec![make_spec(good ^ 1)], stats)
            .unwrap()
            .with_prefetch(2);
        assert!(store.read_raw(0).is_err());
    }

    #[test]
    fn truncated_range_reply_rejected() {
        // A fake "objstore" that answers every Read with fewer bytes
        // than requested — a short reply must be rejected as a protocol
        // violation, not silently accepted.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            for stream in listener.incoming().take(1) {
                let stream = stream.unwrap();
                let mut r = std::io::BufReader::new(stream.try_clone().unwrap());
                let mut w = std::io::BufWriter::new(stream);
                while let Ok(frame) = read_frame(&mut r) {
                    let resp = match crate::data::objserve::decode_request(&frame).unwrap() {
                        ObjRequest::Stat { .. } => ObjResponse::Stat { len: 1 << 20 },
                        ObjRequest::Read { len, .. } => {
                            ObjResponse::Data(vec![0u8; (len as usize).saturating_sub(1)])
                        }
                    };
                    if write_frame(&mut w, &crate::data::objserve::encode_response(&resp)).is_err()
                    {
                        break;
                    }
                }
            }
        });
        let client = RemoteClient::new(&addr, fast_opts(), IoStats::new());
        let mut sess = client.session();
        let err = sess.fetch_exact("whatever", 0, 16).unwrap_err();
        assert!(
            format!("{err:#}").contains("truncated range reply"),
            "{err:#}"
        );
        drop(sess);
        handle.join().unwrap();
    }

    #[test]
    fn failover_to_replica_when_first_objstore_dies_mid_pass() {
        // Two loopback objstores serving the same pack; the client gets
        // both addresses in failover order. The primary is crashed
        // after the pass's first chunk arrives — the next range read
        // must rotate to the replica and the pass must complete with
        // the exact bytes, no manual redirect.
        let (ds, dir, primary) = served_dataset(48);
        let replica = ObjStoreServer::spawn(
            dir.path(),
            "127.0.0.1:0",
            IoStats::new(),
            ObjStoreOptions::default(),
        )
        .unwrap();
        let stats = IoStats::new();
        let client = RemoteClient::new(
            &format!("{},{}", primary.addr(), replica.addr()),
            fast_opts(),
            stats.clone(),
        );
        assert_eq!(client.addrs().len(), 2);
        assert_eq!(client.addr(), primary.addr().to_string());
        let spec = RemoteColumnSpec {
            index: 0,
            raw: "col_0.drfc".into(),
            sorted: None,
            ctype: ColumnType::Numerical,
            raw_checksum: None,
            sorted_checksum: None,
        };
        let store = RemoteStore::open(client.clone(), vec![spec], stats).unwrap();

        let failovers = crate::telemetry::counter("drf_remote_failovers_total");
        let before = failovers.get();
        let mut primary = Some(primary);
        let mut out: Vec<f32> = Vec::new();
        store
            .scan_raw_from(0, 0, &mut |_base, chunk| {
                // Crash the primary mid-pass, first chunk in hand.
                drop(primary.take());
                match chunk {
                    RawChunk::Numerical(v) => out.extend_from_slice(v),
                    _ => unreachable!(),
                }
                Ok(())
            })
            .unwrap();
        match ds.column(0) {
            crate::data::Column::Numerical(v) => assert_eq!(&out, v),
            _ => unreachable!(),
        }
        assert!(
            failovers.get() > before,
            "the pass completed without ever failing over"
        );
        assert_eq!(
            client.addr(),
            replica.addr().to_string(),
            "the shared replica pointer must rest on the live store"
        );
    }

    #[test]
    fn dead_objstore_errors_after_bounded_retries_and_redirect_recovers() {
        let (ds, dir, server) = served_dataset(64);
        let stats = IoStats::new();
        let client = RemoteClient::new(&server.addr().to_string(), fast_opts(), stats.clone());
        let spec = RemoteColumnSpec {
            index: 0,
            raw: "col_0.drfc".into(),
            sorted: None,
            ctype: ColumnType::Numerical,
            raw_checksum: None,
            sorted_checksum: None,
        };
        let store = RemoteStore::open(client, vec![spec], stats).unwrap();
        assert_eq!(&store.read_raw(0).unwrap(), ds.column(0));

        // Kill the server: scans fail with a bounded-retry error...
        drop(server);
        let err = store.read_raw(0).unwrap_err();
        assert!(format!("{err:#}").contains("attempts"), "{err:#}");

        // ...until a supervisor brings a replacement up (anywhere) and
        // redirects the store, after which scans just work again.
        let replacement = ObjStoreServer::spawn(
            dir.path(),
            "127.0.0.1:0",
            IoStats::new(),
            ObjStoreOptions::default(),
        )
        .unwrap();
        store.set_addr(&replacement.addr().to_string());
        assert_eq!(&store.read_raw(0).unwrap(), ds.column(0));
    }

    #[test]
    fn open_rejects_bad_remote_files() {
        let dir = crate::util::tempdir().unwrap();
        // A DRFC header declaring 64 rows over a 4-byte payload.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"DRFC");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes()); // kind: numerical
        bytes.extend_from_slice(&64u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 4]);
        std::fs::write(dir.path().join("trunc.drfc"), &bytes).unwrap();
        std::fs::write(dir.path().join("junk.drfc"), b"JUNKJUNKJUNKJUNKJUNKJUNK").unwrap();
        let server = ObjStoreServer::spawn(
            dir.path(),
            "127.0.0.1:0",
            IoStats::new(),
            ObjStoreOptions::default(),
        )
        .unwrap();
        let client = RemoteClient::new(&server.addr().to_string(), fast_opts(), IoStats::new());
        let spec = |name: &str| RemoteColumnSpec {
            index: 0,
            raw: name.to_string(),
            sorted: None,
            ctype: ColumnType::Numerical,
            raw_checksum: None,
            sorted_checksum: None,
        };

        let err = RemoteStore::open(client.clone(), vec![spec("trunc.drfc")], IoStats::new())
            .unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        assert!(
            RemoteStore::open(client.clone(), vec![spec("junk.drfc")], IoStats::new()).is_err()
        );
        assert!(
            RemoteStore::open(client, vec![spec("missing.drfc")], IoStats::new()).is_err()
        );
    }
}
