//! An owned columnar dataset.
//!
//! This is the unit the synthetic generators produce, the topology shards
//! across splitters, and the baselines consume. Rows are samples; the
//! label column is separate from the features.

use super::column::Column;
use super::schema::{ColumnType, Schema};

/// A fully materialized columnar dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    schema: Schema,
    columns: Vec<Column>,
    labels: Vec<u32>,
}

impl Dataset {
    /// Build a dataset, validating column/schema agreement.
    pub fn new(schema: Schema, columns: Vec<Column>, labels: Vec<u32>) -> Self {
        assert_eq!(
            schema.num_features(),
            columns.len(),
            "schema/column count mismatch"
        );
        let n = labels.len();
        for (i, (spec, col)) in schema.columns.iter().zip(&columns).enumerate() {
            assert_eq!(col.len(), n, "column {i} has wrong row count");
            match (&spec.ctype, col) {
                (ColumnType::Numerical, Column::Numerical(_)) => {}
                (ColumnType::Categorical { arity }, Column::Categorical { values, arity: a }) => {
                    assert_eq!(arity, a, "column {i} arity mismatch");
                    debug_assert!(
                        values.iter().all(|&v| v < *arity),
                        "column {i} has out-of-arity value"
                    );
                }
                _ => panic!("column {i} type does not match schema"),
            }
        }
        debug_assert!(
            labels.iter().all(|&y| y < schema.num_classes),
            "label out of range"
        );
        Self {
            schema,
            columns,
            labels,
        }
    }

    /// The dataset's schema.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Number of rows (paper's `n`).
    pub fn num_rows(&self) -> usize {
        self.labels.len()
    }

    /// Number of feature columns (paper's `m`).
    pub fn num_features(&self) -> usize {
        self.columns.len()
    }

    /// Number of label classes.
    pub fn num_classes(&self) -> u32 {
        self.schema.num_classes
    }

    /// Feature column `j`.
    pub fn column(&self, j: usize) -> &Column {
        &self.columns[j]
    }

    /// All feature columns, in schema order.
    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    /// The label column.
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// One row's feature values, materialized (for inference/baselines).
    pub fn row(&self, i: usize) -> RowView<'_> {
        RowView { ds: self, row: i }
    }

    /// A new dataset restricted to the given rows (order preserved).
    /// Used to build train/test splits and the Leo 1% / 10% subsets.
    pub fn subset(&self, rows: &[u32]) -> Dataset {
        let columns = self.columns.iter().map(|c| c.gather(rows)).collect();
        let labels = rows.iter().map(|&r| self.labels[r as usize]).collect();
        Dataset {
            schema: self.schema.clone(),
            columns,
            labels,
        }
    }

    /// The first `k` rows (deterministic subset, used for x% scaling runs).
    pub fn head(&self, k: usize) -> Dataset {
        let rows: Vec<u32> = (0..k.min(self.num_rows()) as u32).collect();
        self.subset(&rows)
    }

    /// Deterministic train/test split: every `holdout`-th row goes to
    /// test. Returns (train, test).
    pub fn split_holdout(&self, holdout: usize) -> (Dataset, Dataset) {
        assert!(holdout >= 2);
        let mut train = Vec::new();
        let mut test = Vec::new();
        for i in 0..self.num_rows() as u32 {
            if (i as usize) % holdout == 0 {
                test.push(i);
            } else {
                train.push(i);
            }
        }
        (self.subset(&train), self.subset(&test))
    }

    /// Total in-memory footprint in bytes (features + labels).
    pub fn nbytes(&self) -> usize {
        self.columns.iter().map(|c| c.nbytes()).sum::<usize>() + self.labels.len() * 4
    }

    /// Per-class label counts.
    pub fn class_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.num_classes() as usize];
        for &y in &self.labels {
            counts[y as usize] += 1;
        }
        counts
    }
}

/// A borrowed view of one dataset row.
#[derive(Debug, Clone, Copy)]
pub struct RowView<'a> {
    ds: &'a Dataset,
    row: usize,
}

impl<'a> RowView<'a> {
    /// Numerical value of feature `j` (panics if not numerical).
    pub fn numerical(&self, j: usize) -> f32 {
        self.ds.columns[j].as_numerical()[self.row]
    }

    /// Categorical value of feature `j` (panics if not categorical).
    pub fn categorical(&self, j: usize) -> u32 {
        self.ds.columns[j].as_categorical()[self.row]
    }

    /// The row's label.
    pub fn label(&self) -> u32 {
        self.ds.labels[self.row]
    }

    /// The row's index in the dataset.
    pub fn index(&self) -> usize {
        self.row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::ColumnSpec;

    fn toy() -> Dataset {
        let schema = Schema::new(
            vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("c", 3),
            ],
            2,
        );
        Dataset::new(
            schema,
            vec![
                Column::Numerical(vec![1.0, 2.0, 3.0, 4.0]),
                Column::Categorical {
                    values: vec![0, 1, 2, 1],
                    arity: 3,
                },
            ],
            vec![0, 1, 0, 1],
        )
    }

    #[test]
    fn basic_accessors() {
        let ds = toy();
        assert_eq!(ds.num_rows(), 4);
        assert_eq!(ds.num_features(), 2);
        assert_eq!(ds.row(2).numerical(0), 3.0);
        assert_eq!(ds.row(2).categorical(1), 2);
        assert_eq!(ds.row(2).label(), 0);
        assert_eq!(ds.class_counts(), vec![2, 2]);
        assert_eq!(ds.nbytes(), 4 * 4 + 4 * 4 + 4 * 4);
    }

    #[test]
    fn subset_and_head() {
        let ds = toy();
        let s = ds.subset(&[3, 1]);
        assert_eq!(s.num_rows(), 2);
        assert_eq!(s.row(0).numerical(0), 4.0);
        assert_eq!(s.labels(), &[1, 1]);
        let h = ds.head(2);
        assert_eq!(h.num_rows(), 2);
        assert_eq!(h.row(1).numerical(0), 2.0);
    }

    #[test]
    fn holdout_split_partitions() {
        let ds = toy();
        let (train, test) = ds.split_holdout(2);
        assert_eq!(train.num_rows() + test.num_rows(), ds.num_rows());
        assert_eq!(test.num_rows(), 2); // rows 0, 2
        assert_eq!(test.row(1).numerical(0), 3.0);
    }

    #[test]
    #[should_panic(expected = "wrong row count")]
    fn row_count_mismatch_rejected() {
        let schema = Schema::all_numerical(1);
        Dataset::new(
            schema,
            vec![Column::Numerical(vec![1.0])],
            vec![0, 1],
        );
    }
}
