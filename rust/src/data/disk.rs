//! On-disk binary column files with strictly sequential access.
//!
//! DRF workers "only need to read their assigned part of the dataset
//! sequentially, i.e. no random access and no writing are needed" (paper
//! §2). This module provides that storage: one file per column, a small
//! header, then densely packed little-endian records. Readers and
//! writers are buffered and charge an [`IoStats`] so the complexity
//! benches can report bytes/passes per worker exactly as Table 1 does.
//!
//! Three record layouts:
//! * raw numerical column: `f32` per row;
//! * raw categorical column: `u32` per row;
//! * presorted numerical column (Alg. 1's `q(j)`): `(f32 value, u32
//!   sample)` pairs in value order — produced by the presorting phase
//!   ([`super::sort`]).
//!
//! Two container versions:
//! * **DRFC v1** — header (magic, version, kind, row count) followed by
//!   one monolithic record stream;
//! * **DRFC v2** — the v1 header fields plus a **chunk table**: the
//!   per-chunk record counts, written up front. A reader can therefore
//!   resume or stop a pass at any chunk boundary without scanning to
//!   the end of the file — the property the chunked
//!   [`super::store::ColumnStore`] scan path and SPRINT-style partial
//!   passes rely on.
//!
//! Readers of either version expose **bounded-buffer chunk reads**
//! (`next_chunk_*`): at most `max_records` records are materialized per
//! call, so a pass over an arbitrarily large column runs in constant
//! memory. Byte/pass accounting is identical to the historical
//! whole-column reads: the header is charged at open, each record
//! exactly once as its chunk is read, and one read pass per full scan.

use super::column::SortedEntry;
use super::io_stats::IoStats;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// File magic: "DRFC" (DRF Column).
const MAGIC: [u8; 4] = *b"DRFC";
/// Monolithic format version.
const VERSION_V1: u32 = 1;
/// Chunk-table format version.
const VERSION_V2: u32 = 2;

/// Default records per chunk for bounded-buffer scans and v2 files
/// (64Ki records = 256 KiB raw / 512 KiB sorted per chunk buffer).
pub const DEFAULT_CHUNK_ROWS: usize = 64 * 1024;

/// Kind tag stored in the header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileKind {
    /// Raw numerical column: one `f32` per row.
    Numerical = 1,
    /// Raw categorical column: one `u32` per row.
    Categorical = 2,
    /// Presorted numerical column: `(f32, u32)` pairs in value order.
    SortedNumerical = 3,
}

impl FileKind {
    fn from_u32(v: u32) -> Result<Self> {
        Ok(match v {
            1 => FileKind::Numerical,
            2 => FileKind::Categorical,
            3 => FileKind::SortedNumerical,
            _ => bail!("unknown column file kind {v}"),
        })
    }

    /// Bytes per record for this layout.
    pub fn record_bytes(self) -> usize {
        match self {
            FileKind::Numerical | FileKind::Categorical => 4,
            FileKind::SortedNumerical => 8,
        }
    }
}

/// Container layout of a column file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// DRFC v1: one monolithic record stream.
    V1,
    /// DRFC v2: per-chunk record counts in the header; `chunk_rows`
    /// records per chunk (the last chunk may be short).
    V2 {
        /// Records per chunk (>= 1; the last chunk may be short).
        chunk_rows: u32,
    },
}

/// The per-chunk record counts of a v2 file with `rows` records cut
/// into `chunk_rows`-record chunks. Callers validate `chunk_rows >= 1`
/// ([`write_header`] rejects 0 with an error).
fn chunk_counts(rows: u64, chunk_rows: u32) -> Vec<u32> {
    debug_assert!(chunk_rows >= 1);
    let mut counts = Vec::new();
    let mut left = rows;
    while left > 0 {
        let c = left.min(chunk_rows as u64) as u32;
        counts.push(c);
        left -= c as u64;
    }
    counts
}

/// Parsed column-file header.
#[derive(Debug, Clone, PartialEq)]
pub struct Header {
    /// Record layout of the file.
    pub kind: FileKind,
    /// Declared record count.
    pub rows: u64,
    /// Container version (1 = monolithic, 2 = chunk-tabled).
    pub version: u32,
    /// v2 chunk table (empty for v1 files).
    pub chunks: Vec<u32>,
}

impl Header {
    /// Serialized size of this header in bytes.
    pub fn nbytes(&self) -> u64 {
        match self.version {
            VERSION_V1 => HEADER_BYTES_V1,
            _ => HEADER_BYTES_V1 + 4 + 4 * self.chunks.len() as u64,
        }
    }

    /// Parse a header from the start of `bytes` (the mmap backend reads
    /// headers straight out of the mapping; same validation as the
    /// streaming reader).
    pub fn parse(mut bytes: &[u8]) -> Result<Header> {
        read_header(&mut bytes)
    }

    /// Reject a file whose payload is shorter than this header's
    /// declared row count — shared by every backend so a truncated
    /// column file fails **at open** with the same error, never as a
    /// confusing mid-scan EOF/fault deep inside a training pass.
    /// (Saturating: a forged astronomic row count must fail the check,
    /// not overflow it.)
    pub fn ensure_untruncated(&self, file_len: u64, path: &Path) -> Result<()> {
        let expected = self
            .nbytes()
            .saturating_add(self.rows.saturating_mul(self.kind.record_bytes() as u64));
        ensure!(
            file_len >= expected,
            "{}: truncated column file — header declares {} records \
             ({expected} bytes incl. header) but the file has {file_len} bytes",
            path.display(),
            self.rows
        );
        Ok(())
    }

    /// Chunk sizes of a full pass over the records: the file's own
    /// chunk table (v2) or [`DEFAULT_CHUNK_ROWS`] cuts (v1). Shared by
    /// every backend so chunk boundaries — and therefore scan-visitor
    /// call sequences — are identical for the same file.
    pub fn chunk_plan(&self) -> Vec<usize> {
        if self.version == VERSION_V2 {
            self.chunks.iter().map(|&c| c as usize).collect()
        } else {
            let mut plan = Vec::new();
            let mut left = self.rows as usize;
            while left > 0 {
                let c = left.min(DEFAULT_CHUNK_ROWS);
                plan.push(c);
                left -= c;
            }
            plan
        }
    }
}

const HEADER_BYTES_V1: u64 = 4 + 4 + 4 + 8; // magic, version, kind, rows

fn write_header(w: &mut impl Write, kind: FileKind, rows: u64, layout: Layout) -> Result<Header> {
    let (version, chunks) = match layout {
        Layout::V1 => (VERSION_V1, Vec::new()),
        Layout::V2 { chunk_rows } => {
            ensure!(chunk_rows >= 1, "v2 layout needs chunk_rows >= 1");
            (VERSION_V2, chunk_counts(rows, chunk_rows))
        }
    };
    w.write_all(&MAGIC)?;
    w.write_all(&version.to_le_bytes())?;
    w.write_all(&(kind as u32).to_le_bytes())?;
    w.write_all(&rows.to_le_bytes())?;
    if version == VERSION_V2 {
        w.write_all(&(chunks.len() as u32).to_le_bytes())?;
        for &c in &chunks {
            w.write_all(&c.to_le_bytes())?;
        }
    }
    Ok(Header {
        kind,
        rows,
        version,
        chunks,
    })
}

fn read_header(r: &mut impl Read) -> Result<Header> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("reading column magic")?;
    ensure!(magic == MAGIC, "bad column file magic");
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    ensure!(
        version == VERSION_V1 || version == VERSION_V2,
        "unsupported column file version {version}"
    );
    r.read_exact(&mut b4)?;
    let kind = FileKind::from_u32(u32::from_le_bytes(b4))?;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8);
    let chunks = if version == VERSION_V2 {
        r.read_exact(&mut b4)?;
        let n = u32::from_le_bytes(b4) as usize;
        // Each table entry describes >= 1 record, so the row count
        // bounds the table size — reject forged counts before
        // allocating.
        ensure!(
            n as u64 <= rows,
            "chunk table claims {n} chunks for {rows} rows"
        );
        // `rows` is itself untrusted: a forged header declaring 2^64
        // rows passes the bound above with n = u32::MAX and would
        // reserve 16 GiB here (fuzz finding). Clamp the up-front
        // reservation; a genuine table this long grows amortized while
        // truncated input fails at the next read.
        let mut chunks = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            r.read_exact(&mut b4)?;
            chunks.push(u32::from_le_bytes(b4));
        }
        ensure!(
            chunks.iter().map(|&c| c as u64).sum::<u64>() == rows,
            "chunk table sums to {} records, header declares {rows}",
            chunks.iter().map(|&c| c as u64).sum::<u64>()
        );
        ensure!(
            chunks.iter().all(|&c| c > 0),
            "chunk table contains an empty chunk"
        );
        chunks
    } else {
        Vec::new()
    };
    Ok(Header {
        kind,
        rows,
        version,
        chunks,
    })
}

/// Decode packed little-endian `f32` records into `buf` (replacing its
/// contents). The single source of truth for the record layout, shared
/// by the streaming reader's chunk reads and the mmap backend's
/// non-zero-copy fallback.
pub fn decode_f32(bytes: &[u8], buf: &mut Vec<f32>) {
    buf.clear();
    buf.extend(
        bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap())),
    );
}

/// Decode packed little-endian `u32` records into `buf`.
pub fn decode_u32(bytes: &[u8], buf: &mut Vec<u32>) {
    buf.clear();
    buf.extend(
        bytes
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap())),
    );
}

/// Decode packed little-endian `(f32 value, u32 sample)` records into
/// `buf`.
pub fn decode_sorted(bytes: &[u8], buf: &mut Vec<SortedEntry>) {
    buf.clear();
    buf.extend(bytes.chunks_exact(8).map(|b| SortedEntry {
        value: f32::from_le_bytes(b[0..4].try_into().unwrap()),
        sample: u32::from_le_bytes(b[4..8].try_into().unwrap()),
    }));
}

/// Streaming writer for a column file.
pub struct ColumnWriter {
    w: BufWriter<File>,
    kind: FileKind,
    written: u64,
    declared: u64,
    stats: IoStats,
    path: PathBuf,
}

impl ColumnWriter {
    /// Create a v1 file declaring `rows` records of `kind`.
    pub fn create(path: &Path, kind: FileKind, rows: u64, stats: IoStats) -> Result<Self> {
        Self::create_with(path, kind, rows, Layout::V1, stats)
    }

    /// Create a file declaring `rows` records of `kind` in `layout`.
    pub fn create_with(
        path: &Path,
        kind: FileKind,
        rows: u64,
        layout: Layout,
        stats: IoStats,
    ) -> Result<Self> {
        let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        let header = write_header(&mut w, kind, rows, layout)?;
        stats.add_disk_write(header.nbytes());
        Ok(Self {
            w,
            kind,
            written: 0,
            declared: rows,
            stats,
            path: path.to_path_buf(),
        })
    }

    /// Append one numerical record.
    pub fn write_f32(&mut self, v: f32) -> Result<()> {
        ensure!(self.kind == FileKind::Numerical, "layout mismatch");
        self.w.write_all(&v.to_le_bytes())?;
        self.written += 1;
        self.stats.add_disk_write(4);
        Ok(())
    }

    /// Append one categorical record.
    pub fn write_u32(&mut self, v: u32) -> Result<()> {
        ensure!(self.kind == FileKind::Categorical, "layout mismatch");
        self.w.write_all(&v.to_le_bytes())?;
        self.written += 1;
        self.stats.add_disk_write(4);
        Ok(())
    }

    /// Append one presorted entry.
    pub fn write_sorted(&mut self, e: SortedEntry) -> Result<()> {
        ensure!(self.kind == FileKind::SortedNumerical, "layout mismatch");
        self.w.write_all(&e.value.to_le_bytes())?;
        self.w.write_all(&e.sample.to_le_bytes())?;
        self.written += 1;
        self.stats.add_disk_write(8);
        Ok(())
    }

    /// Finish the file; counts one write pass and validates the declared
    /// row count.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush()?;
        ensure!(
            self.written == self.declared,
            "{}: wrote {} records, declared {}",
            self.path.display(),
            self.written,
            self.declared
        );
        self.stats.add_write_pass();
        Ok(())
    }
}

/// Buffered sequential reader over a column file (either version).
pub struct ColumnReader {
    r: BufReader<File>,
    header: Header,
    read: u64,
    stats: IoStats,
    /// Scratch byte buffer for bounded chunk reads.
    scratch: Vec<u8>,
    /// v2 chunk cursor: index of the chunk the read position sits in,
    /// and the cumulative record count through that chunk (makes
    /// [`Self::next_chunk_records`] amortized O(1)).
    chunk_idx: usize,
    chunk_end: u64,
}

impl ColumnReader {
    /// Open `path`, validating the header and the truncation check up
    /// front; charges the header bytes to `stats`.
    pub fn open(path: &Path, stats: IoStats) -> Result<Self> {
        let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let file_len = f.metadata()?.len();
        let mut r = BufReader::with_capacity(1 << 20, f);
        let header = read_header(&mut r)
            .with_context(|| format!("reading header of {}", path.display()))?;
        header.ensure_untruncated(file_len, path)?;
        stats.add_disk_read(header.nbytes());
        let chunk_end = header.chunks.first().copied().unwrap_or(0) as u64;
        Ok(Self {
            r,
            header,
            read: 0,
            stats,
            scratch: Vec::new(),
            chunk_idx: 0,
            chunk_end,
        })
    }

    /// The validated header.
    pub fn header(&self) -> &Header {
        &self.header
    }

    /// Records left to read.
    pub fn remaining(&self) -> u64 {
        self.header.rows - self.read
    }

    /// Read one numerical record.
    pub fn next_f32(&mut self) -> Result<f32> {
        ensure!(self.header.kind == FileKind::Numerical, "layout mismatch");
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        self.read += 1;
        self.stats.add_disk_read(4);
        Ok(f32::from_le_bytes(b))
    }

    /// Read one categorical record.
    pub fn next_u32(&mut self) -> Result<u32> {
        ensure!(self.header.kind == FileKind::Categorical, "layout mismatch");
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        self.read += 1;
        self.stats.add_disk_read(4);
        Ok(u32::from_le_bytes(b))
    }

    /// Read one presorted entry.
    pub fn next_sorted(&mut self) -> Result<SortedEntry> {
        ensure!(
            self.header.kind == FileKind::SortedNumerical,
            "layout mismatch"
        );
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        self.read += 1;
        self.stats.add_disk_read(8);
        Ok(SortedEntry {
            value: f32::from_le_bytes(b[0..4].try_into().unwrap()),
            sample: u32::from_le_bytes(b[4..8].try_into().unwrap()),
        })
    }

    /// Read up to `max_records` records' worth of raw bytes into the
    /// scratch buffer; returns the record count (0 = end of column).
    fn fill_chunk(&mut self, max_records: usize) -> Result<usize> {
        let n = (self.remaining() as usize).min(max_records);
        let bytes = n * self.header.kind.record_bytes();
        self.scratch.resize(bytes, 0);
        self.r.read_exact(&mut self.scratch)?;
        self.read += n as u64;
        self.stats.add_disk_read(bytes as u64);
        Ok(n)
    }

    /// Bounded-buffer chunk read: replace `buf` with the next (up to)
    /// `max_records` f32 records. Returns the record count (0 = EOF).
    pub fn next_chunk_f32(&mut self, buf: &mut Vec<f32>, max_records: usize) -> Result<usize> {
        ensure!(self.header.kind == FileKind::Numerical, "layout mismatch");
        let n = self.fill_chunk(max_records)?;
        decode_f32(&self.scratch, buf);
        Ok(n)
    }

    /// Bounded-buffer chunk read of u32 records.
    pub fn next_chunk_u32(&mut self, buf: &mut Vec<u32>, max_records: usize) -> Result<usize> {
        ensure!(self.header.kind == FileKind::Categorical, "layout mismatch");
        let n = self.fill_chunk(max_records)?;
        decode_u32(&self.scratch, buf);
        Ok(n)
    }

    /// Bounded-buffer chunk read of sorted entries.
    pub fn next_chunk_sorted(
        &mut self,
        buf: &mut Vec<SortedEntry>,
        max_records: usize,
    ) -> Result<usize> {
        ensure!(
            self.header.kind == FileKind::SortedNumerical,
            "layout mismatch"
        );
        let n = self.fill_chunk(max_records)?;
        decode_sorted(&self.scratch, buf);
        Ok(n)
    }

    /// Chunk sizes of a full pass from the start of the file: the
    /// file's own chunk table (v2) or `DEFAULT_CHUNK_ROWS` cuts (v1).
    /// Callers doing a whole-column scan iterate this once instead of
    /// probing [`Self::next_chunk_records`] per chunk.
    pub fn chunk_plan(&self) -> Vec<usize> {
        self.header.chunk_plan()
    }

    /// Record count of the next chunk of a scan: the file's own chunk
    /// table entry (v2) or `DEFAULT_CHUNK_ROWS` (v1), clamped to the
    /// remaining records. Record-granular reads may leave the cursor
    /// mid-chunk; scans that mix the two APIs just get a short chunk,
    /// which is harmless. Amortized O(1) across a whole pass.
    pub fn next_chunk_records(&mut self) -> usize {
        if self.header.version == VERSION_V2 {
            while self.chunk_idx < self.header.chunks.len() && self.read >= self.chunk_end {
                self.chunk_idx += 1;
                self.chunk_end += self
                    .header
                    .chunks
                    .get(self.chunk_idx)
                    .copied()
                    .unwrap_or(0) as u64;
            }
            if self.chunk_idx >= self.header.chunks.len() {
                0
            } else {
                (self.chunk_end - self.read) as usize
            }
        } else {
            (self.remaining() as usize).min(DEFAULT_CHUNK_ROWS)
        }
    }

    /// Read the whole remainder as sorted entries (counts one pass).
    pub fn read_all_sorted(mut self) -> Result<Vec<SortedEntry>> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        let mut buf = Vec::new();
        while self.remaining() > 0 {
            self.next_chunk_sorted(&mut buf, DEFAULT_CHUNK_ROWS)?;
            out.extend_from_slice(&buf);
        }
        self.stats.add_read_pass();
        Ok(out)
    }

    /// Read the whole remainder as f32 (counts one pass).
    pub fn read_all_f32(mut self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        let mut buf = Vec::new();
        while self.remaining() > 0 {
            self.next_chunk_f32(&mut buf, DEFAULT_CHUNK_ROWS)?;
            out.extend_from_slice(&buf);
        }
        self.stats.add_read_pass();
        Ok(out)
    }

    /// Read the whole remainder as u32 (counts one pass).
    pub fn read_all_u32(mut self) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        let mut buf = Vec::new();
        while self.remaining() > 0 {
            self.next_chunk_u32(&mut buf, DEFAULT_CHUNK_ROWS)?;
            out.extend_from_slice(&buf);
        }
        self.stats.add_read_pass();
        Ok(out)
    }

    /// Mark the end of a logical pass (when the caller reads record by
    /// record or chunk by chunk instead of via `read_all_*`).
    pub fn end_pass(&self) {
        self.stats.add_read_pass();
    }
}

/// Write a full numerical column to `path`.
pub fn write_numerical(path: &Path, values: &[f32], stats: IoStats) -> Result<()> {
    write_numerical_with(path, values, Layout::V1, stats)
}

/// Write a full numerical column to `path` in `layout`.
pub fn write_numerical_with(
    path: &Path,
    values: &[f32],
    layout: Layout,
    stats: IoStats,
) -> Result<()> {
    let mut w = ColumnWriter::create_with(
        path,
        FileKind::Numerical,
        values.len() as u64,
        layout,
        stats,
    )?;
    for &v in values {
        w.write_f32(v)?;
    }
    w.finish()
}

/// Write a full categorical column to `path`.
pub fn write_categorical(path: &Path, values: &[u32], stats: IoStats) -> Result<()> {
    write_categorical_with(path, values, Layout::V1, stats)
}

/// Write a full categorical column to `path` in `layout`.
pub fn write_categorical_with(
    path: &Path,
    values: &[u32],
    layout: Layout,
    stats: IoStats,
) -> Result<()> {
    let mut w = ColumnWriter::create_with(
        path,
        FileKind::Categorical,
        values.len() as u64,
        layout,
        stats,
    )?;
    for &v in values {
        w.write_u32(v)?;
    }
    w.finish()
}

/// Write a raw u32 column (e.g. the label column) — alias of
/// [`write_categorical`] with a name that doesn't imply arity checks.
pub fn write_categorical_raw(path: &Path, values: &[u32], stats: IoStats) -> Result<()> {
    write_categorical(path, values, stats)
}

/// Write a presorted numerical column to `path`.
pub fn write_sorted(path: &Path, entries: &[SortedEntry], stats: IoStats) -> Result<()> {
    write_sorted_with(path, entries, Layout::V1, stats)
}

/// Write a presorted numerical column to `path` in `layout`.
pub fn write_sorted_with(
    path: &Path,
    entries: &[SortedEntry],
    layout: Layout,
    stats: IoStats,
) -> Result<()> {
    let mut w = ColumnWriter::create_with(
        path,
        FileKind::SortedNumerical,
        entries.len() as u64,
        layout,
        stats,
    )?;
    for &e in entries {
        w.write_sorted(e)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_numerical() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.drfc");
        let stats = IoStats::new();
        let vals = vec![1.5f32, -2.0, 0.0, 3.25];
        write_numerical(&path, &vals, stats.clone()).unwrap();
        let r = ColumnReader::open(&path, stats.clone()).unwrap();
        assert_eq!(r.header().rows, 4);
        assert_eq!(r.header().kind, FileKind::Numerical);
        assert_eq!(r.read_all_f32().unwrap(), vals);
        assert_eq!(stats.disk_write_passes(), 1);
        assert_eq!(stats.disk_read_passes(), 1);
        // 4 records * 4 bytes + header on both sides.
        assert_eq!(stats.disk_write_bytes(), 16 + 20);
        assert_eq!(stats.disk_read_bytes(), 16 + 20);
    }

    #[test]
    fn roundtrip_sorted() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("sorted.drfc");
        let stats = IoStats::new();
        let entries = vec![
            SortedEntry { value: 0.5, sample: 2 },
            SortedEntry { value: 1.5, sample: 0 },
        ];
        write_sorted(&path, &entries, stats.clone()).unwrap();
        let r = ColumnReader::open(&path, stats).unwrap();
        assert_eq!(r.read_all_sorted().unwrap(), entries);
    }

    #[test]
    fn roundtrip_categorical() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("cat.drfc");
        let stats = IoStats::new();
        write_categorical(&path, &[7, 8, 9], stats.clone()).unwrap();
        let r = ColumnReader::open(&path, stats).unwrap();
        assert_eq!(r.read_all_u32().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn roundtrip_v2_with_chunk_table() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.v2.drfc");
        let stats = IoStats::new();
        let vals: Vec<f32> = (0..10).map(|i| i as f32).collect();
        write_numerical_with(&path, &vals, Layout::V2 { chunk_rows: 4 }, stats.clone()).unwrap();
        let mut r = ColumnReader::open(&path, stats.clone()).unwrap();
        assert_eq!(r.header().version, 2);
        assert_eq!(r.header().chunks, vec![4, 4, 2]);
        // The reader announces the file's own chunk boundaries.
        let mut buf = Vec::new();
        let mut got = Vec::new();
        let mut sizes = Vec::new();
        loop {
            let want = r.next_chunk_records();
            if want == 0 {
                break;
            }
            let n = r.next_chunk_f32(&mut buf, want).unwrap();
            sizes.push(n);
            got.extend_from_slice(&buf);
        }
        r.end_pass();
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(got, vals);
        // Bytes: header (20 + 4 + 3*4 = 36) + 40 payload, one pass.
        assert_eq!(stats.disk_read_bytes(), 36 + 40);
        assert_eq!(stats.disk_read_passes(), 1);
    }

    #[test]
    fn v2_pass_can_stop_early() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("cat.v2.drfc");
        let stats = IoStats::new();
        let vals: Vec<u32> = (0..100).collect();
        write_categorical_with(&path, &vals, Layout::V2 { chunk_rows: 32 }, stats.clone())
            .unwrap();
        let mut r = ColumnReader::open(&path, stats.clone()).unwrap();
        let mut buf = Vec::new();
        // Read only the first chunk; the tail is never touched.
        let want = r.next_chunk_records();
        let n = r.next_chunk_u32(&mut buf, want).unwrap();
        assert_eq!(n, 32);
        assert_eq!(buf, (0..32).collect::<Vec<u32>>());
        assert_eq!(r.remaining(), 68);
        // Only header + one chunk charged.
        let header_bytes = r.header().nbytes();
        assert_eq!(stats.disk_read_bytes(), header_bytes + 32 * 4);
    }

    #[test]
    fn chunked_reads_match_record_reads() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("s.drfc");
        let stats = IoStats::new();
        let entries: Vec<SortedEntry> = (0..1000)
            .map(|i| SortedEntry {
                value: (i % 37) as f32,
                sample: i as u32,
            })
            .collect();
        write_sorted(&path, &entries, stats.clone()).unwrap();
        let mut r = ColumnReader::open(&path, stats.clone()).unwrap();
        let mut buf = Vec::new();
        let mut got = Vec::new();
        while r.next_chunk_sorted(&mut buf, 123).unwrap() > 0 {
            got.extend_from_slice(&buf);
        }
        r.end_pass();
        assert_eq!(got, entries);
        // Byte totals identical to a record-by-record pass.
        assert_eq!(stats.disk_read_bytes(), 20 + 8 * 1000);
        assert_eq!(stats.disk_read_passes(), 1);
    }

    #[test]
    fn layout_mismatch_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.drfc");
        let stats = IoStats::new();
        write_numerical(&path, &[1.0], stats.clone()).unwrap();
        let mut r = ColumnReader::open(&path, stats).unwrap();
        assert!(r.next_u32().is_err());
        assert!(r.next_chunk_u32(&mut Vec::new(), 8).is_err());
    }

    #[test]
    fn truncated_count_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.drfc");
        let stats = IoStats::new();
        let mut w = ColumnWriter::create(&path, FileKind::Numerical, 3, stats).unwrap();
        w.write_f32(1.0).unwrap();
        assert!(w.finish().is_err(), "declared 3 rows but wrote 1");
    }

    #[test]
    fn truncated_payload_rejected_at_open() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.drfc");
        let stats = IoStats::new();
        let vals = vec![1.0f32, 2.0, 3.0, 4.0];
        write_numerical(&path, &vals, stats.clone()).unwrap();
        // Chop two records off the tail; the header still claims 4.
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        let err = ColumnReader::open(&path, stats.clone()).unwrap_err();
        assert!(
            format!("{err:#}").contains("truncated column file"),
            "unexpected error: {err:#}"
        );
        // Same for v2 (header is larger, check survives the table).
        let path2 = dir.path().join("col.v2.drfc");
        write_numerical_with(&path2, &vals, Layout::V2 { chunk_rows: 2 }, stats.clone())
            .unwrap();
        let full = std::fs::read(&path2).unwrap();
        std::fs::write(&path2, &full[..full.len() - 4]).unwrap();
        assert!(ColumnReader::open(&path2, stats).is_err());
    }

    #[test]
    fn zero_chunk_rows_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("z.drfc");
        let err = write_numerical_with(
            &path,
            &[1.0, 2.0],
            Layout::V2 { chunk_rows: 0 },
            IoStats::new(),
        );
        assert!(err.is_err(), "chunk_rows = 0 must be an error, not a panic");
    }

    #[test]
    fn corrupt_chunk_table_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("c.v2.drfc");
        let stats = IoStats::new();
        write_categorical_with(&path, &[1, 2, 3], Layout::V2 { chunk_rows: 2 }, stats.clone())
            .unwrap();
        // Flip one chunk count so the table no longer sums to rows.
        let mut bytes = std::fs::read(&path).unwrap();
        // Layout: magic(4) version(4) kind(4) rows(8) nchunks(4) c0(4)…
        bytes[24] = 3; // first chunk count 2 -> 3
        std::fs::write(&path, &bytes).unwrap();
        assert!(ColumnReader::open(&path, stats).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("junk");
        std::fs::write(&path, b"JUNKJUNKJUNKJUNKJUNKJUNK").unwrap();
        assert!(ColumnReader::open(&path, IoStats::new()).is_err());
    }
}
