//! On-disk binary column files with strictly sequential access.
//!
//! DRF workers "only need to read their assigned part of the dataset
//! sequentially, i.e. no random access and no writing are needed" (paper
//! §2). This module provides that storage: one file per column, a small
//! header, then densely packed little-endian records. Readers and
//! writers are buffered and charge an [`IoStats`] so the complexity
//! benches can report bytes/passes per worker exactly as Table 1 does.
//!
//! Three record layouts:
//! * raw numerical column: `f32` per row;
//! * raw categorical column: `u32` per row;
//! * presorted numerical column (Alg. 1's `q(j)`): `(f32 value, u32
//!   sample)` pairs in value order — produced by the presorting phase
//!   ([`super::sort`]).

use super::column::SortedEntry;
use super::io_stats::IoStats;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// File magic: "DRFC" (DRF Column).
const MAGIC: [u8; 4] = *b"DRFC";
/// Format version.
const VERSION: u32 = 1;

/// Kind tag stored in the header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileKind {
    Numerical = 1,
    Categorical = 2,
    SortedNumerical = 3,
}

impl FileKind {
    fn from_u32(v: u32) -> Result<Self> {
        Ok(match v {
            1 => FileKind::Numerical,
            2 => FileKind::Categorical,
            3 => FileKind::SortedNumerical,
            _ => bail!("unknown column file kind {v}"),
        })
    }

    /// Bytes per record for this layout.
    pub fn record_bytes(self) -> usize {
        match self {
            FileKind::Numerical | FileKind::Categorical => 4,
            FileKind::SortedNumerical => 8,
        }
    }
}

/// Parsed column-file header.
#[derive(Debug, Clone, Copy)]
pub struct Header {
    pub kind: FileKind,
    pub rows: u64,
}

const HEADER_BYTES: u64 = 4 + 4 + 4 + 8; // magic, version, kind, rows

fn write_header(w: &mut impl Write, kind: FileKind, rows: u64) -> Result<()> {
    w.write_all(&MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(kind as u32).to_le_bytes())?;
    w.write_all(&rows.to_le_bytes())?;
    Ok(())
}

fn read_header(r: &mut impl Read) -> Result<Header> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("reading column magic")?;
    ensure!(magic == MAGIC, "bad column file magic");
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    ensure!(version == VERSION, "unsupported column file version {version}");
    r.read_exact(&mut b4)?;
    let kind = FileKind::from_u32(u32::from_le_bytes(b4))?;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8);
    Ok(Header { kind, rows })
}

/// Streaming writer for a column file.
pub struct ColumnWriter {
    w: BufWriter<File>,
    kind: FileKind,
    written: u64,
    declared: u64,
    stats: IoStats,
    path: PathBuf,
}

impl ColumnWriter {
    /// Create a file declaring `rows` records of `kind`.
    pub fn create(path: &Path, kind: FileKind, rows: u64, stats: IoStats) -> Result<Self> {
        let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        write_header(&mut w, kind, rows)?;
        stats.add_disk_write(HEADER_BYTES);
        Ok(Self {
            w,
            kind,
            written: 0,
            declared: rows,
            stats,
            path: path.to_path_buf(),
        })
    }

    pub fn write_f32(&mut self, v: f32) -> Result<()> {
        ensure!(self.kind == FileKind::Numerical, "layout mismatch");
        self.w.write_all(&v.to_le_bytes())?;
        self.written += 1;
        self.stats.add_disk_write(4);
        Ok(())
    }

    pub fn write_u32(&mut self, v: u32) -> Result<()> {
        ensure!(self.kind == FileKind::Categorical, "layout mismatch");
        self.w.write_all(&v.to_le_bytes())?;
        self.written += 1;
        self.stats.add_disk_write(4);
        Ok(())
    }

    pub fn write_sorted(&mut self, e: SortedEntry) -> Result<()> {
        ensure!(self.kind == FileKind::SortedNumerical, "layout mismatch");
        self.w.write_all(&e.value.to_le_bytes())?;
        self.w.write_all(&e.sample.to_le_bytes())?;
        self.written += 1;
        self.stats.add_disk_write(8);
        Ok(())
    }

    /// Finish the file; counts one write pass and validates the declared
    /// row count.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush()?;
        ensure!(
            self.written == self.declared,
            "{}: wrote {} records, declared {}",
            self.path.display(),
            self.written,
            self.declared
        );
        self.stats.add_write_pass();
        Ok(())
    }
}

/// Buffered sequential reader over a column file.
pub struct ColumnReader {
    r: BufReader<File>,
    header: Header,
    read: u64,
    stats: IoStats,
}

impl ColumnReader {
    pub fn open(path: &Path, stats: IoStats) -> Result<Self> {
        let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::with_capacity(1 << 20, f);
        let header = read_header(&mut r)?;
        stats.add_disk_read(HEADER_BYTES);
        Ok(Self {
            r,
            header,
            read: 0,
            stats,
        })
    }

    pub fn header(&self) -> Header {
        self.header
    }

    pub fn remaining(&self) -> u64 {
        self.header.rows - self.read
    }

    pub fn next_f32(&mut self) -> Result<f32> {
        ensure!(self.header.kind == FileKind::Numerical, "layout mismatch");
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        self.read += 1;
        self.stats.add_disk_read(4);
        Ok(f32::from_le_bytes(b))
    }

    pub fn next_u32(&mut self) -> Result<u32> {
        ensure!(self.header.kind == FileKind::Categorical, "layout mismatch");
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        self.read += 1;
        self.stats.add_disk_read(4);
        Ok(u32::from_le_bytes(b))
    }

    pub fn next_sorted(&mut self) -> Result<SortedEntry> {
        ensure!(
            self.header.kind == FileKind::SortedNumerical,
            "layout mismatch"
        );
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        self.read += 1;
        self.stats.add_disk_read(8);
        Ok(SortedEntry {
            value: f32::from_le_bytes(b[0..4].try_into().unwrap()),
            sample: u32::from_le_bytes(b[4..8].try_into().unwrap()),
        })
    }

    /// Read the whole remainder as sorted entries (counts one pass).
    pub fn read_all_sorted(mut self) -> Result<Vec<SortedEntry>> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        while self.remaining() > 0 {
            out.push(self.next_sorted()?);
        }
        self.stats.add_read_pass();
        Ok(out)
    }

    /// Read the whole remainder as f32 (counts one pass).
    pub fn read_all_f32(mut self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        while self.remaining() > 0 {
            out.push(self.next_f32()?);
        }
        self.stats.add_read_pass();
        Ok(out)
    }

    /// Read the whole remainder as u32 (counts one pass).
    pub fn read_all_u32(mut self) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        while self.remaining() > 0 {
            out.push(self.next_u32()?);
        }
        self.stats.add_read_pass();
        Ok(out)
    }

    /// Mark the end of a logical pass (when the caller reads record by
    /// record instead of via `read_all_*`).
    pub fn end_pass(&self) {
        self.stats.add_read_pass();
    }
}

/// Write a full numerical column to `path`.
pub fn write_numerical(path: &Path, values: &[f32], stats: IoStats) -> Result<()> {
    let mut w = ColumnWriter::create(path, FileKind::Numerical, values.len() as u64, stats)?;
    for &v in values {
        w.write_f32(v)?;
    }
    w.finish()
}

/// Write a full categorical column to `path`.
pub fn write_categorical(path: &Path, values: &[u32], stats: IoStats) -> Result<()> {
    let mut w = ColumnWriter::create(path, FileKind::Categorical, values.len() as u64, stats)?;
    for &v in values {
        w.write_u32(v)?;
    }
    w.finish()
}

/// Write a raw u32 column (e.g. the label column) — alias of
/// [`write_categorical`] with a name that doesn't imply arity checks.
pub fn write_categorical_raw(path: &Path, values: &[u32], stats: IoStats) -> Result<()> {
    write_categorical(path, values, stats)
}

/// Write a presorted numerical column to `path`.
pub fn write_sorted(path: &Path, entries: &[SortedEntry], stats: IoStats) -> Result<()> {
    let mut w = ColumnWriter::create(
        path,
        FileKind::SortedNumerical,
        entries.len() as u64,
        stats,
    )?;
    for &e in entries {
        w.write_sorted(e)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_numerical() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.drfc");
        let stats = IoStats::new();
        let vals = vec![1.5f32, -2.0, 0.0, 3.25];
        write_numerical(&path, &vals, stats.clone()).unwrap();
        let r = ColumnReader::open(&path, stats.clone()).unwrap();
        assert_eq!(r.header().rows, 4);
        assert_eq!(r.header().kind, FileKind::Numerical);
        assert_eq!(r.read_all_f32().unwrap(), vals);
        assert_eq!(stats.disk_write_passes(), 1);
        assert_eq!(stats.disk_read_passes(), 1);
        // 4 records * 4 bytes + header on both sides.
        assert_eq!(stats.disk_write_bytes(), 16 + 20);
        assert_eq!(stats.disk_read_bytes(), 16 + 20);
    }

    #[test]
    fn roundtrip_sorted() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("sorted.drfc");
        let stats = IoStats::new();
        let entries = vec![
            SortedEntry { value: 0.5, sample: 2 },
            SortedEntry { value: 1.5, sample: 0 },
        ];
        write_sorted(&path, &entries, stats.clone()).unwrap();
        let r = ColumnReader::open(&path, stats).unwrap();
        assert_eq!(r.read_all_sorted().unwrap(), entries);
    }

    #[test]
    fn roundtrip_categorical() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("cat.drfc");
        let stats = IoStats::new();
        write_categorical(&path, &[7, 8, 9], stats.clone()).unwrap();
        let r = ColumnReader::open(&path, stats).unwrap();
        assert_eq!(r.read_all_u32().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn layout_mismatch_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.drfc");
        let stats = IoStats::new();
        write_numerical(&path, &[1.0], stats.clone()).unwrap();
        let mut r = ColumnReader::open(&path, stats).unwrap();
        assert!(r.next_u32().is_err());
    }

    #[test]
    fn truncated_count_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("col.drfc");
        let stats = IoStats::new();
        let mut w = ColumnWriter::create(&path, FileKind::Numerical, 3, stats).unwrap();
        w.write_f32(1.0).unwrap();
        assert!(w.finish().is_err(), "declared 3 rows but wrote 1");
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("junk");
        std::fs::write(&path, b"JUNKJUNKJUNKJUNKJUNKJUNK").unwrap();
        assert!(ColumnReader::open(&path, IoStats::new()).is_err());
    }
}
