//! Zero-copy mmap [`ColumnStore`] backend.
//!
//! [`DiskStore`](super::store::DiskStore) re-reads warm page-cache
//! bytes through `read(2)` into bounded buffers on every pass — at
//! billions of examples the per-level syscall + copy tax on the
//! splitter scans *is* training overhead (the paper's complexity
//! analysis charges one sequential pass per column per level, so scan
//! throughput is training throughput). [`MmapStore`] maps each DRFC
//! column file once and hands the scan visitors **borrowed slices
//! straight out of the mapping**: after the first (page-faulting) pass
//! a scan touches no syscalls and copies no bytes.
//!
//! * On unix the mapping is real `mmap(2)` via self-declared FFI (no
//!   new crates — the dependency policy is anyhow-only), advised
//!   `MADV_SEQUENTIAL` to keep kernel readahead aligned with the
//!   strictly sequential scan discipline of paper §2.
//! * On non-unix platforms the same type falls back to one buffered
//!   whole-file read at open; scans then serve borrowed slices from the
//!   owned buffer (same API, same accounting, no mapping).
//!
//! Validation happens **at open**, exactly like the streaming reader:
//! DRFC v1/v2 magic/version/kind, chunk-table consistency, and the
//! truncation check (payload at least `rows × record_bytes`). A
//! truncated or forged file is rejected before any scan runs
//! (`tests/storage_backends.rs` holds the rejection matrix).
//!
//! Accounting: the header is charged at open (like
//! [`ColumnReader::open`](super::disk::ColumnReader)); a file's payload
//! bytes and its read pass are charged on the **first-touch pass**
//! only — that pass is the one that actually faults pages in from
//! disk. Warm re-scans are free, like [`MemStore`](super::store::MemStore)
//! scans, which is precisely the economy the backend exists to exhibit
//! in the Table 1 benches.
//!
//! Byte→record reinterpretation is zero-copy only on little-endian
//! targets with the 4-byte payload alignment every DRFC header
//! guarantees (v1 header = 20 bytes, v2 = 20 + 4 + 4·chunks); otherwise
//! chunks are decoded through a scratch buffer, bit-identically.

use super::column::SortedEntry;
use super::disk::{self, Header};
use super::io_stats::IoStats;
use super::schema::ColumnType;
use super::store::{ColumnFiles, ColumnStore, RawChunk};
use crate::Result;
use anyhow::{ensure, Context};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};

// ---------------------------------------------------------------------
// The mapping itself (unix mmap / non-unix buffered fallback)
// ---------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_PRIVATE: c_int = 0x2;
    pub const MADV_SEQUENTIAL: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// One read-only mapped (or buffered) file.
enum Backing {
    #[cfg(unix)]
    Mapped {
        ptr: *mut std::os::raw::c_void,
        len: usize,
    },
    /// Non-unix fallback (and zero-length guard): the file read once
    /// into an owned buffer at open.
    #[allow(dead_code)]
    Buffered(Vec<u8>),
}

// The mapping is read-only for its entire lifetime; sharing the raw
// pointer across scan threads is safe because nothing ever writes
// through it and `munmap` only runs at drop (after all borrows end).
unsafe impl Send for Backing {}
unsafe impl Sync for Backing {}

impl Backing {
    #[cfg(unix)]
    fn open(path: &Path) -> Result<Backing> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            // mmap(2) rejects zero-length mappings.
            return Ok(Backing::Buffered(Vec::new()));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        ensure!(
            ptr as isize != -1,
            "mmap of {} ({len} bytes) failed: {}",
            path.display(),
            std::io::Error::last_os_error()
        );
        // Readahead hint; purely advisory, failure is not an error.
        unsafe { sys::madvise(ptr, len, sys::MADV_SEQUENTIAL) };
        Ok(Backing::Mapped { ptr, len })
    }

    #[cfg(not(unix))]
    fn open(path: &Path) -> Result<Backing> {
        Ok(Backing::Buffered(std::fs::read(path).with_context(
            || format!("reading {}", path.display()),
        )?))
    }

    fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            Backing::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr as *const u8, *len)
            },
            Backing::Buffered(v) => v.as_slice(),
        }
    }
}

impl Drop for Backing {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = *self {
            unsafe { sys::munmap(ptr, len) };
        }
    }
}

// ---------------------------------------------------------------------
// Record reinterpretation
// ---------------------------------------------------------------------

/// Reinterpret the packed little-endian payload as records of `T`, or
/// `None` if the platform cannot do it zero-copy (big-endian, or a
/// misaligned buffer — DRFC headers are 4-byte multiples, so mapped
/// payloads are always aligned; the fallback only triggers on exotic
/// targets).
fn cast_records<T: Copy>(payload: &[u8]) -> Option<&[T]> {
    if cfg!(target_endian = "big") {
        return None;
    }
    let size = std::mem::size_of::<T>();
    if payload.len() % size != 0 || payload.as_ptr() as usize % std::mem::align_of::<T>() != 0 {
        return None;
    }
    // Safety: T is one of f32/u32/SortedEntry — Copy, repr(C), no
    // padding, valid for every bit pattern — and the pointer is
    // aligned, in-bounds, and read-only for the borrow's lifetime.
    Some(unsafe {
        std::slice::from_raw_parts(payload.as_ptr() as *const T, payload.len() / size)
    })
}

/// One mapped DRFC column file.
struct MappedFile {
    backing: Backing,
    header: Header,
    payload: std::ops::Range<usize>,
    /// Whether a pass has already faulted this file in (first-touch
    /// accounting; see module docs).
    touched: AtomicBool,
}

impl MappedFile {
    fn open(path: &Path, expect: disk::FileKind, stats: &IoStats) -> Result<MappedFile> {
        let backing = Backing::open(path)?;
        let bytes = backing.bytes();
        let header = Header::parse(bytes)
            .with_context(|| format!("reading header of {}", path.display()))?;
        ensure!(
            header.kind == expect,
            "{}: file holds {:?}, expected {:?}",
            path.display(),
            header.kind,
            expect
        );
        // Same truncation rejection as the streaming reader's open.
        header.ensure_untruncated(bytes.len() as u64, path)?;
        let start = header.nbytes() as usize;
        let end = start + header.rows as usize * header.kind.record_bytes();
        stats.add_disk_read(header.nbytes());
        Ok(MappedFile {
            backing,
            header,
            payload: start..end,
            touched: AtomicBool::new(false),
        })
    }

    fn payload(&self) -> &[u8] {
        &self.backing.bytes()[self.payload.clone()]
    }

    /// Charge this file's payload + pass if this is its first scan.
    fn charge_first_touch(&self, stats: &IoStats) {
        if !self.touched.swap(true, Ordering::Relaxed) {
            stats.add_disk_read(self.payload.len() as u64);
            stats.add_read_pass();
        }
    }

    /// Feed the payload to `visit` as `(base_record, &[T])` chunks
    /// following the file's chunk plan — zero-copy when the platform
    /// allows, decoded through a scratch buffer otherwise.
    fn scan<T: Copy>(
        &self,
        decode: impl Fn(&[u8], &mut Vec<T>),
        mut visit: impl FnMut(usize, &[T]) -> Result<()>,
    ) -> Result<()> {
        let payload = self.payload();
        let rec = self.header.kind.record_bytes();
        let mut base = 0usize;
        match cast_records::<T>(payload) {
            Some(records) => {
                for want in self.header.chunk_plan() {
                    visit(base, &records[base..base + want])?;
                    base += want;
                }
            }
            None => {
                let mut buf: Vec<T> = Vec::new();
                for want in self.header.chunk_plan() {
                    decode(&payload[base * rec..(base + want) * rec], &mut buf);
                    visit(base, buf.as_slice())?;
                    base += want;
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// MmapStore
// ---------------------------------------------------------------------

struct MmapColumn {
    raw: MappedFile,
    sorted: Option<MappedFile>,
    ctype: ColumnType,
}

/// Memory-mapped DRFC columns: scans hand out borrowed chunk slices
/// straight from the mapping (see module docs for accounting and
/// platform behavior). Files are validated at open and never copied.
pub struct MmapStore {
    columns: BTreeMap<usize, MmapColumn>,
    stats: IoStats,
}

impl MmapStore {
    /// Map column files that already exist on disk (a shard pack, a
    /// dataset directory, or files written by [`MmapStore::build`]).
    /// Every header is parsed and validated up front.
    pub fn open(files: BTreeMap<usize, ColumnFiles>, stats: IoStats) -> Result<MmapStore> {
        let mut columns = BTreeMap::new();
        for (j, f) in files {
            let expect = match f.ctype {
                ColumnType::Numerical => disk::FileKind::Numerical,
                ColumnType::Categorical { .. } => disk::FileKind::Categorical,
            };
            let raw = MappedFile::open(&f.raw, expect, &stats)
                .with_context(|| format!("column {j}"))?;
            let sorted = f
                .sorted
                .as_ref()
                .map(|sp| {
                    MappedFile::open(sp, disk::FileKind::SortedNumerical, &stats)
                        .with_context(|| format!("column {j} (presorted)"))
                })
                .transpose()?;
            columns.insert(
                j,
                MmapColumn {
                    raw,
                    sorted,
                    ctype: f.ctype,
                },
            );
        }
        Ok(MmapStore { columns, stats })
    }

    /// Write `columns` of `ds` as chunked DRFC v2 files under `dir`
    /// (presorting numerical columns) and map them — the mmap
    /// equivalent of [`super::store::DiskV2Store::build`].
    pub fn build(
        ds: &super::dataset::Dataset,
        columns: &[usize],
        dir: &Path,
        chunk_rows: u32,
        stats: IoStats,
    ) -> Result<MmapStore> {
        let layout = disk::Layout::V2 { chunk_rows };
        let mut files = BTreeMap::new();
        for &j in columns {
            let raw = dir.join(format!("col_{j}.drfc"));
            let ctype = ds.schema().columns[j].ctype;
            let mut sorted_path = None;
            match ds.column(j) {
                super::column::Column::Numerical(vals) => {
                    disk::write_numerical_with(&raw, vals, layout, stats.clone())?;
                    let sp = dir.join(format!("col_{j}.sorted.drfc"));
                    disk::write_sorted_with(&sp, &ds.column(j).presort(), layout, stats.clone())?;
                    sorted_path = Some(sp);
                }
                super::column::Column::Categorical { values, .. } => {
                    disk::write_categorical_with(&raw, values, layout, stats.clone())?;
                }
            }
            files.insert(
                j,
                ColumnFiles {
                    raw,
                    sorted: sorted_path,
                    ctype,
                },
            );
        }
        MmapStore::open(files, stats)
    }

    fn column(&self, j: usize) -> Result<&MmapColumn> {
        self.columns
            .get(&j)
            .ok_or_else(|| anyhow::anyhow!("store lacks column {j}"))
    }

    /// Whole raw file bytes of column `j` (header + payload), straight
    /// from the mapping — lets a worker checksum its shard pack against
    /// the manifest over the *exact bytes training will scan*, warming
    /// the pages on the way.
    pub fn raw_file_bytes(&self, j: usize) -> Result<&[u8]> {
        Ok(self.column(j)?.raw.backing.bytes())
    }

    /// Whole presorted file bytes of column `j`, if it has one.
    pub fn sorted_file_bytes(&self, j: usize) -> Result<Option<&[u8]>> {
        Ok(self.column(j)?.sorted.as_ref().map(|m| m.backing.bytes()))
    }
}

impl ColumnStore for MmapStore {
    fn columns(&self) -> Vec<usize> {
        self.columns.keys().copied().collect()
    }

    fn column_type(&self, j: usize) -> Result<ColumnType> {
        Ok(self.column(j)?.ctype)
    }

    fn scan_raw(
        &self,
        j: usize,
        visit: &mut dyn FnMut(usize, RawChunk<'_>) -> Result<()>,
    ) -> Result<()> {
        let col = self.column(j)?;
        col.raw.charge_first_touch(&self.stats);
        match col.ctype {
            ColumnType::Numerical => col.raw.scan::<f32>(disk::decode_f32, |base, chunk: &[f32]| {
                visit(base, RawChunk::Numerical(chunk))
            }),
            ColumnType::Categorical { .. } => {
                col.raw.scan::<u32>(disk::decode_u32, |base, chunk: &[u32]| {
                    visit(base, RawChunk::Categorical(chunk))
                })
            }
        }
    }

    fn scan_sorted(
        &self,
        j: usize,
        visit: &mut dyn FnMut(&[SortedEntry]) -> Result<()>,
    ) -> Result<()> {
        let col = self.column(j)?;
        let m = col
            .sorted
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("column {j} has no presorted file"))?;
        m.charge_first_touch(&self.stats);
        m.scan::<SortedEntry>(disk::decode_sorted, |_base, chunk: &[SortedEntry]| {
            visit(chunk)
        })
    }

    fn borrow_sorted(&self, j: usize) -> Option<&[SortedEntry]> {
        let m = self.columns.get(&j)?.sorted.as_ref()?;
        let entries = cast_records::<SortedEntry>(m.payload())?;
        m.charge_first_touch(&self.stats);
        Some(entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::{self, mem_store_for};
    use crate::data::synthetic::LeoLikeSpec;
    use std::sync::Arc;

    fn mmap_over(ds: &crate::data::Dataset, cols: &[usize], dir: &Path) -> MmapStore {
        MmapStore::build(ds, cols, dir, 97, IoStats::new()).unwrap()
    }

    #[test]
    fn scans_match_memory_backend() {
        let ds = LeoLikeSpec::new(600, 5).generate();
        let cols = vec![0usize, 1, 3, 5];
        let dir = crate::util::tempdir().unwrap();
        let mem = mem_store_for(&ds, &cols);
        let mm = mmap_over(&ds, &cols, dir.path());
        assert_eq!(ColumnStore::columns(&mm), cols);
        for &j in &cols {
            assert_eq!(mm.column_type(j).unwrap(), ds.schema().columns[j].ctype);
            assert_eq!(mm.read_raw(j).unwrap(), mem.read_raw(j).unwrap(), "col {j}");
            if ds.column(j).is_numerical() {
                assert_eq!(mm.read_sorted(j).unwrap(), mem.read_sorted(j).unwrap());
                // The presorted view is borrowable zero-copy.
                let b = mm.borrow_sorted(j).expect("mapped borrow");
                assert_eq!(b, mem.borrow_sorted(j).unwrap());
            }
        }
        // Chunks arrive in order with correct bases, per the v2 table.
        let mut seen = 0usize;
        mm.scan_raw(cols[0], &mut |base, chunk| {
            assert_eq!(base, seen);
            assert!(chunk.len() <= 97);
            seen += chunk.len();
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, ds.num_rows());
        // Missing column errors.
        assert!(mm.scan_raw(2, &mut |_, _| Ok(())).is_err());
    }

    #[test]
    fn first_touch_accounting() {
        let ds = LeoLikeSpec::new(300, 9).generate();
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        // Build charges the writes; open charges each header once.
        let mm = MmapStore::build(&ds, &[0], dir.path(), 64, stats.clone()).unwrap();
        stats.reset();
        let before = stats.snapshot();
        mm.read_raw(0).unwrap();
        let first = stats.snapshot().delta_since(&before);
        assert_eq!(first.disk_read_bytes, 300 * 4, "payload charged on first touch");
        assert_eq!(first.disk_read_passes, 1);
        // Warm re-scan: free, like MemStore.
        mm.read_raw(0).unwrap();
        let warm = stats.snapshot().delta_since(&before);
        assert_eq!(warm.disk_read_bytes, first.disk_read_bytes);
        assert_eq!(warm.disk_read_passes, first.disk_read_passes);
        // The sorted view has its own first touch.
        mm.read_sorted(0).unwrap();
        let sorted = stats.snapshot().delta_since(&before);
        assert_eq!(sorted.disk_read_bytes, 300 * 4 + 300 * 8);
        assert_eq!(sorted.disk_read_passes, 2);
    }

    #[test]
    fn v1_files_map_too() {
        let dir = crate::util::tempdir().unwrap();
        let path = dir.path().join("v1.drfc");
        let stats = IoStats::new();
        let vals: Vec<f32> = (0..1000).map(|i| (i % 31) as f32).collect();
        disk::write_numerical(&path, &vals, stats.clone()).unwrap();
        let mut files = BTreeMap::new();
        files.insert(
            0usize,
            ColumnFiles {
                raw: path,
                sorted: None,
                ctype: ColumnType::Numerical,
            },
        );
        let mm = MmapStore::open(files, stats).unwrap();
        assert_eq!(mm.read_raw(0).unwrap().as_numerical(), vals.as_slice());
    }

    #[test]
    fn truncated_and_forged_files_rejected_at_open() {
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let vals: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let open_one = |path: std::path::PathBuf| {
            let mut files = BTreeMap::new();
            files.insert(
                0usize,
                ColumnFiles {
                    raw: path,
                    sorted: None,
                    ctype: ColumnType::Numerical,
                },
            );
            MmapStore::open(files, IoStats::new())
        };
        // Truncated payload.
        let p = dir.path().join("t.drfc");
        disk::write_numerical(&p, &vals, stats.clone()).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 8]).unwrap();
        let err = open_one(p).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        // Forged magic.
        let p = dir.path().join("m.drfc");
        disk::write_numerical(&p, &vals, stats.clone()).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] = b'X';
        std::fs::write(&p, &bytes).unwrap();
        assert!(open_one(p).is_err());
        // Forged v2 chunk table (sums past the row count).
        let p = dir.path().join("c.drfc");
        disk::write_numerical_with(&p, &vals, disk::Layout::V2 { chunk_rows: 16 }, stats)
            .unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[24] = 60; // first chunk count 16 -> 60
        std::fs::write(&p, &bytes).unwrap();
        assert!(open_one(p).is_err());
        // Kind mismatch vs the manifest-declared type.
        let p = dir.path().join("k.drfc");
        disk::write_categorical(&p, &[1, 2, 3], IoStats::new()).unwrap();
        let err = open_one(p).unwrap_err();
        assert!(format!("{err:#}").contains("expected"), "{err:#}");
    }

    #[test]
    fn plugs_into_the_store_seam() {
        // The trait-object seam every scan site uses.
        let ds = LeoLikeSpec::new(200, 3).generate();
        let dir = crate::util::tempdir().unwrap();
        let mm: Arc<dyn ColumnStore> =
            Arc::new(mmap_over(&ds, &[0, 1], dir.path()));
        let got = store::run_scans(2, 2, |k| mm.read_raw(k)).unwrap();
        assert_eq!(&got[0], ds.column(0));
        assert_eq!(&got[1], ds.column(1));
    }
}
