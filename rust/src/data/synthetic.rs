//! Synthetic dataset families (paper §4) and the Leo-like stand-in for
//! the proprietary real-world dataset (paper §5).
//!
//! The artificial families follow (P. Geurts, Guillame-Bert & Teytaud
//! 2018, "Synthetic vectorized datasets for large scale machine learning
//! experiments"): binary classification, a ground-truth function over
//! `informative` binary features (XOR/parity, Majority, Needle), plus any
//! number of *useless variables* (UV) with no correlation to the label.
//! Feature values are generated *statelessly* — value(row, col) is a pure
//! hash of `(seed, col, row)` — so datasets of billions of rows could be
//! streamed without materialization, and any subset is reproducible.
//!
//! The **Leo-like** family mirrors the schema of the paper's Leo dataset:
//! 3 numerical + 69 categorical features with arities log-spaced 2..10'000,
//! an unbalanced (~5% positive) label, and a noisy tree-structured ground
//! truth touching a minority of the features. It does not (cannot)
//! reproduce Leo's values; it reproduces the *shape* that drives DRF's
//! code paths: mixed types, high arity, imbalance.

use super::column::Column;
use super::dataset::Dataset;
use super::schema::{ColumnSpec, Schema};
use crate::rng::SplitMix64;

/// Ground-truth family for synthetic generation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Family {
    /// Label = parity (XOR) of the first `informative` binary features.
    Xor { informative: usize },
    /// Label = majority vote of the first `informative` binary features
    /// (use odd `informative` to avoid ties; ties break to 0).
    Majority { informative: usize },
    /// Label = 1 iff *all* of the first `informative` binary features are
    /// 1 — the paper's "highly imbalanced needle" (positive rate 2^-k).
    Needle { informative: usize },
    /// Continuous features in [0,1); label = 1 iff the sum of the first
    /// `informative` features exceeds `informative / 2`. Exercises real
    /// numerical thresholds rather than the 0.5 cut of binary families.
    LinearCont { informative: usize },
}

impl Family {
    /// Number of informative features of the family.
    pub fn informative(&self) -> usize {
        match *self {
            Family::Xor { informative }
            | Family::Majority { informative }
            | Family::Needle { informative }
            | Family::LinearCont { informative } => informative,
        }
    }

    /// Short family name (CLI `--family` spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Family::Xor { .. } => "xor",
            Family::Majority { .. } => "majority",
            Family::Needle { .. } => "needle",
            Family::LinearCont { .. } => "linear",
        }
    }

    fn is_binary(&self) -> bool {
        !matches!(self, Family::LinearCont { .. })
    }
}

/// Specification of a synthetic dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyntheticSpec {
    /// Ground-truth family.
    pub family: Family,
    /// Number of rows (paper's `n`).
    pub rows: usize,
    /// Total number of features `m` (informative + useless); must be
    /// >= `family.informative()`.
    pub features: usize,
    /// Generation seed. Different seeds = independent datasets (train vs
    /// test).
    pub seed: u64,
    /// Probability of flipping the label (label noise); 0 by default.
    pub label_noise: f64,
}

impl SyntheticSpec {
    /// Spec with no label noise (see [`Self::with_label_noise`]).
    pub fn new(family: Family, rows: usize, features: usize, seed: u64) -> Self {
        assert!(
            features >= family.informative(),
            "need at least {} features",
            family.informative()
        );
        assert!(family.informative() > 0, "need at least one informative feature");
        Self {
            family,
            rows,
            features,
            seed,
            label_noise: 0.0,
        }
    }

    /// Flip each label with probability `p`.
    pub fn with_label_noise(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.label_noise = p;
        self
    }

    /// Number of useless variables.
    pub fn useless(&self) -> usize {
        self.features - self.family.informative()
    }

    /// Stateless uniform in [0,1) for (col, row).
    #[inline]
    fn uniform(&self, col: usize, row: usize) -> f64 {
        let h = SplitMix64::hash_key(&[self.seed, 0x5EED ^ col as u64, row as u64]);
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Stateless binary feature for (col, row).
    #[inline]
    fn bit(&self, col: usize, row: usize) -> bool {
        self.uniform(col, row) >= 0.5
    }

    /// Feature value as stored in the (numerical) column.
    #[inline]
    pub fn value(&self, col: usize, row: usize) -> f32 {
        if self.family.is_binary() {
            if self.bit(col, row) {
                1.0
            } else {
                0.0
            }
        } else {
            self.uniform(col, row) as f32
        }
    }

    /// Ground-truth label before noise.
    pub fn clean_label(&self, row: usize) -> u32 {
        let k = self.family.informative();
        match self.family {
            Family::Xor { .. } => {
                let mut parity = false;
                for j in 0..k {
                    parity ^= self.bit(j, row);
                }
                parity as u32
            }
            Family::Majority { .. } => {
                let ones = (0..k).filter(|&j| self.bit(j, row)).count();
                (2 * ones > k) as u32
            }
            Family::Needle { .. } => (0..k).all(|j| self.bit(j, row)) as u32,
            Family::LinearCont { .. } => {
                let s: f64 = (0..k).map(|j| self.uniform(j, row)).sum();
                (s > k as f64 / 2.0) as u32
            }
        }
    }

    /// Label with noise applied.
    pub fn label(&self, row: usize) -> u32 {
        let y = self.clean_label(row);
        if self.label_noise > 0.0 {
            let h = SplitMix64::hash_key(&[self.seed, 0xF11B, row as u64]);
            let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            if u < self.label_noise {
                return 1 - y;
            }
        }
        y
    }

    /// Materialize the dataset.
    pub fn generate(&self) -> Dataset {
        let schema = Schema::new(
            (0..self.features)
                .map(|j| ColumnSpec::numerical(format!("f{j}")))
                .collect(),
            2,
        );
        let columns: Vec<Column> = (0..self.features)
            .map(|j| {
                Column::Numerical((0..self.rows).map(|i| self.value(j, i)).collect())
            })
            .collect();
        let labels: Vec<u32> = (0..self.rows).map(|i| self.label(i)).collect();
        Dataset::new(schema, columns, labels)
    }
}

/// Specification of the Leo-like dataset (paper §5 stand-in).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LeoLikeSpec {
    /// Number of rows to materialize.
    pub rows: usize,
    /// Generation seed.
    pub seed: u64,
}

impl LeoLikeSpec {
    /// Paper schema: 3 numerical features…
    pub const NUM_NUMERICAL: usize = 3;
    /// …plus 69 categorical features.
    pub const NUM_CATEGORICAL: usize = 69;
    /// Categorical features that carry signal — spread across the arity
    /// range (2 .. 10'000), because in real high-arity data (ids,
    /// cities, SKUs) the heavy values repeat and carry behaviour. This
    /// also makes the high-arity split path *meaningful*, not just
    /// memorizable noise.
    pub const INFORMATIVE_CATS: [usize; 8] = [0, 1, 2, 3, 20, 35, 50, 65];

    /// Spec for `rows` rows generated from `seed`.
    pub fn new(rows: usize, seed: u64) -> Self {
        Self { rows, seed }
    }

    /// Paper-scale arity of categorical feature `c` (0-based among
    /// categoricals): log-spaced from 2 to 10'000, like Leo's
    /// "2 to 10'000".
    pub fn paper_arity(c: usize) -> u32 {
        let t = c as f64 / (Self::NUM_CATEGORICAL - 1) as f64;
        (2.0 * (5000.0f64).powf(t)).round() as u32
    }

    /// Arity actually used at this dataset scale: the paper trains on
    /// 17.3e9 rows, so even arity-10'000 features have >10^6 rows per
    /// value and exact subset splits are statistically safe. Scaling n
    /// down by ~5 orders of magnitude without scaling arity would make
    /// high-arity features pure memorization fuel (every value nearly
    /// unique), which is NOT the regime the paper operates in. We
    /// preserve the paper's rows-per-value regime by capping arity at
    /// `rows / 256` (min 2) — see DESIGN.md §1.
    pub fn arity_at(&self, c: usize) -> u32 {
        let cap = (self.rows as u32 / 256).max(2);
        Self::paper_arity(c).min(cap)
    }

    /// Schema at this dataset's scale.
    pub fn schema(&self) -> Schema {
        let mut cols = Vec::with_capacity(Self::NUM_NUMERICAL + Self::NUM_CATEGORICAL);
        for j in 0..Self::NUM_NUMERICAL {
            cols.push(ColumnSpec::numerical(format!("num{j}")));
        }
        for c in 0..Self::NUM_CATEGORICAL {
            cols.push(ColumnSpec::categorical(format!("cat{c}"), self.arity_at(c)));
        }
        Schema::new(cols, 2)
    }

    #[inline]
    fn uniform(&self, tag: u64, a: u64, b: u64) -> f64 {
        let h = SplitMix64::hash_key(&[self.seed, tag, a, b]);
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Value of numerical feature `j` at `row`: standard-normal-ish via
    /// sum of uniforms (Irwin-Hall, shifted) — cheap and deterministic.
    #[inline]
    pub fn numerical_value(&self, j: usize, row: usize) -> f32 {
        let s: f64 = (0..4)
            .map(|k| self.uniform(0x401 + k, j as u64, row as u64))
            .sum();
        ((s - 2.0) * (12.0f64 / 4.0).sqrt()) as f32
    }

    /// Value of categorical feature `c` (0-based among categoricals).
    /// Skewed (Zipf-ish) distribution: real-world high-arity categoricals
    /// are never uniform.
    #[inline]
    pub fn categorical_value(&self, c: usize, row: usize) -> u32 {
        let arity = self.arity_at(c) as f64;
        let u = self.uniform(0xCA7, c as u64, row as u64);
        // Power-law mass: v = floor(arity * u^2) concentrates on small ids.
        ((arity * u * u) as u32).min(self.arity_at(c) - 1)
    }

    /// Per-category latent effect of an informative categorical feature:
    /// a deterministic pseudo-random weight in [-1, 1].
    #[inline]
    fn category_effect(&self, c: usize, value: u32) -> f64 {
        2.0 * self.uniform(0xEFF, c as u64, value as u64) - 1.0
    }

    /// Latent score; the label is a noisy threshold of this.
    pub fn score(&self, row: usize) -> f64 {
        // Numerical features 0 and 1 are informative; 2 is noise.
        let mut s = 1.2 * self.numerical_value(0, row) as f64
            - 0.8 * self.numerical_value(1, row) as f64;
        // Informative categoricals carry per-category effects, with an
        // interaction term to make the ground truth tree-like
        // (axis-aligned splits can capture it, linear models cannot
        // fully).
        for &c in Self::INFORMATIVE_CATS.iter() {
            let v = self.categorical_value(c, row);
            s += 1.3 * self.category_effect(c, v);
        }
        let v0 = self.categorical_value(0, row);
        let v1 = self.categorical_value(1, row);
        if self.category_effect(0, v0) > 0.0 && self.category_effect(1, v1) > 0.0 {
            s += 2.0;
        }
        s
    }

    /// Unbalanced label: P(y=1) = sigmoid(score - 3.2) ≈ 5% base rate.
    pub fn label(&self, row: usize) -> u32 {
        let p = 1.0 / (1.0 + (-(self.score(row) - 3.2)).exp());
        let u = self.uniform(0x1AB, row as u64, 0);
        (u < p) as u32
    }

    /// Materialize rows `[start, start + count)`. The concept (per-
    /// category effects, feature weights) is a pure function of the
    /// seed, so disjoint row ranges from the same spec are train/test
    /// splits of the *same* learning problem.
    pub fn generate_rows(&self, start: usize, count: usize) -> Dataset {
        let schema = self.schema();
        let rows = start..start + count;
        let mut columns = Vec::with_capacity(schema.num_features());
        for j in 0..Self::NUM_NUMERICAL {
            columns.push(Column::Numerical(
                rows.clone().map(|i| self.numerical_value(j, i)).collect(),
            ));
        }
        for c in 0..Self::NUM_CATEGORICAL {
            columns.push(Column::Categorical {
                values: rows.clone().map(|i| self.categorical_value(c, i)).collect(),
                arity: self.arity_at(c),
            });
        }
        let labels = rows.map(|i| self.label(i)).collect();
        Dataset::new(schema, columns, labels)
    }

    /// Materialize rows `[0, rows)`.
    pub fn generate(&self) -> Dataset {
        self.generate_rows(0, self.rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xor_labels_match_parity() {
        let spec = SyntheticSpec::new(Family::Xor { informative: 3 }, 200, 6, 7);
        let ds = spec.generate();
        for i in 0..200 {
            let parity = (0..3)
                .map(|j| ds.row(i).numerical(j) as u32)
                .fold(0, |a, b| a ^ b);
            assert_eq!(ds.row(i).label(), parity);
        }
    }

    #[test]
    fn majority_balance() {
        let spec = SyntheticSpec::new(Family::Majority { informative: 5 }, 20_000, 10, 3);
        let ds = spec.generate();
        let pos = ds.class_counts()[1] as f64 / 20_000.0;
        assert!((pos - 0.5).abs() < 0.02, "majority positive rate {pos}");
    }

    #[test]
    fn needle_is_rare() {
        let spec = SyntheticSpec::new(Family::Needle { informative: 4 }, 50_000, 8, 3);
        let ds = spec.generate();
        let pos = ds.class_counts()[1] as f64 / 50_000.0;
        assert!((pos - 0.0625).abs() < 0.01, "needle positive rate {pos}");
    }

    #[test]
    fn linear_cont_features_continuous() {
        let spec = SyntheticSpec::new(Family::LinearCont { informative: 4 }, 1000, 8, 3);
        let ds = spec.generate();
        let col = ds.column(0).as_numerical();
        let distinct: std::collections::HashSet<u32> =
            col.iter().map(|v| v.to_bits()).collect();
        assert!(distinct.len() > 900, "should be continuous");
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 4, 9);
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.labels(), b.labels());
        assert_eq!(a.column(3).as_numerical(), b.column(3).as_numerical());
    }

    #[test]
    fn different_seeds_differ() {
        let a = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 4, 9).generate();
        let b = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 4, 10).generate();
        assert_ne!(a.labels(), b.labels());
    }

    #[test]
    fn label_noise_flips_some() {
        let clean = SyntheticSpec::new(Family::Majority { informative: 3 }, 5000, 6, 1);
        let noisy = clean.with_label_noise(0.2);
        let a = clean.generate();
        let b = noisy.generate();
        let flips = a
            .labels()
            .iter()
            .zip(b.labels())
            .filter(|(x, y)| x != y)
            .count() as f64
            / 5000.0;
        assert!((flips - 0.2).abs() < 0.03, "flip rate {flips}");
    }

    #[test]
    fn leo_like_schema_shape() {
        let spec = LeoLikeSpec::new(4_000_000, 1);
        let schema = spec.schema();
        assert_eq!(schema.num_features(), 72);
        assert_eq!(schema.numerical_indices().len(), 3);
        assert_eq!(schema.categorical_indices().len(), 69);
        assert_eq!(LeoLikeSpec::paper_arity(0), 2);
        assert_eq!(LeoLikeSpec::paper_arity(68), 10_000);
        // Arities are monotonically non-decreasing and capped by scale.
        for c in 1..69 {
            assert!(LeoLikeSpec::paper_arity(c) >= LeoLikeSpec::paper_arity(c - 1));
            assert!(spec.arity_at(c) <= 4_000_000 / 256);
        }
        // At paper-ish scale the cap is inactive for most features.
        assert_eq!(spec.arity_at(68), 10_000);
        // At small scale the cap bites.
        let small = LeoLikeSpec::new(10_000, 1);
        assert_eq!(small.arity_at(68), 39);
    }

    #[test]
    fn leo_like_is_unbalanced() {
        let ds = LeoLikeSpec::new(20_000, 4).generate();
        let pos = ds.class_counts()[1] as f64 / 20_000.0;
        assert!(
            (0.01..0.15).contains(&pos),
            "leo positive rate {pos} should be unbalanced-low"
        );
    }

    #[test]
    fn leo_like_values_within_arity() {
        let ds = LeoLikeSpec::new(2_000, 4).generate();
        let spec = LeoLikeSpec::new(2_000, 4);
        for (k, &j) in ds.schema().categorical_indices().iter().enumerate() {
            let arity = spec.arity_at(k);
            assert!(ds.column(j).as_categorical().iter().all(|&v| v < arity));
        }
    }

    #[test]
    fn leo_like_signal_exists() {
        // The informative features must shift the score: check positives
        // have a higher average score than negatives.
        let spec = LeoLikeSpec::new(5_000, 4);
        let (mut s_pos, mut n_pos, mut s_neg, mut n_neg) = (0.0, 0, 0.0, 0);
        for i in 0..5_000 {
            if spec.label(i) == 1 {
                s_pos += spec.score(i);
                n_pos += 1;
            } else {
                s_neg += spec.score(i);
                n_neg += 1;
            }
        }
        assert!(n_pos > 10);
        assert!(s_pos / n_pos as f64 > s_neg / n_neg as f64 + 0.5);
    }
}
