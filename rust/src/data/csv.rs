//! CSV ingestion: parse delimited text into a columnar [`Dataset`] with
//! schema inference and dictionary encoding — the adoption path for
//! real data (built in-tree; this project builds fully offline).
//!
//! Rules:
//! * first row is the header; one column must be the label (by name,
//!   default `"label"`);
//! * a feature column is **numerical** if every non-empty value parses
//!   as a float, otherwise **categorical** (values dictionary-encoded
//!   in first-appearance order; arity = number of distinct values);
//! * labels may be integers `0..k` or arbitrary strings (dictionary-
//!   encoded the same way);
//! * empty numerical cells become `NaN` (sorted last by presorting and
//!   therefore never chosen as thresholds); empty categorical cells are
//!   their own category.
//!
//! Quoted fields (RFC-4180 style, `""` escaping) are supported.

use super::column::Column;
use super::dataset::Dataset;
use super::schema::{ColumnSpec, Schema};
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::collections::HashMap;
use std::path::Path;

/// CSV parsing options.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    /// Field delimiter (default `,`).
    pub delimiter: char,
    /// Name of the label column.
    pub label_column: String,
}

impl Default for CsvOptions {
    fn default() -> Self {
        Self {
            delimiter: ',',
            label_column: "label".to_string(),
        }
    }
}

/// Split one CSV record into fields (handles quotes).
fn split_record(line: &str, delim: char) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' && cur.is_empty() {
            in_quotes = true;
        } else if c == delim {
            fields.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    fields.push(cur);
    fields
}

/// Parse CSV text into a dataset.
pub fn parse_csv(text: &str, opts: &CsvOptions) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().context("empty CSV")?;
    let names = split_record(header, opts.delimiter);
    ensure!(names.len() >= 2, "need at least one feature and the label");
    let label_idx = names
        .iter()
        .position(|n| n.trim() == opts.label_column)
        .with_context(|| format!("no '{}' column in header {names:?}", opts.label_column))?;

    // Collect raw cells per column.
    let mut raw: Vec<Vec<String>> = vec![Vec::new(); names.len()];
    for (lineno, line) in lines.enumerate() {
        let fields = split_record(line, opts.delimiter);
        ensure!(
            fields.len() == names.len(),
            "row {} has {} fields, header has {}",
            lineno + 2,
            fields.len(),
            names.len()
        );
        for (c, f) in fields.into_iter().enumerate() {
            raw[c].push(f.trim().to_string());
        }
    }
    let n = raw[0].len();
    ensure!(n > 0, "CSV has a header but no rows");

    // Labels: integers if all parse, else dictionary order.
    let label_raw = &raw[label_idx];
    let all_int = label_raw.iter().all(|v| v.parse::<u32>().is_ok());
    let (labels, num_classes) = if all_int {
        let vals: Vec<u32> = label_raw.iter().map(|v| v.parse().unwrap()).collect();
        let max = *vals.iter().max().unwrap();
        (vals, max + 1)
    } else {
        let mut dict: HashMap<&str, u32> = HashMap::new();
        let mut vals = Vec::with_capacity(n);
        for v in label_raw {
            let next = dict.len() as u32;
            let id = *dict.entry(v.as_str()).or_insert(next);
            vals.push(id);
        }
        (vals, dict.len() as u32)
    };
    ensure!(num_classes >= 2, "label column has a single class");

    // Features: numerical if fully parseable, else categorical.
    let mut specs = Vec::new();
    let mut columns = Vec::new();
    for (c, name) in names.iter().enumerate() {
        if c == label_idx {
            continue;
        }
        let cells = &raw[c];
        let numeric = cells
            .iter()
            .all(|v| v.is_empty() || v.parse::<f32>().is_ok());
        if numeric {
            specs.push(ColumnSpec::numerical(name.trim()));
            columns.push(Column::Numerical(
                cells
                    .iter()
                    .map(|v| {
                        if v.is_empty() {
                            f32::NAN
                        } else {
                            v.parse().unwrap()
                        }
                    })
                    .collect(),
            ));
        } else {
            let mut dict: HashMap<&str, u32> = HashMap::new();
            let values: Vec<u32> = cells
                .iter()
                .map(|v| {
                    let next = dict.len() as u32;
                    *dict.entry(v.as_str()).or_insert(next)
                })
                .collect();
            specs.push(ColumnSpec::categorical(name.trim(), dict.len() as u32));
            columns.push(Column::Categorical {
                values,
                arity: dict.len() as u32,
            });
        }
    }
    if specs.is_empty() {
        bail!("CSV contains only the label column");
    }
    Ok(Dataset::new(Schema::new(specs, num_classes), columns, labels))
}

/// Load a CSV file.
pub fn load_csv(path: &Path, opts: &CsvOptions) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text, opts)
}

/// Write a dataset back to CSV (round-trip/testing aid; categorical
/// values are written as their ids).
pub fn to_csv(ds: &Dataset, opts: &CsvOptions) -> String {
    let mut out = String::new();
    let names: Vec<String> = ds
        .schema()
        .columns
        .iter()
        .map(|c| c.name.clone())
        .chain([opts.label_column.clone()])
        .collect();
    out.push_str(&names.join(&opts.delimiter.to_string()));
    out.push('\n');
    for i in 0..ds.num_rows() {
        let mut fields: Vec<String> = Vec::with_capacity(names.len());
        for (j, spec) in ds.schema().columns.iter().enumerate() {
            match spec.ctype {
                super::schema::ColumnType::Numerical => {
                    fields.push(format!("{}", ds.row(i).numerical(j)))
                }
                super::schema::ColumnType::Categorical { .. } => {
                    fields.push(format!("c{}", ds.row(i).categorical(j)))
                }
            }
        }
        fields.push(ds.labels()[i].to_string());
        out.push_str(&fields.join(&opts.delimiter.to_string()));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_schema() {
        let csv = "age,city,income,label\n\
                   31,zurich,50.5,0\n\
                   45,geneva,61.0,1\n\
                   29,zurich,,0\n\
                   52,\"basel, bs\",70.25,1\n";
        let ds = parse_csv(csv, &CsvOptions::default()).unwrap();
        assert_eq!(ds.num_rows(), 4);
        assert_eq!(ds.num_features(), 3);
        assert_eq!(ds.num_classes(), 2);
        let schema = ds.schema();
        assert!(schema.columns[0].ctype.is_numerical()); // age
        assert!(schema.columns[1].ctype.is_categorical()); // city
        assert_eq!(schema.columns[1].ctype.arity(), Some(3));
        assert!(schema.columns[2].ctype.is_numerical()); // income
        // Dictionary order: zurich=0, geneva=1, "basel, bs"=2.
        assert_eq!(ds.column(1).as_categorical(), &[0, 1, 0, 2]);
        // Empty numerical -> NaN.
        assert!(ds.column(2).as_numerical()[2].is_nan());
        assert_eq!(ds.labels(), &[0, 1, 0, 1]);
    }

    #[test]
    fn string_labels_encoded() {
        let csv = "x,label\n1,spam\n2,ham\n3,spam\n";
        let ds = parse_csv(csv, &CsvOptions::default()).unwrap();
        assert_eq!(ds.labels(), &[0, 1, 0]);
        assert_eq!(ds.num_classes(), 2);
    }

    #[test]
    fn custom_delimiter_and_label_column() {
        let csv = "y;f1\n0;1.5\n1;2.5\n";
        let opts = CsvOptions {
            delimiter: ';',
            label_column: "y".into(),
        };
        let ds = parse_csv(csv, &opts).unwrap();
        assert_eq!(ds.num_features(), 1);
        assert_eq!(ds.column(0).as_numerical(), &[1.5, 2.5]);
    }

    #[test]
    fn errors_are_clear() {
        assert!(parse_csv("", &CsvOptions::default()).is_err());
        assert!(parse_csv("a,b\n1,2\n", &CsvOptions::default()).is_err(), "no label col");
        assert!(
            parse_csv("a,label\n1\n", &CsvOptions::default()).is_err(),
            "ragged row"
        );
        assert!(
            parse_csv("a,label\n1,0\n2,0\n", &CsvOptions::default()).is_err(),
            "single class"
        );
    }

    #[test]
    fn quoted_fields_roundtrip() {
        let fields = split_record("a,\"b,c\",\"d\"\"e\",f", ',');
        assert_eq!(fields, vec!["a", "b,c", "d\"e", "f"]);
    }

    #[test]
    fn trains_on_csv_data() {
        // End-to-end: CSV -> dataset -> forest.
        let mut csv = String::from("f0,f1,cat,label\n");
        for i in 0..400 {
            let x = (i % 20) as f32 / 20.0;
            let y = ((i / 20) % 20) as f32 / 20.0;
            let c = ["a", "b", "c"][i % 3];
            let label = ((x > 0.5) ^ (y > 0.5)) as u32;
            csv.push_str(&format!("{x},{y},{c},{label}\n"));
        }
        let ds = parse_csv(&csv, &CsvOptions::default()).unwrap();
        let params = crate::config::ForestParams {
            num_trees: 5,
            max_depth: 6,
            seed: 3,
            ..Default::default()
        };
        let forest = crate::forest::RandomForest::train(&ds, &params).unwrap();
        let auc = crate::metrics::auc(&forest.predict_scores(&ds), ds.labels());
        assert!(auc > 0.95, "CSV-trained forest should fit XOR, AUC {auc}");
    }
}
