//! On-disk dataset store: persist a columnar dataset as a directory of
//! binary column files plus a JSON schema — the "dataset preparation"
//! output of paper §2.1 (prepare and presort once, train many forests).
//!
//! Layout:
//! ```text
//! <dir>/schema.json          column specs + num_classes + row count
//! <dir>/labels.drfc          u32 label column
//! <dir>/col_<j>.drfc         raw column (f32 or u32)
//! <dir>/col_<j>.sorted.drfc  presorted entries (numerical columns)
//! ```
//! Splitters can consume these files directly in `Disk` storage mode;
//! `load_dataset` materializes the whole thing for in-memory work.

use super::column::Column;
use super::dataset::Dataset;
use super::disk::{self, ColumnReader};
use super::io_stats::IoStats;
use super::schema::{ColumnSpec, ColumnType, Schema};
use crate::util::Json;
use crate::Result;
use anyhow::{ensure, Context};
use std::path::Path;

fn schema_to_json(schema: &Schema, rows: usize) -> Json {
    let mut o = Json::object();
    o.set("rows", Json::from_usize(rows))
        .set("num_classes", Json::from_u64(schema.num_classes as u64))
        .set(
            "columns",
            Json::Arr(
                schema
                    .columns
                    .iter()
                    .map(|c| {
                        let mut cj = Json::object();
                        cj.set("name", Json::Str(c.name.clone()));
                        match c.ctype {
                            ColumnType::Numerical => {
                                cj.set("type", Json::Str("numerical".into()));
                            }
                            ColumnType::Categorical { arity } => {
                                cj.set("type", Json::Str("categorical".into()))
                                    .set("arity", Json::from_u64(arity as u64));
                            }
                        }
                        cj
                    })
                    .collect(),
            ),
        );
    o
}

fn schema_from_json(v: &Json) -> Result<(Schema, usize)> {
    let rows = v.get("rows")?.as_usize()?;
    let num_classes = v.get("num_classes")?.as_u32()?;
    let columns = v
        .get("columns")?
        .as_arr()?
        .iter()
        .map(|cj| {
            let name = cj.get("name")?.as_str()?.to_string();
            Ok(match cj.get("type")?.as_str()? {
                "numerical" => ColumnSpec::numerical(name),
                "categorical" => ColumnSpec::categorical(name, cj.get("arity")?.as_u32()?),
                t => anyhow::bail!("unknown column type '{t}'"),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((Schema::new(columns, num_classes), rows))
}

/// Persist a dataset (including presorted numerical columns).
pub fn save_dataset(ds: &Dataset, dir: &Path, stats: IoStats) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(
        dir.join("schema.json"),
        schema_to_json(ds.schema(), ds.num_rows()).to_string(),
    )?;
    disk::write_categorical_raw(&dir.join("labels.drfc"), ds.labels(), stats.clone())?;
    for (j, col) in ds.columns().iter().enumerate() {
        let raw = dir.join(format!("col_{j}.drfc"));
        match col {
            Column::Numerical(vals) => {
                disk::write_numerical(&raw, vals, stats.clone())?;
                disk::write_sorted(
                    &dir.join(format!("col_{j}.sorted.drfc")),
                    &col.presort(),
                    stats.clone(),
                )?;
            }
            Column::Categorical { values, .. } => {
                disk::write_categorical(&raw, values, stats.clone())?;
            }
        }
    }
    Ok(())
}

/// Load a dataset saved by [`save_dataset`].
pub fn load_dataset(dir: &Path, stats: IoStats) -> Result<Dataset> {
    let text = std::fs::read_to_string(dir.join("schema.json"))
        .with_context(|| format!("reading {}/schema.json", dir.display()))?;
    let (schema, rows) = schema_from_json(&Json::parse(&text)?)?;
    let labels =
        ColumnReader::open(&dir.join("labels.drfc"), stats.clone())?.read_all_u32()?;
    ensure!(labels.len() == rows, "label count mismatch");
    let mut columns = Vec::with_capacity(schema.num_features());
    for (j, spec) in schema.columns.iter().enumerate() {
        let raw = dir.join(format!("col_{j}.drfc"));
        let r = ColumnReader::open(&raw, stats.clone())?;
        let col = match spec.ctype {
            ColumnType::Numerical => Column::Numerical(r.read_all_f32()?),
            ColumnType::Categorical { arity } => Column::Categorical {
                values: r.read_all_u32()?,
                arity,
            },
        };
        ensure!(col.len() == rows, "column {j} row-count mismatch");
        columns.push(col);
    }
    Ok(Dataset::new(schema, columns, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::LeoLikeSpec;

    #[test]
    fn roundtrip_mixed_dataset() {
        let ds = LeoLikeSpec::new(500, 3).generate();
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        save_dataset(&ds, dir.path(), stats.clone()).unwrap();
        let back = load_dataset(dir.path(), stats).unwrap();
        assert_eq!(ds.schema(), back.schema());
        assert_eq!(ds.labels(), back.labels());
        for j in 0..ds.num_features() {
            assert_eq!(ds.column(j), back.column(j), "column {j}");
        }
        // Presorted files exist for numerical columns.
        assert!(dir.path().join("col_0.sorted.drfc").exists());
        assert!(!dir.path().join("col_3.sorted.drfc").exists());
    }

    #[test]
    fn missing_dir_fails_cleanly() {
        let err = load_dataset(Path::new("/nonexistent/nope"), IoStats::new());
        assert!(err.is_err());
    }

    #[test]
    fn corrupt_schema_fails() {
        let dir = crate::util::tempdir().unwrap();
        std::fs::write(dir.path().join("schema.json"), "{\"rows\": 1}").unwrap();
        assert!(load_dataset(dir.path(), IoStats::new()).is_err());
    }
}
