//! The data plane: the [`ColumnStore`] abstraction every splitter scan
//! runs on, its backends, and on-disk dataset persistence.
//!
//! DRF's contract with its storage is narrow (paper §2): a worker reads
//! its assigned columns **sequentially**, never writes after the
//! presorting phase, and never does random access. [`ColumnStore`]
//! captures exactly that contract as **chunk-granular sequential
//! scans** — a visitor is fed bounded slices of the column, so a pass
//! over an arbitrarily large column runs in constant memory and any
//! backend that can produce ordered chunks can plug in:
//!
//! * [`MemStore`] — columns (and presorted views) held in RAM; scans
//!   visit borrowed slices, zero copies, no I/O charged;
//! * [`DiskStore`] — one DRFC v1 file per column, re-read sequentially
//!   through a bounded chunk buffer every pass; every byte charged to
//!   the worker's [`IoStats`] exactly as the Table 1 benches expect;
//! * [`DiskV2Store`] — DRFC v2 files whose header carries the per-chunk
//!   record counts ([`disk::Layout::V2`]), so a pass can be resumed or
//!   stopped at any chunk boundary without reading the tail;
//! * [`crate::data::mmap::MmapStore`] — DRFC files memory-mapped once,
//!   scans borrow chunk slices straight from the mapping (zero
//!   syscalls, zero copies after the first-touch pass);
//! * [`crate::data::remote::RemoteStore`] — DRFC files on a
//!   `drf objstore`, scanned by chunk-aligned byte-range reads over
//!   the wire (checksummed complete passes, bounded-backoff retry,
//!   chunk-boundary resume).
//!
//! The streaming backends (disk reads and remote range reads)
//! optionally run each scan as a **double-buffered prefetch pipeline**
//! ([`DiskStore::with_prefetch`]): a background reader decodes (or
//! fetches) chunk `N+1` while the visitor consumes chunk `N`, bounded
//! by `TrainConfig::prefetch_chunks`. Delivery order is unchanged, so
//! prefetching is invisible to results, and completed passes charge
//! exactly what synchronous passes charge.
//!
//! Because the scan algorithms (Alg. 1 supersplit search, condition
//! evaluation, SPRINT pruning) are pure left-to-right folds, chunk
//! boundaries cannot change any result: all backends produce
//! bit-identical trees (asserted by `tests/storage_backends.rs`).
//!
//! [`run_scans`] is the intra-splitter parallelism substrate: a scoped
//! worker pool that runs per-column scan jobs concurrently (bounded by
//! `TrainConfig::scan_threads`) and returns results in deterministic
//! job order.
//!
//! The module also persists whole datasets as a directory of column
//! files plus a JSON schema — the "dataset preparation" output of paper
//! §2.1 (prepare and presort once, train many forests):
//! ```text
//! <dir>/schema.json          column specs + num_classes + row count
//! <dir>/labels.drfc          u32 label column
//! <dir>/col_<j>.drfc         raw column (f32 or u32)
//! <dir>/col_<j>.sorted.drfc  presorted entries (numerical columns)
//! ```

use super::column::{Column, SortedEntry};
use super::dataset::Dataset;
use super::disk::{self, ColumnReader, Layout};
use super::io_stats::IoStats;
use super::schema::{ColumnSpec, ColumnType, Schema};
use crate::util::Json;
use crate::Result;
use anyhow::{ensure, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

// ---------------------------------------------------------------------
// ColumnStore: the chunked scan abstraction
// ---------------------------------------------------------------------

/// One borrowed chunk of a raw (row-order) column.
#[derive(Debug, Clone, Copy)]
pub enum RawChunk<'a> {
    /// Chunk of a numerical column.
    Numerical(&'a [f32]),
    /// Chunk of a categorical column.
    Categorical(&'a [u32]),
}

impl<'a> RawChunk<'a> {
    /// Records in the chunk.
    pub fn len(&self) -> usize {
        match self {
            RawChunk::Numerical(v) => v.len(),
            RawChunk::Categorical(v) => v.len(),
        }
    }

    /// Whether the chunk holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Sequential, chunk-granular access to a set of columns — the only
/// storage interface the splitter knows. Implementations must feed
/// chunks strictly in order and cover every record exactly once per
/// scan; chunk sizes are an implementation detail (the fold-style scan
/// algorithms are invariant to them).
///
/// # Examples
///
/// A scan is a left-to-right fold over ordered chunks; the visitor
/// sees every row exactly once, whatever the backend:
///
/// ```
/// use drf::data::synthetic::{Family, SyntheticSpec};
/// use drf::data::{ColumnStore, MemStore, RawChunk};
///
/// let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 4, 7).generate();
/// let store = MemStore::build(&ds, &[0, 2]); // this splitter owns columns 0 and 2
///
/// let mut rows_seen = 0;
/// store.scan_raw(0, &mut |base_row, chunk: RawChunk<'_>| {
///     assert_eq!(base_row, rows_seen); // chunks arrive strictly in row order
///     rows_seen += chunk.len();
///     Ok(())
/// })?;
/// assert_eq!(rows_seen, ds.num_rows());
///
/// // Presorted scans feed Alg. 1's q(j): values ascending.
/// let mut last = f32::NEG_INFINITY;
/// store.scan_sorted(0, &mut |entries| {
///     for e in entries {
///         assert!(e.value >= last);
///         last = e.value;
///     }
///     Ok(())
/// })?;
/// # Ok::<(), anyhow::Error>(())
/// ```
pub trait ColumnStore: Send + Sync {
    /// Column indices this store holds, ascending.
    fn columns(&self) -> Vec<usize>;

    /// Type of column `j` (errors if the store lacks it).
    fn column_type(&self, j: usize) -> Result<ColumnType>;

    /// One sequential pass over the raw column in row order. The
    /// visitor receives `(base_row, chunk)`; `base_row` is the row
    /// index of the chunk's first record.
    fn scan_raw(
        &self,
        j: usize,
        visit: &mut dyn FnMut(usize, RawChunk<'_>) -> Result<()>,
    ) -> Result<()>;

    /// One sequential pass over the presorted entries (Alg. 1's `q(j)`)
    /// of numerical column `j`, in value order.
    fn scan_sorted(
        &self,
        j: usize,
        visit: &mut dyn FnMut(&[SortedEntry]) -> Result<()>,
    ) -> Result<()>;

    /// Materialize the whole raw column (one pass). Only for consumers
    /// that genuinely need the full column at once (e.g. the XLA
    /// scorer's batched task builder).
    fn read_raw(&self, j: usize) -> Result<Column> {
        match self.column_type(j)? {
            ColumnType::Numerical => {
                let mut vals = Vec::new();
                self.scan_raw(j, &mut |_base, chunk| {
                    match chunk {
                        RawChunk::Numerical(v) => vals.extend_from_slice(v),
                        RawChunk::Categorical(_) => anyhow::bail!("chunk/type mismatch"),
                    }
                    Ok(())
                })?;
                Ok(Column::Numerical(vals))
            }
            ColumnType::Categorical { arity } => {
                let mut vals = Vec::new();
                self.scan_raw(j, &mut |_base, chunk| {
                    match chunk {
                        RawChunk::Categorical(v) => vals.extend_from_slice(v),
                        RawChunk::Numerical(_) => anyhow::bail!("chunk/type mismatch"),
                    }
                    Ok(())
                })?;
                Ok(Column::Categorical {
                    values: vals,
                    arity,
                })
            }
        }
    }

    /// Materialize the whole presorted view (one pass).
    fn read_sorted(&self, j: usize) -> Result<Vec<SortedEntry>> {
        let mut out = Vec::new();
        self.scan_sorted(j, &mut |chunk| {
            out.extend_from_slice(chunk);
            Ok(())
        })?;
        Ok(out)
    }

    /// Zero-copy borrow of the whole presorted view, for backends that
    /// hold it resident ([`MemStore`]). `None` means the caller must
    /// stream ([`Self::scan_sorted`]) or materialize
    /// ([`Self::read_sorted`]) instead — never an error.
    fn borrow_sorted(&self, _j: usize) -> Option<&[SortedEntry]> {
        None
    }
}

/// Run `jobs` independent scan jobs on up to `threads` scoped worker
/// threads and return their results **in job order** (deterministic
/// regardless of scheduling). `threads <= 1` runs inline. Errors are
/// propagated; the first job's error (in job order) wins.
pub fn run_scans<T: Send>(
    threads: usize,
    jobs: usize,
    run: impl Fn(usize) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    if threads <= 1 || jobs <= 1 {
        return (0..jobs).map(&run).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<Result<T>>>> =
        (0..jobs).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(jobs) {
            scope.spawn(|| loop {
                let k = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if k >= jobs {
                    break;
                }
                *slots[k].lock().unwrap() = Some(run(k));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("scan job not completed"))
        .collect()
}

// ---------------------------------------------------------------------
// MemStore
// ---------------------------------------------------------------------

/// Columns held in RAM (paper: "workers can be configured to load the
/// dataset in memory"). Scans visit borrowed whole-column slices —
/// zero copies, nothing charged to I/O stats.
pub struct MemStore {
    /// column index → raw column (row order).
    columns: BTreeMap<usize, Column>,
    /// column index → presorted entries (numerical columns only).
    sorted: BTreeMap<usize, Vec<SortedEntry>>,
}

impl MemStore {
    /// Build from a full dataset and a column assignment, presorting
    /// numerical columns on the way (the dataset-preparation phase of
    /// §2.1).
    pub fn build(ds: &Dataset, columns: &[usize]) -> MemStore {
        let mut cols = BTreeMap::new();
        let mut sorted = BTreeMap::new();
        for &j in columns {
            let col = ds.column(j).clone();
            if col.is_numerical() {
                sorted.insert(j, col.presort());
            }
            cols.insert(j, col);
        }
        MemStore::from_parts(cols, sorted)
    }

    /// Assemble from already-materialized columns and presorted views
    /// (e.g. a cluster worker preloading its shard pack into RAM —
    /// the presorted files were written at shard time, so nothing is
    /// re-sorted here).
    pub fn from_parts(
        columns: BTreeMap<usize, Column>,
        sorted: BTreeMap<usize, Vec<SortedEntry>>,
    ) -> MemStore {
        MemStore { columns, sorted }
    }

    fn column(&self, j: usize) -> Result<&Column> {
        self.columns
            .get(&j)
            .ok_or_else(|| anyhow::anyhow!("store lacks column {j}"))
    }
}

impl ColumnStore for MemStore {
    fn columns(&self) -> Vec<usize> {
        self.columns.keys().copied().collect()
    }

    fn column_type(&self, j: usize) -> Result<ColumnType> {
        Ok(match self.column(j)? {
            Column::Numerical(_) => ColumnType::Numerical,
            Column::Categorical { arity, .. } => ColumnType::Categorical { arity: *arity },
        })
    }

    fn scan_raw(
        &self,
        j: usize,
        visit: &mut dyn FnMut(usize, RawChunk<'_>) -> Result<()>,
    ) -> Result<()> {
        match self.column(j)? {
            Column::Numerical(v) => visit(0, RawChunk::Numerical(v.as_slice())),
            Column::Categorical { values, .. } => {
                visit(0, RawChunk::Categorical(values.as_slice()))
            }
        }
    }

    fn scan_sorted(
        &self,
        j: usize,
        visit: &mut dyn FnMut(&[SortedEntry]) -> Result<()>,
    ) -> Result<()> {
        let entries = self
            .sorted
            .get(&j)
            .ok_or_else(|| anyhow::anyhow!("no presorted data for column {j}"))?;
        visit(entries.as_slice())
    }

    fn borrow_sorted(&self, j: usize) -> Option<&[SortedEntry]> {
        self.sorted.get(&j).map(|v| v.as_slice())
    }
}

// ---------------------------------------------------------------------
// DiskStore (DRFC v1) and DiskV2Store (DRFC v2)
// ---------------------------------------------------------------------

/// Paths of one on-disk column.
#[derive(Debug, Clone)]
pub struct ColumnFiles {
    /// The raw (row-order) column file.
    pub raw: PathBuf,
    /// The presorted file (numerical columns only).
    pub sorted: Option<PathBuf>,
    /// Declared column type (validated against the file headers).
    pub ctype: ColumnType,
}

/// Columns on disk; every scan is a fresh sequential pass through a
/// bounded chunk buffer, charged to the worker's [`IoStats`]. Reads
/// both DRFC versions; [`DiskStore::build`] writes v1 files.
///
/// With [`DiskStore::with_prefetch`] a scan becomes a two-stage
/// pipeline: a background reader thread decodes chunk `N+1` (up to
/// `prefetch_chunks` ahead, bounded channel) while the scan visitor
/// consumes chunk `N`. Chunks are still delivered strictly in order, so
/// the pipeline is deterministic by construction — it can change wall
/// clock, never a tree, and on every completed pass the `IoStats`
/// totals are byte-identical to the synchronous loop. (Only if a
/// visitor *errors mid-scan* can the reader have charged up to
/// `prefetch_chunks` of read-ahead the synchronous path would not have
/// reached — the pass is aborted either way.)
pub struct DiskStore {
    files: BTreeMap<usize, ColumnFiles>,
    stats: IoStats,
    /// Chunks the background reader may run ahead of the visitor
    /// (0 = synchronous single-threaded scans, the default).
    prefetch_chunks: usize,
}

/// Drive one prefetching pass: the spawned reader pulls chunks of `T`
/// off `reader` in plan order and ships them through a bounded channel;
/// the caller's `consume` runs on the current thread. Spent buffers are
/// recycled through a return channel, so steady state allocates
/// `prefetch + 1` chunk buffers total. Reader-side I/O errors surface
/// to the caller; a consumer error tears the pipeline down (the reader
/// notices the closed channel and stops mid-file, exactly like a `?`
/// in the synchronous loop).
fn prefetched_scan<T: Send>(
    reader: ColumnReader,
    prefetch: usize,
    read: impl FnMut(&mut ColumnReader, &mut Vec<T>, usize) -> Result<usize> + Send,
    mut consume: impl FnMut(usize, &[T]) -> Result<()>,
) -> Result<()> {
    let plan = reader.chunk_plan();
    std::thread::scope(|scope| {
        let (tx, rx) = std::sync::mpsc::sync_channel::<Result<(usize, Vec<T>)>>(prefetch.max(1));
        let (recycle_tx, recycle_rx) = std::sync::mpsc::channel::<Vec<T>>();
        scope.spawn(move || {
            let (mut reader, mut read) = (reader, read);
            let mut base = 0usize;
            for want in plan {
                let mut buf = recycle_rx.try_recv().unwrap_or_default();
                match read(&mut reader, &mut buf, want) {
                    Ok(n) => {
                        if tx.send(Ok((base, buf))).is_err() {
                            return; // consumer bailed; stop reading
                        }
                        base += n;
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        return;
                    }
                }
            }
            // The whole column went through: one completed read pass,
            // charged from the thread that did the reading.
            reader.end_pass();
        });
        for msg in rx {
            let (base, buf) = msg?;
            consume(base, &buf)?;
            let _ = recycle_tx.send(buf);
        }
        Ok(())
    })
}

impl DiskStore {
    /// Write the columns of `ds` named by `columns` under `dir` in
    /// `layout` and return the store (used by the manager in disk
    /// storage modes and by the disk benches/tests).
    fn build_with(
        ds: &Dataset,
        columns: &[usize],
        dir: &Path,
        layout: Layout,
        stats: IoStats,
    ) -> Result<DiskStore> {
        let mut files = BTreeMap::new();
        for &j in columns {
            let raw = dir.join(format!("col_{j}.drfc"));
            let ctype = ds.schema().columns[j].ctype;
            let mut sorted_path = None;
            match ds.column(j) {
                Column::Numerical(vals) => {
                    disk::write_numerical_with(&raw, vals, layout, stats.clone())?;
                    let sp = dir.join(format!("col_{j}.sorted.drfc"));
                    disk::write_sorted_with(&sp, &ds.column(j).presort(), layout, stats.clone())?;
                    sorted_path = Some(sp);
                }
                Column::Categorical { values, .. } => {
                    disk::write_categorical_with(&raw, values, layout, stats.clone())?;
                }
            }
            files.insert(
                j,
                ColumnFiles {
                    raw,
                    sorted: sorted_path,
                    ctype,
                },
            );
        }
        Ok(DiskStore {
            files,
            stats,
            prefetch_chunks: 0,
        })
    }

    /// Enable the double-buffered prefetch pipeline: scans may run up
    /// to `chunks` chunk reads ahead of the visitor (0 disables).
    pub fn with_prefetch(mut self, chunks: usize) -> Self {
        self.prefetch_chunks = chunks;
        self
    }

    /// Build a v1 (monolithic) disk store.
    pub fn build(
        ds: &Dataset,
        columns: &[usize],
        dir: &Path,
        stats: IoStats,
    ) -> Result<DiskStore> {
        Self::build_with(ds, columns, dir, Layout::V1, stats)
    }

    /// Open a store over column files that already exist on disk (e.g.
    /// a shard pack written by `drf shard`). Each file's header is
    /// validated up front; scans then stream the files sequentially
    /// like any other disk store.
    pub fn open(files: BTreeMap<usize, ColumnFiles>, stats: IoStats) -> Result<DiskStore> {
        for (j, f) in &files {
            let r = ColumnReader::open(&f.raw, stats.clone())?;
            let expected = match f.ctype {
                ColumnType::Numerical => disk::FileKind::Numerical,
                ColumnType::Categorical { .. } => disk::FileKind::Categorical,
            };
            ensure!(
                r.header().kind == expected,
                "column {j}: file {} holds {:?}, manifest says {:?}",
                f.raw.display(),
                r.header().kind,
                f.ctype
            );
            if let Some(sp) = &f.sorted {
                let r = ColumnReader::open(sp, stats.clone())?;
                ensure!(
                    r.header().kind == disk::FileKind::SortedNumerical,
                    "column {j}: {} is not a presorted column file",
                    sp.display()
                );
            }
        }
        Ok(DiskStore {
            files,
            stats,
            prefetch_chunks: 0,
        })
    }

    fn file(&self, j: usize) -> Result<&ColumnFiles> {
        self.files
            .get(&j)
            .ok_or_else(|| anyhow::anyhow!("store lacks column {j}"))
    }
}

impl ColumnStore for DiskStore {
    fn columns(&self) -> Vec<usize> {
        self.files.keys().copied().collect()
    }

    fn column_type(&self, j: usize) -> Result<ColumnType> {
        Ok(self.file(j)?.ctype)
    }

    fn scan_raw(
        &self,
        j: usize,
        visit: &mut dyn FnMut(usize, RawChunk<'_>) -> Result<()>,
    ) -> Result<()> {
        let f = self.file(j)?;
        let mut r = ColumnReader::open(&f.raw, self.stats.clone())?;
        if self.prefetch_chunks > 0 {
            return match f.ctype {
                ColumnType::Numerical => prefetched_scan(
                    r,
                    self.prefetch_chunks,
                    |r, buf, want| r.next_chunk_f32(buf, want),
                    |base, chunk: &[f32]| visit(base, RawChunk::Numerical(chunk)),
                ),
                ColumnType::Categorical { .. } => prefetched_scan(
                    r,
                    self.prefetch_chunks,
                    |r, buf, want| r.next_chunk_u32(buf, want),
                    |base, chunk: &[u32]| visit(base, RawChunk::Categorical(chunk)),
                ),
            };
        }
        let plan = r.chunk_plan();
        let mut base = 0usize;
        match f.ctype {
            ColumnType::Numerical => {
                let mut buf: Vec<f32> = Vec::new();
                for want in plan {
                    let n = r.next_chunk_f32(&mut buf, want)?;
                    visit(base, RawChunk::Numerical(buf.as_slice()))?;
                    base += n;
                }
            }
            ColumnType::Categorical { .. } => {
                let mut buf: Vec<u32> = Vec::new();
                for want in plan {
                    let n = r.next_chunk_u32(&mut buf, want)?;
                    visit(base, RawChunk::Categorical(buf.as_slice()))?;
                    base += n;
                }
            }
        }
        r.end_pass();
        Ok(())
    }

    fn scan_sorted(
        &self,
        j: usize,
        visit: &mut dyn FnMut(&[SortedEntry]) -> Result<()>,
    ) -> Result<()> {
        let f = self.file(j)?;
        let path = f
            .sorted
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("column {j} has no presorted file"))?;
        let mut r = ColumnReader::open(path, self.stats.clone())?;
        if self.prefetch_chunks > 0 {
            return prefetched_scan(
                r,
                self.prefetch_chunks,
                |r, buf, want| r.next_chunk_sorted(buf, want),
                |_base, chunk: &[SortedEntry]| visit(chunk),
            );
        }
        let plan = r.chunk_plan();
        let mut buf: Vec<SortedEntry> = Vec::new();
        for want in plan {
            r.next_chunk_sorted(&mut buf, want)?;
            visit(buf.as_slice())?;
        }
        r.end_pass();
        Ok(())
    }
}

/// Columns in the chunked DRFC v2 layout: per-chunk record counts live
/// in each file's header, so scans follow the file's own chunk table
/// and partial passes never read the tail. Scan semantics (and tree
/// output) are identical to the other backends.
pub struct DiskV2Store {
    inner: DiskStore,
}

impl DiskV2Store {
    /// Write v2 column files (`chunk_rows` records per chunk) under
    /// `dir` and return the store.
    pub fn build(
        ds: &Dataset,
        columns: &[usize],
        dir: &Path,
        chunk_rows: u32,
        stats: IoStats,
    ) -> Result<DiskV2Store> {
        Ok(DiskV2Store {
            inner: DiskStore::build_with(ds, columns, dir, Layout::V2 { chunk_rows }, stats)?,
        })
    }

    /// Enable the prefetch pipeline (see [`DiskStore::with_prefetch`]).
    pub fn with_prefetch(mut self, chunks: usize) -> Self {
        self.inner = self.inner.with_prefetch(chunks);
        self
    }
}

impl ColumnStore for DiskV2Store {
    fn columns(&self) -> Vec<usize> {
        self.inner.columns()
    }

    fn column_type(&self, j: usize) -> Result<ColumnType> {
        self.inner.column_type(j)
    }

    fn scan_raw(
        &self,
        j: usize,
        visit: &mut dyn FnMut(usize, RawChunk<'_>) -> Result<()>,
    ) -> Result<()> {
        self.inner.scan_raw(j, visit)
    }

    fn scan_sorted(
        &self,
        j: usize,
        visit: &mut dyn FnMut(&[SortedEntry]) -> Result<()>,
    ) -> Result<()> {
        self.inner.scan_sorted(j, visit)
    }
}

/// In-memory store for `columns` of `ds` (presorts numerical columns).
pub fn mem_store_for(ds: &Dataset, columns: &[usize]) -> Arc<dyn ColumnStore> {
    Arc::new(MemStore::build(ds, columns))
}

/// v1 disk store for `columns` of `ds`, files written under `dir`,
/// prefetching `prefetch_chunks` ahead (0 = synchronous scans).
pub fn disk_store_for(
    ds: &Dataset,
    columns: &[usize],
    dir: &Path,
    stats: IoStats,
    prefetch_chunks: usize,
) -> Result<Arc<dyn ColumnStore>> {
    Ok(Arc::new(
        DiskStore::build(ds, columns, dir, stats)?.with_prefetch(prefetch_chunks),
    ))
}

/// v2 (chunked) disk store for `columns` of `ds`.
pub fn disk_v2_store_for(
    ds: &Dataset,
    columns: &[usize],
    dir: &Path,
    chunk_rows: u32,
    stats: IoStats,
    prefetch_chunks: usize,
) -> Result<Arc<dyn ColumnStore>> {
    Ok(Arc::new(
        DiskV2Store::build(ds, columns, dir, chunk_rows, stats)?.with_prefetch(prefetch_chunks),
    ))
}

/// Zero-copy mmap store for `columns` of `ds`: chunked v2 files written
/// under `dir`, then memory-mapped ([`crate::data::mmap::MmapStore`]).
pub fn mmap_store_for(
    ds: &Dataset,
    columns: &[usize],
    dir: &Path,
    chunk_rows: u32,
    stats: IoStats,
) -> Result<Arc<dyn ColumnStore>> {
    Ok(Arc::new(crate::data::mmap::MmapStore::build(
        ds, columns, dir, chunk_rows, stats,
    )?))
}

// ---------------------------------------------------------------------
// Dataset directory persistence
// ---------------------------------------------------------------------

/// Serialize a schema (+ row count) to the JSON shape shared by the
/// dataset directory format and the cluster shard manifests.
pub fn schema_to_json(schema: &Schema, rows: usize) -> Json {
    let mut o = Json::object();
    o.set("rows", Json::from_usize(rows))
        .set("num_classes", Json::from_u64(schema.num_classes as u64))
        .set(
            "columns",
            Json::Arr(
                schema
                    .columns
                    .iter()
                    .map(|c| {
                        let mut cj = Json::object();
                        cj.set("name", Json::Str(c.name.clone()));
                        match c.ctype {
                            ColumnType::Numerical => {
                                cj.set("type", Json::Str("numerical".into()));
                            }
                            ColumnType::Categorical { arity } => {
                                cj.set("type", Json::Str("categorical".into()))
                                    .set("arity", Json::from_u64(arity as u64));
                            }
                        }
                        cj
                    })
                    .collect(),
            ),
        );
    o
}

/// Parse a schema serialized by [`schema_to_json`].
///
/// The input is untrusted (shard manifests arrive over the network or
/// from an object store), so every [`Schema::new`] assertion is checked
/// here first and surfaced as a descriptive `Err` instead of a panic.
pub fn schema_from_json(v: &Json) -> Result<(Schema, usize)> {
    use anyhow::ensure;
    let rows = v.get("rows")?.as_usize()?;
    let num_classes = v.get("num_classes")?.as_u32()?;
    ensure!(num_classes >= 2, "schema num_classes {num_classes} < 2");
    let columns = v
        .get("columns")?
        .as_arr()?
        .iter()
        .map(|cj| {
            let name = cj.get("name")?.as_str()?.to_string();
            Ok(match cj.get("type")?.as_str()? {
                "numerical" => ColumnSpec::numerical(name),
                "categorical" => {
                    let arity = cj.get("arity")?.as_u32()?;
                    ensure!(arity >= 1, "categorical column '{name}' has arity 0");
                    ColumnSpec::categorical(name, arity)
                }
                t => anyhow::bail!("unknown column type '{t}'"),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    ensure!(!columns.is_empty(), "schema has no feature columns");
    let mut names: Vec<&str> = columns.iter().map(|c| c.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    ensure!(
        names.len() == columns.len(),
        "schema has duplicate column names"
    );
    Ok((Schema::new(columns, num_classes), rows))
}

/// Persist a dataset (including presorted numerical columns) as DRFC
/// v1 files. See [`save_dataset_with`] to pick the layout.
pub fn save_dataset(ds: &Dataset, dir: &Path, stats: IoStats) -> Result<()> {
    save_dataset_with(ds, dir, Layout::V1, stats)
}

/// Persist a dataset in the chosen DRFC `layout`. The chunk-tabled v2
/// layout (`Layout::V2`) is what remote serving wants: a
/// [`crate::data::remote::RemoteStore`] maps its chunk-aligned range
/// reads — and its resumable passes — directly onto the written chunk
/// table, so `drf generate --chunk-rows N` + `drf objstore --dir` is a
/// servable object store with no extra preparation.
pub fn save_dataset_with(ds: &Dataset, dir: &Path, layout: Layout, stats: IoStats) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(
        dir.join("schema.json"),
        schema_to_json(ds.schema(), ds.num_rows()).to_string(),
    )?;
    disk::write_categorical_with(&dir.join("labels.drfc"), ds.labels(), layout, stats.clone())?;
    for (j, col) in ds.columns().iter().enumerate() {
        let raw = dir.join(format!("col_{j}.drfc"));
        match col {
            Column::Numerical(vals) => {
                disk::write_numerical_with(&raw, vals, layout, stats.clone())?;
                disk::write_sorted_with(
                    &dir.join(format!("col_{j}.sorted.drfc")),
                    &col.presort(),
                    layout,
                    stats.clone(),
                )?;
            }
            Column::Categorical { values, .. } => {
                disk::write_categorical_with(&raw, values, layout, stats.clone())?;
            }
        }
    }
    Ok(())
}

/// Load a dataset saved by [`save_dataset`].
pub fn load_dataset(dir: &Path, stats: IoStats) -> Result<Dataset> {
    let text = std::fs::read_to_string(dir.join("schema.json"))
        .with_context(|| format!("reading {}/schema.json", dir.display()))?;
    let (schema, rows) = schema_from_json(&Json::parse(&text)?)?;
    let labels =
        ColumnReader::open(&dir.join("labels.drfc"), stats.clone())?.read_all_u32()?;
    ensure!(labels.len() == rows, "label count mismatch");
    let mut columns = Vec::with_capacity(schema.num_features());
    for (j, spec) in schema.columns.iter().enumerate() {
        let raw = dir.join(format!("col_{j}.drfc"));
        let r = ColumnReader::open(&raw, stats.clone())?;
        let col = match spec.ctype {
            ColumnType::Numerical => Column::Numerical(r.read_all_f32()?),
            ColumnType::Categorical { arity } => Column::Categorical {
                values: r.read_all_u32()?,
                arity,
            },
        };
        ensure!(col.len() == rows, "column {j} row-count mismatch");
        columns.push(col);
    }
    Ok(Dataset::new(schema, columns, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};

    #[test]
    fn roundtrip_mixed_dataset() {
        let ds = LeoLikeSpec::new(500, 3).generate();
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        save_dataset(&ds, dir.path(), stats.clone()).unwrap();
        let back = load_dataset(dir.path(), stats).unwrap();
        assert_eq!(ds.schema(), back.schema());
        assert_eq!(ds.labels(), back.labels());
        for j in 0..ds.num_features() {
            assert_eq!(ds.column(j), back.column(j), "column {j}");
        }
        // Presorted files exist for numerical columns.
        assert!(dir.path().join("col_0.sorted.drfc").exists());
        assert!(!dir.path().join("col_3.sorted.drfc").exists());
    }

    #[test]
    fn missing_dir_fails_cleanly() {
        let err = load_dataset(Path::new("/nonexistent/nope"), IoStats::new());
        assert!(err.is_err());
    }

    #[test]
    fn corrupt_schema_fails() {
        let dir = crate::util::tempdir().unwrap();
        std::fs::write(dir.path().join("schema.json"), "{\"rows\": 1}").unwrap();
        assert!(load_dataset(dir.path(), IoStats::new()).is_err());
    }

    /// Every backend must deliver identical data through scans, chunk
    /// boundaries notwithstanding.
    #[test]
    fn backends_scan_identical_data() {
        let ds = LeoLikeSpec::new(700, 11).generate();
        let cols: Vec<usize> = vec![0, 1, 3, 5];
        let dir1 = crate::util::tempdir().unwrap();
        let dir2 = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let dir3 = crate::util::tempdir().unwrap();
        let stores: Vec<Arc<dyn ColumnStore>> = vec![
            mem_store_for(&ds, &cols),
            disk_store_for(&ds, &cols, dir1.path(), stats.clone(), 0).unwrap(),
            // Tiny chunks so the v2 scan actually visits many chunks.
            disk_v2_store_for(&ds, &cols, dir2.path(), 97, stats.clone(), 0).unwrap(),
            // Prefetching delivery must be indistinguishable.
            disk_v2_store_for(&ds, &cols, dir3.path(), 97, stats.clone(), 2).unwrap(),
        ];
        for store in &stores {
            assert_eq!(store.columns(), cols);
            for &j in &cols {
                assert_eq!(store.column_type(j).unwrap(), ds.schema().columns[j].ctype);
                // Raw scan reassembles the column.
                assert_eq!(&store.read_raw(j).unwrap(), ds.column(j), "column {j}");
                // Sorted scan reassembles the presorted view.
                if ds.column(j).is_numerical() {
                    assert_eq!(store.read_sorted(j).unwrap(), ds.column(j).presort());
                }
            }
            // Chunks arrive in row order with correct base offsets.
            let mut seen = 0usize;
            store
                .scan_raw(cols[0], &mut |base, chunk| {
                    assert_eq!(base, seen);
                    seen += chunk.len();
                    Ok(())
                })
                .unwrap();
            assert_eq!(seen, ds.num_rows());
            // Missing column errors.
            assert!(store.scan_raw(2, &mut |_, _| Ok(())).is_err());
            assert!(store.read_raw(2).is_err());
        }
    }

    /// Disk scans charge exactly the historical whole-pass byte counts.
    #[test]
    fn disk_scan_accounting_matches_monolithic_pass() {
        let ds = SyntheticSpec::new(Family::LinearCont { informative: 2 }, 300, 3, 5).generate();
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let store = disk_store_for(&ds, &[0], dir.path(), stats.clone(), 0).unwrap();
        let before = stats.snapshot();
        let col = store.read_raw(0).unwrap();
        assert_eq!(col.len(), 300);
        let d = stats.snapshot().delta_since(&before);
        // v1 header (20) + 300 f32 records, one pass.
        assert_eq!(d.disk_read_bytes, 20 + 300 * 4);
        assert_eq!(d.disk_read_passes, 1);
    }

    /// The prefetch pipeline charges the same bytes/passes and delivers
    /// the same chunk sequence as the synchronous loop, and tears down
    /// cleanly when the visitor errors mid-scan.
    #[test]
    fn prefetch_is_invisible_to_results_and_accounting() {
        let ds = SyntheticSpec::new(Family::LinearCont { informative: 2 }, 500, 3, 8).generate();
        let dir_a = crate::util::tempdir().unwrap();
        let dir_b = crate::util::tempdir().unwrap();
        let (sa, sb) = (IoStats::new(), IoStats::new());
        let sync = disk_v2_store_for(&ds, &[0, 1], dir_a.path(), 64, sa.clone(), 0).unwrap();
        let pre = disk_v2_store_for(&ds, &[0, 1], dir_b.path(), 64, sb.clone(), 3).unwrap();
        sa.reset();
        sb.reset();
        let collect = |s: &Arc<dyn ColumnStore>| {
            let mut chunks: Vec<(usize, Vec<f32>)> = Vec::new();
            s.scan_raw(0, &mut |base, c| {
                match c {
                    RawChunk::Numerical(v) => chunks.push((base, v.to_vec())),
                    _ => unreachable!(),
                }
                Ok(())
            })
            .unwrap();
            let mut sorted: Vec<SortedEntry> = Vec::new();
            s.scan_sorted(1, &mut |c| {
                sorted.extend_from_slice(c);
                Ok(())
            })
            .unwrap();
            (chunks, sorted)
        };
        assert_eq!(collect(&sync), collect(&pre), "chunk sequences must match");
        assert_eq!(sa.snapshot(), sb.snapshot(), "accounting must match");
        // Visitor error: propagates, pipeline shuts down without hanging.
        let err = pre.scan_raw(0, &mut |base, _| {
            if base > 0 {
                anyhow::bail!("stop at {base}")
            }
            Ok(())
        });
        assert!(err.is_err());
        // The store is still usable afterwards.
        assert_eq!(pre.read_raw(0).unwrap(), *ds.column(0));
    }

    #[test]
    fn mem_store_charges_nothing() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 3, 9).generate();
        let store = mem_store_for(&ds, &[0, 1, 2]);
        store.read_raw(1).unwrap();
        store.read_sorted(0).unwrap_or_default();
        // MemStore holds no IoStats at all — nothing to charge. Getting
        // here without panicking is the assertion.
        assert_eq!(store.columns(), vec![0, 1, 2]);
    }

    #[test]
    fn run_scans_is_ordered_and_propagates_errors() {
        // Order: results line up with job indices whatever the threads.
        for threads in [1, 4] {
            let out = run_scans(threads, 17, |k| Ok(k * k)).unwrap();
            assert_eq!(out, (0..17).map(|k| k * k).collect::<Vec<_>>());
        }
        // Errors propagate.
        let err = run_scans(4, 8, |k| {
            if k == 5 {
                anyhow::bail!("job {k} failed")
            } else {
                Ok(k)
            }
        });
        assert!(err.is_err());
        // Zero jobs is fine.
        assert_eq!(run_scans(4, 0, |_| Ok(0u8)).unwrap(), Vec::<u8>::new());
    }
}
