//! Typed columnar arrays and presorted views.
//!
//! Numerical columns are `f32` (the paper's datasets are dense floats),
//! categorical columns are `u32` value ids in `0..arity`. Presorting
//! (paper §2.1) turns a numerical column into the list `q(j)` of Alg. 1:
//! `(value, sample_index)` tuples sorted by value. Labels are *not*
//! duplicated into the sorted list — unlike SLIQ, DRF keeps labels in a
//! single shared label column (paper §2.3 "DRF does not store the label
//! values in memory" — in our implementation labels live once per
//! splitter process, not once per attribute list).


/// One entry of a presorted numerical column: Alg. 1's `(a, i)` (the
/// label `y` is looked up from the label column at scan time).
///
/// `repr(C)` pins the layout to the on-disk DRFC record (little-endian
/// `f32` value then `u32` sample, 8 bytes, align 4) so the mmap backend
/// can reinterpret mapped file bytes as `&[SortedEntry]` without a copy.
#[repr(C)]
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SortedEntry {
    /// Attribute value.
    pub value: f32,
    /// Sample (row) index.
    pub sample: u32,
}

/// A typed feature column.
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    /// Dense numerical values, one per row.
    Numerical(Vec<f32>),
    /// Dense categorical value ids, one per row, each `< arity`.
    Categorical {
        /// Value ids, one per row.
        values: Vec<u32>,
        /// Number of distinct values.
        arity: u32,
    },
}

impl Column {
    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            Column::Numerical(v) => v.len(),
            Column::Categorical { values, .. } => values.len(),
        }
    }

    /// Whether the column has no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this is a [`Column::Numerical`].
    pub fn is_numerical(&self) -> bool {
        matches!(self, Column::Numerical(_))
    }

    /// Numerical values, or panic.
    pub fn as_numerical(&self) -> &[f32] {
        match self {
            Column::Numerical(v) => v,
            _ => panic!("column is not numerical"),
        }
    }

    /// Categorical values, or panic.
    pub fn as_categorical(&self) -> &[u32] {
        match self {
            Column::Categorical { values, .. } => values,
            _ => panic!("column is not categorical"),
        }
    }

    /// Arity of a categorical column (`None` for numerical ones).
    pub fn arity(&self) -> Option<u32> {
        match self {
            Column::Categorical { arity, .. } => Some(*arity),
            Column::Numerical(_) => None,
        }
    }

    /// Presort a numerical column into Alg. 1's `q(j)`. Ties are broken
    /// by sample index, making the order — and therefore every
    /// downstream split decision — fully deterministic.
    pub fn presort(&self) -> Vec<SortedEntry> {
        let vals = self.as_numerical();
        let mut entries: Vec<SortedEntry> = vals
            .iter()
            .enumerate()
            .map(|(i, &v)| SortedEntry {
                value: v,
                sample: i as u32,
            })
            .collect();
        entries.sort_by(|a, b| {
            a.value
                .partial_cmp(&b.value)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.sample.cmp(&b.sample))
        });
        entries
    }

    /// Gather a row subset (used by the classic in-memory baseline and by
    /// dataset subsetting; DRF itself never does random access).
    pub fn gather(&self, rows: &[u32]) -> Column {
        match self {
            Column::Numerical(v) => {
                Column::Numerical(rows.iter().map(|&r| v[r as usize]).collect())
            }
            Column::Categorical { values, arity } => Column::Categorical {
                values: rows.iter().map(|&r| values[r as usize]).collect(),
                arity: *arity,
            },
        }
    }

    /// In-memory footprint in bytes (for the memory-complexity benches).
    pub fn nbytes(&self) -> usize {
        match self {
            Column::Numerical(v) => v.len() * 4,
            Column::Categorical { values, .. } => values.len() * 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presort_orders_values_with_stable_ties() {
        let c = Column::Numerical(vec![3.0, 1.0, 2.0, 1.0]);
        let q = c.presort();
        let vals: Vec<f32> = q.iter().map(|e| e.value).collect();
        assert_eq!(vals, vec![1.0, 1.0, 2.0, 3.0]);
        // Tie between rows 1 and 3 broken by sample index.
        assert_eq!(q[0].sample, 1);
        assert_eq!(q[1].sample, 3);
    }

    #[test]
    fn presort_handles_nan_without_panicking() {
        let c = Column::Numerical(vec![1.0, f32::NAN, 0.5]);
        let q = c.presort();
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn gather_subsets() {
        let c = Column::Categorical {
            values: vec![5, 6, 7, 8],
            arity: 10,
        };
        let g = c.gather(&[3, 0]);
        assert_eq!(g.as_categorical(), &[8, 5]);
        assert_eq!(g.arity(), Some(10));
    }

    #[test]
    fn nbytes() {
        let c = Column::Numerical(vec![0.0; 100]);
        assert_eq!(c.nbytes(), 400);
        assert_eq!(c.len(), 100);
        assert!(!c.is_empty());
    }

    #[test]
    #[should_panic(expected = "not numerical")]
    fn wrong_accessor_panics() {
        Column::Categorical {
            values: vec![],
            arity: 2,
        }
        .as_numerical();
    }
}
