//! Dataset substrate: columnar storage (memory + disk), presorting,
//! synthetic generators, and I/O accounting.
//!
//! DRF partitions the dataset **by column** (paper §2.1): each splitter
//! owns a subset of columns and only ever reads them *sequentially* — no
//! random access, no writes after the presorting phase. The structures
//! here are built around that discipline:
//!
//! * [`schema`] — column types and dataset specs;
//! * [`mod@column`] — typed columnar arrays + presorted views;
//! * [`dataset`] — an owned columnar dataset (the unit the generator
//!   produces and the topology shards);
//! * [`disk`] — the DRFC binary column-file format (v1 monolithic, v2
//!   chunk-tabled) with bounded-buffer sequential readers/writers,
//!   instrumented by [`io_stats`];
//! * [`store`] — the **[`store::ColumnStore`]** abstraction: every
//!   splitter scan is a chunk-granular sequential pass over one of its
//!   backends ([`store::MemStore`], [`store::DiskStore`],
//!   [`store::DiskV2Store`], [`mmap::MmapStore`]), plus
//!   [`store::run_scans`] for bounded intra-splitter scan parallelism;
//! * [`mmap`] — the zero-copy backend: DRFC files memory-mapped via
//!   self-declared unix FFI, scans borrow chunk slices straight from
//!   the mapping (first-touch I/O accounting, buffered fallback on
//!   non-unix);
//! * [`sort`] — in-memory and external (k-way merge) presorting of
//!   numerical columns;
//! * [`synthetic`] — the paper's artificial dataset families plus the
//!   Leo-like stand-in for the proprietary real-world dataset.

pub mod column;
pub mod csv;
pub mod dataset;
pub mod disk;
pub mod io_stats;
pub mod mmap;
pub mod schema;
pub mod sort;
pub mod store;
pub mod synthetic;

pub use column::{Column, SortedEntry};
pub use dataset::Dataset;
pub use mmap::MmapStore;
pub use schema::{ColumnSpec, ColumnType, Schema};
pub use store::{ColumnStore, DiskStore, DiskV2Store, MemStore, RawChunk};
