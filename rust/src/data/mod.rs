//! Dataset substrate: columnar storage (memory + disk), presorting,
//! synthetic generators, and I/O accounting.
//!
//! DRF partitions the dataset **by column** (paper §2.1): each splitter
//! owns a subset of columns and only ever reads them *sequentially* — no
//! random access, no writes after the presorting phase. The structures
//! here are built around that discipline:
//!
//! * [`schema`] — column types and dataset specs;
//! * [`mod@column`] — typed columnar arrays + presorted views;
//! * [`dataset`] — an owned columnar dataset (the unit the generator
//!   produces and the topology shards);
//! * [`disk`] — a paged binary column-file format with sequential
//!   readers/writers, instrumented by [`io_stats`];
//! * [`sort`] — in-memory and external (k-way merge) presorting of
//!   numerical columns;
//! * [`synthetic`] — the paper's artificial dataset families plus the
//!   Leo-like stand-in for the proprietary real-world dataset.

pub mod column;
pub mod csv;
pub mod dataset;
pub mod disk;
pub mod io_stats;
pub mod schema;
pub mod sort;
pub mod store;
pub mod synthetic;

pub use column::{Column, SortedEntry};
pub use dataset::Dataset;
pub use schema::{ColumnSpec, ColumnType, Schema};
