//! Dataset substrate: columnar storage (memory, disk, mmap, remote),
//! presorting, synthetic generators, and I/O accounting.
//!
//! DRF partitions the dataset **by column** (paper §2.1): each splitter
//! owns a subset of columns and only ever reads them *sequentially* — no
//! random access, no writes after the presorting phase. The structures
//! here are built around that discipline:
//!
//! * [`schema`] — column types and dataset specs;
//! * [`mod@column`] — typed columnar arrays + presorted views;
//! * [`dataset`] — an owned columnar dataset (the unit the generator
//!   produces and the topology shards);
//! * [`disk`] — the DRFC binary column-file format (v1 monolithic, v2
//!   chunk-tabled) with bounded-buffer sequential readers/writers,
//!   instrumented by [`io_stats`];
//! * [`store`] — the **[`store::ColumnStore`]** abstraction: every
//!   splitter scan is a chunk-granular sequential pass over one of its
//!   backends ([`store::MemStore`], [`store::DiskStore`],
//!   [`store::DiskV2Store`], [`mmap::MmapStore`],
//!   [`remote::RemoteStore`]), plus [`store::run_scans`] for bounded
//!   intra-splitter scan parallelism;
//! * [`mmap`] — the zero-copy backend: DRFC files memory-mapped via
//!   self-declared unix FFI, scans borrow chunk slices straight from
//!   the mapping (first-touch I/O accounting, buffered fallback on
//!   non-unix);
//! * [`remote`] — the object-store backend: DRFC files fetched by
//!   chunk-aligned byte-range reads from a [`objserve`] server
//!   (checksummed complete passes, bounded retry with backoff,
//!   resumable mid-column passes, background range-read prefetch);
//! * [`objserve`] — the `drf objstore` server those reads hit: byte
//!   ranges of one root directory over the shared wire substrate;
//! * [`sort`] — in-memory and external (k-way merge) presorting of
//!   numerical columns;
//! * [`synthetic`] — the paper's artificial dataset families plus the
//!   Leo-like stand-in for the proprietary real-world dataset.
//!
//! The whole module tree carries `#![deny(missing_docs)]`: the data
//! plane is the documented worked example of the "add a backend"
//! recipe (see `ARCHITECTURE.md` and the [`store`] docs), so every
//! public item here must say what it is.
#![deny(missing_docs)]

pub mod column;
pub mod csv;
pub mod dataset;
pub mod disk;
pub mod io_stats;
pub mod mmap;
pub mod objserve;
pub mod remote;
pub mod schema;
pub mod sort;
pub mod store;
pub mod synthetic;

pub use column::{Column, SortedEntry};
pub use dataset::Dataset;
pub use mmap::MmapStore;
pub use objserve::ObjStoreServer;
pub use remote::{RemoteClient, RemoteStore};
pub use schema::{ColumnSpec, ColumnType, Schema};
pub use store::{ColumnStore, DiskStore, DiskV2Store, MemStore, RawChunk};
