//! Dataset schema: column types and metadata.
//!
//! The paper considers exactly two attribute kinds (§2.1): **numerical**
//! (split condition `x ≤ τ`) and **categorical** with known arity (split
//! condition `x ∈ C`). Labels are categorical classes (binary in all of
//! the paper's experiments, but the code is generic over `num_classes`).


/// The type of a feature column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnType {
    /// Real-valued attribute; candidate conditions are `x <= τ`.
    Numerical,
    /// Categorical attribute with values in `0..arity`; candidate
    /// conditions are `x ∈ C`, `C ⊆ {0..arity}`.
    Categorical {
        /// Number of distinct values (paper's Leo dataset has arities
        /// from 2 to 10'000).
        arity: u32,
    },
}

impl ColumnType {
    /// Whether this is [`ColumnType::Numerical`].
    pub fn is_numerical(&self) -> bool {
        matches!(self, ColumnType::Numerical)
    }

    /// Whether this is [`ColumnType::Categorical`].
    pub fn is_categorical(&self) -> bool {
        matches!(self, ColumnType::Categorical { .. })
    }

    /// Arity of a categorical type (`None` for numerical).
    pub fn arity(&self) -> Option<u32> {
        match self {
            ColumnType::Categorical { arity } => Some(*arity),
            ColumnType::Numerical => None,
        }
    }
}

/// One feature column's spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColumnSpec {
    /// Human-readable name, unique within a schema.
    pub name: String,
    /// The column's type.
    pub ctype: ColumnType,
}

impl ColumnSpec {
    /// Spec of a numerical column called `name`.
    pub fn numerical(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            ctype: ColumnType::Numerical,
        }
    }

    /// Spec of a categorical column called `name` with `arity` values.
    pub fn categorical(name: impl Into<String>, arity: u32) -> Self {
        Self {
            name: name.into(),
            ctype: ColumnType::Categorical { arity },
        }
    }
}

/// A dataset schema: the ordered feature columns plus the label.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    /// Feature columns, in dataset order. Column index = position here.
    pub columns: Vec<ColumnSpec>,
    /// Number of label classes (>= 2).
    pub num_classes: u32,
}

impl Schema {
    /// Assemble a schema, asserting well-formedness (at least one
    /// feature, `num_classes >= 2`, unique column names).
    pub fn new(columns: Vec<ColumnSpec>, num_classes: u32) -> Self {
        assert!(num_classes >= 2, "need at least two classes");
        assert!(!columns.is_empty(), "schema needs at least one feature");
        let mut names: Vec<&str> = columns.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), columns.len(), "duplicate column names");
        Self {
            columns,
            num_classes,
        }
    }

    /// Convenience: `k` numerical columns named f0..f{k-1}, binary labels.
    pub fn all_numerical(k: usize) -> Self {
        Self::new(
            (0..k).map(|i| ColumnSpec::numerical(format!("f{i}"))).collect(),
            2,
        )
    }

    /// Number of feature columns (paper's `m`).
    pub fn num_features(&self) -> usize {
        self.columns.len()
    }

    /// Index of a column by name.
    pub fn column_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c.name == name)
    }

    /// Indices of numerical columns.
    pub fn numerical_indices(&self) -> Vec<usize> {
        self.columns
            .iter()
            .enumerate()
            .filter(|(_, c)| c.ctype.is_numerical())
            .map(|(i, _)| i)
            .collect()
    }

    /// Indices of categorical columns.
    pub fn categorical_indices(&self) -> Vec<usize> {
        self.columns
            .iter()
            .enumerate()
            .filter(|(_, c)| c.ctype.is_categorical())
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_basics() {
        let s = Schema::new(
            vec![
                ColumnSpec::numerical("age"),
                ColumnSpec::categorical("country", 50),
            ],
            2,
        );
        assert_eq!(s.num_features(), 2);
        assert_eq!(s.column_index("country"), Some(1));
        assert_eq!(s.column_index("nope"), None);
        assert_eq!(s.numerical_indices(), vec![0]);
        assert_eq!(s.categorical_indices(), vec![1]);
        assert_eq!(s.columns[1].ctype.arity(), Some(50));
        assert!(s.columns[0].ctype.is_numerical());
    }

    #[test]
    fn all_numerical_helper() {
        let s = Schema::all_numerical(5);
        assert_eq!(s.num_features(), 5);
        assert!(s.categorical_indices().is_empty());
        assert_eq!(s.columns[3].name, "f3");
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_names_rejected() {
        Schema::new(
            vec![ColumnSpec::numerical("x"), ColumnSpec::numerical("x")],
            2,
        );
    }

    #[test]
    #[should_panic(expected = "two classes")]
    fn single_class_rejected() {
        Schema::new(vec![ColumnSpec::numerical("x")], 1);
    }
}
