//! I/O and network accounting.
//!
//! Table 1 of the paper compares algorithms on *measured quantities*:
//! bytes read/written per worker, number of sequential passes, and bytes
//! moved over the network. Every disk reader/writer and every transport
//! edge in this crate charges one of these counters, so the complexity
//! benches report the same columns as the paper's table.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A shared set of I/O counters. Cloning shares the underlying atomics,
/// so a worker and the harness observe the same numbers.
#[derive(Debug, Clone, Default)]
pub struct IoStats {
    inner: Arc<IoStatsInner>,
}

#[derive(Debug, Default)]
struct IoStatsInner {
    disk_read_bytes: AtomicU64,
    disk_write_bytes: AtomicU64,
    disk_read_passes: AtomicU64,
    disk_write_passes: AtomicU64,
    net_bytes: AtomicU64,
    net_messages: AtomicU64,
    net_broadcasts: AtomicU64,
}

impl IoStats {
    /// Fresh counters, all zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `bytes` read from storage (disk, mapping, or remote).
    pub fn add_disk_read(&self, bytes: u64) {
        self.inner.disk_read_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Charge `bytes` written to storage.
    pub fn add_disk_write(&self, bytes: u64) {
        self.inner.disk_write_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// A completed sequential read pass over some column/file.
    pub fn add_read_pass(&self) {
        self.inner.disk_read_passes.fetch_add(1, Ordering::Relaxed);
    }

    /// A completed sequential write pass.
    pub fn add_write_pass(&self) {
        self.inner.disk_write_passes.fetch_add(1, Ordering::Relaxed);
    }

    /// Charge one network message of `bytes`.
    pub fn add_net(&self, bytes: u64) {
        self.inner.net_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.inner.net_messages.fetch_add(1, Ordering::Relaxed);
    }

    /// Charge a broadcast: `bytes` to each of `fanout` peers.
    pub fn add_broadcast(&self, bytes: u64, fanout: u64) {
        self.inner.net_bytes.fetch_add(bytes * fanout, Ordering::Relaxed);
        self.inner
            .net_messages
            .fetch_add(fanout, Ordering::Relaxed);
        self.inner.net_broadcasts.fetch_add(1, Ordering::Relaxed);
    }

    /// Record that a set of already-charged per-peer messages logically
    /// formed one broadcast (transports that fan a broadcast out as
    /// individual RPCs charge bytes/messages per peer and count the
    /// event here).
    pub fn add_broadcast_event(&self) {
        self.inner.net_broadcasts.fetch_add(1, Ordering::Relaxed);
    }

    /// Total bytes read from storage.
    pub fn disk_read_bytes(&self) -> u64 {
        self.inner.disk_read_bytes.load(Ordering::Relaxed)
    }

    /// Total bytes written to storage.
    pub fn disk_write_bytes(&self) -> u64 {
        self.inner.disk_write_bytes.load(Ordering::Relaxed)
    }

    /// Completed sequential read passes.
    pub fn disk_read_passes(&self) -> u64 {
        self.inner.disk_read_passes.load(Ordering::Relaxed)
    }

    /// Completed sequential write passes.
    pub fn disk_write_passes(&self) -> u64 {
        self.inner.disk_write_passes.load(Ordering::Relaxed)
    }

    /// Total network bytes.
    pub fn net_bytes(&self) -> u64 {
        self.inner.net_bytes.load(Ordering::Relaxed)
    }

    /// Total network messages.
    pub fn net_messages(&self) -> u64 {
        self.inner.net_messages.load(Ordering::Relaxed)
    }

    /// Total broadcast events.
    pub fn net_broadcasts(&self) -> u64 {
        self.inner.net_broadcasts.load(Ordering::Relaxed)
    }

    /// Reset all counters (between bench scenarios).
    pub fn reset(&self) {
        self.inner.disk_read_bytes.store(0, Ordering::Relaxed);
        self.inner.disk_write_bytes.store(0, Ordering::Relaxed);
        self.inner.disk_read_passes.store(0, Ordering::Relaxed);
        self.inner.disk_write_passes.store(0, Ordering::Relaxed);
        self.inner.net_bytes.store(0, Ordering::Relaxed);
        self.inner.net_messages.store(0, Ordering::Relaxed);
        self.inner.net_broadcasts.store(0, Ordering::Relaxed);
    }

    /// Fold a snapshot's counts into these counters. Used to aggregate
    /// per-connection stats into process totals at disconnect.
    pub fn add_snapshot(&self, s: &IoSnapshot) {
        self.inner
            .disk_read_bytes
            .fetch_add(s.disk_read_bytes, Ordering::Relaxed);
        self.inner
            .disk_write_bytes
            .fetch_add(s.disk_write_bytes, Ordering::Relaxed);
        self.inner
            .disk_read_passes
            .fetch_add(s.disk_read_passes, Ordering::Relaxed);
        self.inner
            .disk_write_passes
            .fetch_add(s.disk_write_passes, Ordering::Relaxed);
        self.inner
            .net_bytes
            .fetch_add(s.net_bytes, Ordering::Relaxed);
        self.inner
            .net_messages
            .fetch_add(s.net_messages, Ordering::Relaxed);
        self.inner
            .net_broadcasts
            .fetch_add(s.net_broadcasts, Ordering::Relaxed);
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> IoSnapshot {
        IoSnapshot {
            disk_read_bytes: self.disk_read_bytes(),
            disk_write_bytes: self.disk_write_bytes(),
            disk_read_passes: self.disk_read_passes(),
            disk_write_passes: self.disk_write_passes(),
            net_bytes: self.net_bytes(),
            net_messages: self.net_messages(),
            net_broadcasts: self.net_broadcasts(),
        }
    }
}

/// A point-in-time copy of the counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoSnapshot {
    /// Bytes read from storage.
    pub disk_read_bytes: u64,
    /// Bytes written to storage.
    pub disk_write_bytes: u64,
    /// Completed sequential read passes.
    pub disk_read_passes: u64,
    /// Completed sequential write passes.
    pub disk_write_passes: u64,
    /// Network bytes.
    pub net_bytes: u64,
    /// Network messages.
    pub net_messages: u64,
    /// Broadcast events.
    pub net_broadcasts: u64,
}

impl IoSnapshot {
    /// Difference vs an earlier snapshot (per-phase accounting).
    pub fn delta_since(&self, earlier: &IoSnapshot) -> IoSnapshot {
        IoSnapshot {
            disk_read_bytes: self.disk_read_bytes - earlier.disk_read_bytes,
            disk_write_bytes: self.disk_write_bytes - earlier.disk_write_bytes,
            disk_read_passes: self.disk_read_passes - earlier.disk_read_passes,
            disk_write_passes: self.disk_write_passes - earlier.disk_write_passes,
            net_bytes: self.net_bytes - earlier.net_bytes,
            net_messages: self.net_messages - earlier.net_messages,
            net_broadcasts: self.net_broadcasts - earlier.net_broadcasts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_share() {
        let s = IoStats::new();
        let s2 = s.clone(); // shared handle
        s.add_disk_read(100);
        s2.add_disk_read(50);
        s.add_read_pass();
        assert_eq!(s.disk_read_bytes(), 150);
        assert_eq!(s2.disk_read_passes(), 1);
    }

    #[test]
    fn broadcast_multiplies_by_fanout() {
        let s = IoStats::new();
        s.add_broadcast(10, 8);
        assert_eq!(s.net_bytes(), 80);
        assert_eq!(s.net_messages(), 8);
        assert_eq!(s.net_broadcasts(), 1);
    }

    #[test]
    fn add_snapshot_merges_every_field() {
        let conn = IoStats::new();
        conn.add_disk_read(100);
        conn.add_write_pass();
        conn.add_net(10);
        conn.add_broadcast(4, 2);
        let totals = IoStats::new();
        totals.add_disk_read(1);
        totals.add_snapshot(&conn.snapshot());
        let t = totals.snapshot();
        assert_eq!(t.disk_read_bytes, 101);
        assert_eq!(t.disk_write_passes, 1);
        assert_eq!(t.net_bytes, 18);
        assert_eq!(t.net_messages, 3);
        assert_eq!(t.net_broadcasts, 1);
    }

    #[test]
    fn reset_and_snapshot_delta() {
        let s = IoStats::new();
        s.add_net(10);
        let snap1 = s.snapshot();
        s.add_net(5);
        let d = s.snapshot().delta_since(&snap1);
        assert_eq!(d.net_bytes, 5);
        assert_eq!(d.net_messages, 1);
        s.reset();
        assert_eq!(s.net_bytes(), 0);
    }
}
