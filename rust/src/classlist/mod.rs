//! The bit-packed sample→leaf mapping (paper §2.3, the "class list").
//!
//! At any point of depth-wise training, each bagged sample sits in
//! exactly one leaf. With `ℓ` *open* (splittable) leaves, DRF encodes the
//! leaf of each sample with `⌈log2(ℓ+1)⌉` bits — the `+1` reserves a code
//! for "sample is in a closed leaf". For the paper's Leo run this is the
//! difference between 114 GB (one 64-bit integer per sample) and a few
//! GB.
//!
//! Code semantics:
//! * `0` — the sample is in a **closed** leaf (or out of the tree);
//! * `1..=ℓ` — the sample is in the open leaf with that 1-based rank.
//!
//! The list re-packs itself whenever the required width changes (both
//! growing and shrinking as leaves split and close). Unlike SLIQ's class
//! list, no label values are stored here (paper: "DRF does not store the
//! label values in memory").


/// Bit-packed sample→leaf-code array.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClassList {
    n: usize,
    /// Bits per sample = ⌈log2(num_open + 1)⌉, min 1.
    width: u32,
    /// Number of open leaves ℓ. Valid codes are 0..=ℓ.
    num_open: u32,
    words: Vec<u64>,
}

/// Width needed for `num_open` open leaves: ⌈log2(ℓ+1)⌉ bits (paper
/// §2.3), minimum 1.
#[inline]
pub fn width_for(num_open: u32) -> u32 {
    let codes = num_open as u64 + 1; // codes 0..=ℓ
    (64 - (codes - 1).leading_zeros()).max(1)
}

impl ClassList {
    /// A fresh class list: all `n` samples in the root (code 1, ℓ = 1).
    pub fn new_all_root(n: usize) -> Self {
        let mut cl = Self::with_open(n, 1);
        // width_for(1) = 1, code 1 = all bits set.
        for w in &mut cl.words {
            *w = u64::MAX;
        }
        cl.mask_tail();
        cl
    }

    /// An all-closed list (code 0 everywhere) sized for `num_open` leaves.
    pub fn with_open(n: usize, num_open: u32) -> Self {
        let width = width_for(num_open);
        let bits = n as u64 * width as u64;
        let words = vec![0u64; bits.div_ceil(64) as usize];
        Self {
            n,
            width,
            num_open,
            words,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current number of open leaves ℓ.
    pub fn num_open(&self) -> u32 {
        self.num_open
    }

    /// Bits per sample.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Total memory used by the packed words, in bits — the paper's
    /// `n·⌈log2(ℓ+1)⌉` (rounded up to whole words).
    pub fn memory_bits(&self) -> u64 {
        self.words.len() as u64 * 64
    }

    /// Leaf code of sample `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.n);
        let width = self.width as u64;
        let bit = i as u64 * width;
        let word = (bit / 64) as usize;
        let off = bit % 64;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let lo = self.words[word] >> off;
        let val = if off + width <= 64 {
            lo & mask
        } else {
            let hi = self.words[word + 1] << (64 - off);
            (lo | hi) & mask
        };
        val as u32
    }

    /// Set the leaf code of sample `i`. `code` must be `<= num_open`.
    #[inline]
    pub fn set(&mut self, i: usize, code: u32) {
        debug_assert!(i < self.n);
        debug_assert!(code <= self.num_open, "code {code} > ℓ {}", self.num_open);
        let width = self.width as u64;
        let bit = i as u64 * width;
        let word = (bit / 64) as usize;
        let off = bit % 64;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let code = code as u64 & mask;
        self.words[word] = (self.words[word] & !(mask << off)) | (code << off);
        if off + width > 64 {
            let spill = 64 - off;
            let hi_mask = mask >> spill;
            self.words[word + 1] =
                (self.words[word + 1] & !hi_mask) | (code >> spill);
        }
    }

    /// Zero any bits beyond `n * width` (keeps Eq/serialization clean).
    fn mask_tail(&mut self) {
        let bits = self.n as u64 * self.width as u64;
        if bits % 64 != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << (bits % 64)) - 1;
            }
        }
    }

    /// Rebuild the list with a new number of open leaves, computing each
    /// sample's new code from its old one. This is the depth-level
    /// transition of Alg. 2 (steps 6-7): leaves split into children,
    /// close, or survive, and the packed width adjusts to
    /// `⌈log2(ℓ'+1)⌉`.
    pub fn rewrite(&self, new_num_open: u32, mut f: impl FnMut(usize, u32) -> u32) -> ClassList {
        let mut out = ClassList::with_open(self.n, new_num_open);
        for i in 0..self.n {
            let code = f(i, self.get(i));
            debug_assert!(code <= new_num_open);
            if code != 0 {
                out.set(i, code);
            }
        }
        out
    }

    /// Count samples per code (length `num_open + 1`). Used by tests and
    /// by leaf-statistics sanity checks.
    pub fn histogram(&self) -> Vec<u64> {
        let mut h = vec![0u64; self.num_open as usize + 1];
        for i in 0..self.n {
            h[self.get(i) as usize] += 1;
        }
        h
    }

    /// Iterate `(sample, code)` for samples in open leaves (code != 0).
    pub fn iter_open(&self) -> impl Iterator<Item = (usize, u32)> + '_ {
        (0..self.n).filter_map(move |i| {
            let c = self.get(i);
            (c != 0).then_some((i, c))
        })
    }

    /// Word-level sequential decode: the leaf codes of samples
    /// `start..start + out.len()`, written into `out`.
    ///
    /// Equivalent to `out[k] = self.get(start + k)` but each packed
    /// word is loaded **once** into a shift register instead of being
    /// re-fetched (and its offsets re-derived) per sample — the
    /// sequential scans (condition evaluation walks the column in row
    /// order) decode their chunk of codes up front through this
    /// (BENCH_hotpath.json `classlist decode`).
    pub fn decode_into(&self, start: usize, out: &mut [u32]) {
        debug_assert!(start + out.len() <= self.n);
        if out.is_empty() {
            return;
        }
        let width = self.width;
        let mask = if width == 64 {
            u64::MAX
        } else {
            (1u64 << width) - 1
        };
        let start_bit = start as u64 * width as u64;
        let mut word_idx = (start_bit / 64) as usize;
        let off = (start_bit % 64) as u32;
        // Shift register: low bits are the next undecoded code.
        let mut acc: u128 = (self.words[word_idx] >> off) as u128;
        let mut acc_bits: u32 = 64 - off;
        word_idx += 1;
        for o in out.iter_mut() {
            if acc_bits < width {
                let w = self.words.get(word_idx).copied().unwrap_or(0);
                acc |= (w as u128) << acc_bits;
                acc_bits += 64;
                word_idx += 1;
            }
            *o = (acc as u64 & mask) as u32;
            acc >>= width;
            acc_bits -= width;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_formula_matches_paper() {
        // ⌈log2(ℓ+1)⌉
        assert_eq!(width_for(1), 1); // codes {0,1}
        assert_eq!(width_for(2), 2); // codes {0,1,2}
        assert_eq!(width_for(3), 2); // codes {0..3}
        assert_eq!(width_for(4), 3);
        assert_eq!(width_for(7), 3);
        assert_eq!(width_for(8), 4);
        assert_eq!(width_for(1 << 20), 21);
    }

    #[test]
    fn new_all_root() {
        let cl = ClassList::new_all_root(100);
        assert_eq!(cl.num_open(), 1);
        assert_eq!(cl.width(), 1);
        for i in 0..100 {
            assert_eq!(cl.get(i), 1);
        }
        assert_eq!(cl.histogram(), vec![0, 100]);
    }

    #[test]
    fn set_get_roundtrip_across_word_boundaries() {
        // width 3 (ℓ=7): samples straddle u64 boundaries at i=21 etc.
        let mut cl = ClassList::with_open(1000, 7);
        for i in 0..1000 {
            cl.set(i, (i % 8) as u32);
        }
        for i in 0..1000 {
            assert_eq!(cl.get(i), (i % 8) as u32, "sample {i}");
        }
    }

    #[test]
    fn wide_codes() {
        // ℓ = 70_000 -> width 17; check large codes survive.
        let mut cl = ClassList::with_open(50, 70_000);
        assert_eq!(cl.width(), 17);
        cl.set(0, 70_000);
        cl.set(49, 65_535);
        cl.set(25, 1);
        assert_eq!(cl.get(0), 70_000);
        assert_eq!(cl.get(49), 65_535);
        assert_eq!(cl.get(25), 1);
        assert_eq!(cl.get(24), 0);
    }

    #[test]
    fn decode_into_matches_get() {
        // Every width that matters: 1, 3 (straddles words), 5, 17, 33.
        for num_open in [1u32, 7, 31, 100_000, u32::MAX] {
            let n = 257usize;
            let mut cl = ClassList::with_open(n, num_open);
            for i in 0..n {
                cl.set(
                    i,
                    ((i as u64 * 2_654_435_761) % (num_open as u64 + 1)) as u32,
                );
            }
            // Whole-range decode.
            let mut out = vec![0u32; n];
            cl.decode_into(0, &mut out);
            for i in 0..n {
                assert_eq!(out[i], cl.get(i), "i={i} width={}", cl.width());
            }
            // Arbitrary offsets and lengths (chunked decoding).
            for (start, len) in [(0usize, 0usize), (1, 64), (63, 65), (100, 157), (256, 1)] {
                let mut out = vec![0u32; len];
                cl.decode_into(start, &mut out);
                for k in 0..len {
                    assert_eq!(out[k], cl.get(start + k), "start={start} k={k}");
                }
            }
        }
    }

    #[test]
    fn rewrite_repacks_width() {
        // Start at root (width 1), split into 2 children (ℓ=2, width 2).
        let cl = ClassList::new_all_root(10);
        let cl2 = cl.rewrite(2, |i, old| {
            assert_eq!(old, 1);
            if i % 2 == 0 {
                1
            } else {
                2
            }
        });
        assert_eq!(cl2.width(), 2);
        assert_eq!(cl2.histogram(), vec![0, 5, 5]);
        // Now close leaf 1 and keep leaf 2 as the only open leaf (ℓ=1).
        let cl3 = cl2.rewrite(1, |_, old| if old == 2 { 1 } else { 0 });
        assert_eq!(cl3.width(), 1);
        assert_eq!(cl3.histogram(), vec![5, 5]);
    }

    #[test]
    fn memory_matches_formula() {
        let n = 1_000_000usize;
        let cl = ClassList::with_open(n, 1023); // width 10
        assert_eq!(cl.width(), 10);
        let expect_bits = (n as u64 * 10).div_ceil(64) * 64;
        assert_eq!(cl.memory_bits(), expect_bits);
        // vs. 64 bits/sample: 6.4x smaller.
        assert!(cl.memory_bits() * 6 < n as u64 * 64);
    }

    #[test]
    fn iter_open_skips_closed() {
        let mut cl = ClassList::with_open(6, 3);
        cl.set(1, 2);
        cl.set(4, 3);
        let open: Vec<(usize, u32)> = cl.iter_open().collect();
        assert_eq!(open, vec![(1, 2), (4, 3)]);
    }

    #[test]
    fn width64_guard() {
        // Absurd ℓ near 2^32: width still computed sanely (≤ 33 for u32 ℓ).
        assert!(width_for(u32::MAX) <= 33);
    }
}
