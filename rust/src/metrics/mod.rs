//! Evaluation metrics: AUC (the paper's headline metric), accuracy, and
//! simple timing helpers used by the benches.

/// Area under the ROC curve for binary labels, computed exactly via the
/// Mann-Whitney U statistic with average ranks for tied scores.
///
/// Returns 0.5 for degenerate inputs (a single class), matching the
/// paper's convention that random/majority labelling has AUC ½.
pub fn auc(scores: &[f64], labels: &[u32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y == 1).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // Sort indices by score; assign average ranks to ties.
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        // Ranks are 1-based; tied block [i..=j] gets the average rank.
        let avg_rank = (i + j + 2) as f64 / 2.0;
        for &k in &idx[i..=j] {
            if labels[k] == 1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos as f64 * (n_pos as f64 + 1.0)) / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Classification accuracy of hard predictions.
pub fn accuracy(predictions: &[u32], labels: &[u32]) -> f64 {
    assert_eq!(predictions.len(), labels.len());
    if labels.is_empty() {
        return 0.0;
    }
    predictions
        .iter()
        .zip(labels)
        .filter(|(p, y)| p == y)
        .count() as f64
        / labels.len() as f64
}

/// Wall-clock stopwatch for the benches and per-depth timing of Fig 3.
#[derive(Debug)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: std::time::Instant::now(),
        }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let s = self.seconds();
        self.start = std::time::Instant::now();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_and_inverted() {
        let labels = [0u32, 0, 1, 1];
        assert_eq!(auc(&[0.1, 0.2, 0.8, 0.9], &labels), 1.0);
        assert_eq!(auc(&[0.9, 0.8, 0.2, 0.1], &labels), 0.0);
    }

    #[test]
    fn auc_random_is_half() {
        // All scores equal -> AUC must be exactly 0.5 via tie handling.
        let labels = [0u32, 1, 0, 1, 1, 0];
        assert_eq!(auc(&[0.5; 6], &labels), 0.5);
    }

    #[test]
    fn auc_degenerate_single_class() {
        assert_eq!(auc(&[0.1, 0.9], &[1, 1]), 0.5);
        assert_eq!(auc(&[0.1, 0.9], &[0, 0]), 0.5);
    }

    #[test]
    fn auc_known_value() {
        // scores: pos {0.8, 0.4}, neg {0.6, 0.2}.
        // Pairs: (0.8>0.6) (0.8>0.2) (0.4<0.6) (0.4>0.2) -> 3/4.
        let labels = [1u32, 0, 1, 0];
        let scores = [0.8, 0.6, 0.4, 0.2];
        assert!((auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn auc_partial_ties() {
        // pos {0.5}, neg {0.5, 0.1}: pair1 tie (0.5), pair2 win -> 0.75.
        let labels = [1u32, 0, 0];
        let scores = [0.5, 0.5, 0.1];
        assert!((auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let t1 = sw.restart();
        assert!(t1 >= 0.004);
        assert!(sw.seconds() < t1);
    }
}
