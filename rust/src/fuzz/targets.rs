//! One in-process harness target per decoder entry point.
//!
//! A target's [`Target::exercise`] runs exactly what a real peer can
//! reach with one frame/document: the decode, the validation the
//! production caller performs next (e.g. [`RowsBatch::into_dataset`]
//! on serving batches, `ensure_untruncated` + `chunk_plan` on DRFC
//! headers), and — when the input decodes — a **fixpoint check**:
//! re-encoding the decoded message and decoding it again must
//! reproduce the same bytes. A decoder may *reject* arbitrary bytes
//! (`Err` is success from the fuzzer's point of view), but it must
//! never panic, never over-allocate, and never decode a frame its own
//! encoder cannot reproduce.
//!
//! Fixpoint checks compare **re-encoded bytes**, not decoded values:
//! float payloads can legitimately carry NaN (never equal to itself)
//! but its bit pattern must still survive a codec roundtrip.
//!
//! Peak-allocation note: targets drop the first decoded value before
//! re-decoding, so the measured peak stays within
//! [`crate::fuzz::alloc_cap`]'s provable budget (one decoded message +
//! one canonical re-encoding, never two decoded messages at once).

use crate::cluster::manifest::{ClusterManifest, ShardManifest};
use crate::coordinator::wire as coord;
use crate::data::disk::Header;
use crate::data::objserve as obj;
use crate::serve::wire as serve;
use crate::util::json::Json;
use crate::util::wire::{read_frame, write_frame};
use crate::Result;
use anyhow::bail;
use std::path::Path;

/// A fuzzable decoder entry point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    /// The shared length-prefixed frame reader ([`read_frame`]).
    Frame,
    /// Coordinator RPC requests ([`coord::decode_request_traced`]).
    CoordRequest,
    /// Coordinator RPC responses ([`coord::decode_response`]).
    CoordResponse,
    /// Serving requests ([`serve::decode_request_traced`]) plus the
    /// batch shape validation the server runs next.
    ServeRequest,
    /// Serving responses ([`serve::decode_response`]).
    ServeResponse,
    /// Object-store requests ([`obj::decode_request_traced`]).
    ObjRequest,
    /// Object-store responses ([`obj::decode_response`]).
    ObjResponse,
    /// The in-tree JSON parser ([`Json::parse`]).
    Json,
    /// `manifest.json` parsing ([`ShardManifest::from_json`]).
    ShardManifest,
    /// `cluster.json` parsing ([`ClusterManifest::from_json`]).
    ClusterManifest,
    /// DRFC v1/v2 column headers ([`Header::parse`] + the open-time
    /// truncation check + chunk planning).
    DrfcHeader,
}

impl Target {
    /// Every target, in canonical (CLI/report) order.
    pub const ALL: [Target; 11] = [
        Target::Frame,
        Target::CoordRequest,
        Target::CoordResponse,
        Target::ServeRequest,
        Target::ServeResponse,
        Target::ObjRequest,
        Target::ObjResponse,
        Target::Json,
        Target::ShardManifest,
        Target::ClusterManifest,
        Target::DrfcHeader,
    ];

    /// Stable kebab-case name (CLI `--target` value and corpus
    /// subdirectory name).
    pub fn name(self) -> &'static str {
        match self {
            Target::Frame => "frame",
            Target::CoordRequest => "coord-request",
            Target::CoordResponse => "coord-response",
            Target::ServeRequest => "serve-request",
            Target::ServeResponse => "serve-response",
            Target::ObjRequest => "obj-request",
            Target::ObjResponse => "obj-response",
            Target::Json => "json",
            Target::ShardManifest => "shard-manifest",
            Target::ClusterManifest => "cluster-manifest",
            Target::DrfcHeader => "drfc-header",
        }
    }

    /// Position in [`Target::ALL`] (part of the per-iteration seed key,
    /// so every target sees an independent deterministic stream).
    pub fn id(self) -> u64 {
        Target::ALL.iter().position(|&t| t == self).unwrap() as u64
    }

    /// Parse one `--target` name.
    pub fn from_name(s: &str) -> Result<Target> {
        for t in Target::ALL {
            if t.name() == s {
                return Ok(t);
            }
        }
        bail!(
            "unknown fuzz target '{s}' (want all, {})",
            Target::ALL.map(|t| t.name()).join(", ")
        )
    }

    /// Parse a `--target` selector: `all`, one name, or a
    /// comma-separated list.
    pub fn parse_selector(s: &str) -> Result<Vec<Target>> {
        if s == "all" {
            return Ok(Target::ALL.to_vec());
        }
        s.split(',').map(|p| Target::from_name(p.trim())).collect()
    }

    /// Feed `input` to the decoder under test. `Err` means the decoder
    /// rejected the bytes — perfectly fine. Panics and over-allocation
    /// are what the driver is hunting; fixpoint violations surface as
    /// panics via the internal assertions.
    pub fn exercise(self, input: &[u8]) -> Result<()> {
        match self {
            Target::Frame => {
                let mut cursor = std::io::Cursor::new(input);
                let body = read_frame(&mut cursor)?;
                // Re-framing the body must reproduce the bytes consumed.
                let consumed = cursor.position() as usize;
                let mut refrained = Vec::with_capacity(consumed);
                write_frame(&mut refrained, &body).expect("write_frame to Vec");
                assert_eq!(
                    &input[..consumed],
                    &refrained[..],
                    "frame codec fixpoint diverged"
                );
            }
            Target::CoordRequest => {
                let (req, ctx) = coord::decode_request_traced(input)?;
                let e1 = coord::encode_request_traced(&req, ctx.as_ref());
                drop(req);
                let (req2, ctx2) = coord::decode_request_traced(&e1)
                    .expect("re-decode of re-encoded coordinator request failed");
                let e2 = coord::encode_request_traced(&req2, ctx2.as_ref());
                assert_eq!(e1, e2, "coordinator request fixpoint diverged");
            }
            Target::CoordResponse => {
                let resp = coord::decode_response(input)?;
                let e1 = coord::encode_response(&resp);
                drop(resp);
                let resp2 = coord::decode_response(&e1)
                    .expect("re-decode of re-encoded coordinator response failed");
                let e2 = coord::encode_response(&resp2);
                assert_eq!(e1, e2, "coordinator response fixpoint diverged");
            }
            Target::ServeRequest => {
                let (id, req, ctx) = serve::decode_request_traced(input)?;
                let e1 = serve::encode_request_traced(id, &req, ctx.as_ref());
                // The server's next step on prediction requests: shape
                // validation + dataset assembly. Its Err is fine; its
                // panic is a finding.
                match req {
                    serve::ServeRequest::Score(batch) | serve::ServeRequest::Classify(batch) => {
                        let _ = batch.into_dataset(2);
                    }
                    _ => drop(req),
                }
                let (id2, req2, ctx2) = serve::decode_request_traced(&e1)
                    .expect("re-decode of re-encoded serving request failed");
                let e2 = serve::encode_request_traced(id2, &req2, ctx2.as_ref());
                assert_eq!(e1, e2, "serving request fixpoint diverged");
            }
            Target::ServeResponse => {
                let (id, resp) = serve::decode_response(input)?;
                let e1 = serve::encode_response(id, &resp);
                drop(resp);
                let (id2, resp2) = serve::decode_response(&e1)
                    .expect("re-decode of re-encoded serving response failed");
                let e2 = serve::encode_response(id2, &resp2);
                assert_eq!(e1, e2, "serving response fixpoint diverged");
            }
            Target::ObjRequest => {
                let (req, ctx) = obj::decode_request_traced(input)?;
                let e1 = obj::encode_request_traced(&req, ctx.as_ref());
                drop(req);
                let (req2, ctx2) = obj::decode_request_traced(&e1)
                    .expect("re-decode of re-encoded objstore request failed");
                let e2 = obj::encode_request_traced(&req2, ctx2.as_ref());
                assert_eq!(e1, e2, "objstore request fixpoint diverged");
            }
            Target::ObjResponse => {
                let resp = obj::decode_response(input)?;
                let e1 = obj::encode_response(&resp);
                drop(resp);
                let resp2 = obj::decode_response(&e1)
                    .expect("re-decode of re-encoded objstore response failed");
                let e2 = obj::encode_response(&resp2);
                assert_eq!(e1, e2, "objstore response fixpoint diverged");
            }
            Target::Json => {
                let text = std::str::from_utf8(input)?;
                let v1 = Json::parse(text)?;
                let t1 = v1.to_string();
                drop(v1);
                let v2 = Json::parse(&t1).expect("re-parse of serialized JSON failed");
                let t2 = v2.to_string();
                assert_eq!(t1, t2, "JSON writer/parser fixpoint diverged");
            }
            Target::ShardManifest => {
                let text = std::str::from_utf8(input)?;
                let doc = Json::parse(text)?;
                let m1 = ShardManifest::from_json(&doc)?;
                drop(doc);
                let t1 = m1.to_json().to_string();
                drop(m1);
                let m2 = ShardManifest::from_json(
                    &Json::parse(&t1).expect("serialized shard manifest is not JSON"),
                )
                .expect("re-parse of serialized shard manifest failed");
                assert_eq!(t1, m2.to_json().to_string(), "shard manifest fixpoint diverged");
            }
            Target::ClusterManifest => {
                let text = std::str::from_utf8(input)?;
                let doc = Json::parse(text)?;
                let m1 = ClusterManifest::from_json(&doc)?;
                drop(doc);
                let t1 = m1.to_json().to_string();
                drop(m1);
                let m2 = ClusterManifest::from_json(
                    &Json::parse(&t1).expect("serialized cluster manifest is not JSON"),
                )
                .expect("re-parse of serialized cluster manifest failed");
                assert_eq!(
                    t1,
                    m2.to_json().to_string(),
                    "cluster manifest fixpoint diverged"
                );
            }
            Target::DrfcHeader => {
                let h = Header::parse(input)?;
                // The open-time contract every backend follows: parse,
                // reject truncation against the real file length, then
                // plan the pass.
                h.ensure_untruncated(input.len() as u64, Path::new("<fuzz-input>"))?;
                let plan = h.chunk_plan();
                assert_eq!(
                    plan.iter().map(|&c| c as u64).sum::<u64>(),
                    h.rows,
                    "chunk plan does not cover the declared rows"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_and_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for t in Target::ALL {
            assert_eq!(Target::from_name(t.name()).unwrap(), t);
            assert!(seen.insert(t.name()), "duplicate target name {}", t.name());
            assert_eq!(Target::ALL[t.id() as usize], t);
        }
        assert!(Target::from_name("nope").is_err());
    }

    #[test]
    fn selector_parses_all_and_lists() {
        assert_eq!(Target::parse_selector("all").unwrap(), Target::ALL.to_vec());
        assert_eq!(
            Target::parse_selector("json, frame").unwrap(),
            vec![Target::Json, Target::Frame]
        );
        assert!(Target::parse_selector("json,bogus").is_err());
    }

    #[test]
    fn garbage_is_rejected_not_panicked() {
        for t in Target::ALL {
            assert!(t.exercise(b"\xFF\xFE\xFD garbage \x00\x01").is_err());
            assert!(t.exercise(b"").is_err());
        }
    }
}
