//! Deterministic mutational fuzzing for every untrusted decode path.
//!
//! The cluster talks three self-built binary protocols to peers it must
//! not trust blindly — coordinator RPCs, serving requests, objstore
//! Stat/Read — plus JSON manifests and DRFC column headers. At paper
//! scale (17.3B examples, days-long runs) a single malformed frame that
//! panics a worker wastes hours of cluster time, and a forged length
//! prefix that drives an unbounded `with_capacity` is just as fatal.
//! This module enforces the decoder invariant directly:
//!
//! > **No panic, no over-allocation, graceful `Err` only** — for any
//! > byte string, on every decoder entry point.
//!
//! The design is deliberately boring and fully deterministic — no
//! clocks, no global RNG, no thread scheduling in the result path:
//!
//! * [`targets::Target`] — one in-process harness per decoder entry
//!   point (frame reader, 3 × request/response codecs, JSON, both
//!   manifests, DRFC headers), each with a re-encode fixpoint check;
//! * [`corpus`] — encoder-driven seed frames (one per message type,
//!   golden-checked into `rust/tests/corpus/`);
//! * [`mutate`] — seeded structure-aware + byte-level mutators;
//! * [`guard`] — a counting global allocator measuring the peak live
//!   heap of each decode, compared against [`alloc_cap`];
//! * [`run`] — the driver: derives one RNG per (run seed, target,
//!   iteration), mutates a seed frame, executes it under
//!   `catch_unwind` + allocation window, reports the first failure per
//!   target with its exact case seed and mutation trace, and optionally
//!   shrinks the repro with a ddmin-style minimizer.
//!
//! Surfaced as `drf fuzz --target T --seed S --iters N [--corpus DIR]
//! [--minimize] [--repro-out DIR]`; CI runs the pinned smoke budget
//! twice and diffs the output (see `docs/fuzzing.md`).

pub mod corpus;
pub mod guard;
pub mod mutate;
pub mod targets;

pub use guard::measure;
pub use mutate::MAX_INPUT_LEN;
pub use targets::Target;

use crate::rng::{SplitMix64, Xoshiro256pp};
use crate::Result;
use anyhow::Context;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;

/// Peak-live-heap budget for decoding one `len`-byte input.
///
/// The bound is provable, not statistical: the most allocation-dense
/// legitimate frame in any of the protocols is a coordinator `Splits`
/// response full of `None` candidates (1 wire byte becomes a 24-byte
/// `Option<SplitCandidate>` plus `Vec` growth slack — comfortably under
/// 128×), and the harness re-encodes at most one decoded message at a
/// time (see `targets`). The constant term absorbs fixed costs —
/// `Reader`/`Writer` state, error formatting, the re-encode buffer for
/// tiny inputs. A decoder that exceeds this cap on *any* input is
/// treating attacker-controlled lengths as trustworthy.
pub fn alloc_cap(len: usize) -> usize {
    128 * len + (1 << 20)
}

/// Iteration budget the minimizer may spend per finding.
const MINIMIZE_BUDGET: usize = 2000;

/// What a fuzz run should do.
#[derive(Debug, Clone)]
pub struct FuzzOptions {
    /// Targets to fuzz, in [`Target::ALL`] order for `all`.
    pub targets: Vec<Target>,
    /// Run seed: the whole run is a pure function of this (plus the
    /// corpus bytes).
    pub seed: u64,
    /// Iterations per target.
    pub iters: u64,
    /// Load seeds from `<dir>/<target>/*.bin` instead of the built-in
    /// encoder corpus.
    pub corpus_dir: Option<PathBuf>,
    /// Shrink failing inputs with the ddmin-style minimizer.
    pub minimize: bool,
    /// Write each finding's (minimized) repro frame here.
    pub repro_dir: Option<PathBuf>,
}

impl Default for FuzzOptions {
    fn default() -> Self {
        FuzzOptions {
            targets: Target::ALL.to_vec(),
            seed: 42,
            iters: 1000,
            corpus_dir: None,
            minimize: false,
            repro_dir: None,
        }
    }
}

/// How a decode violated the invariant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailureKind {
    /// The decoder (or a harness fixpoint assertion) panicked.
    Panic(String),
    /// The decode stayed graceful but its peak live heap exceeded
    /// [`alloc_cap`].
    AllocCap { peak: usize, cap: usize },
}

impl FailureKind {
    fn describe(&self) -> String {
        match self {
            FailureKind::Panic(msg) => format!("panic: {msg}"),
            FailureKind::AllocCap { peak, cap } => {
                format!("allocation cap exceeded: peak {peak} bytes > cap {cap} bytes")
            }
        }
    }

    /// Same failure *class* (minimization must preserve this, not the
    /// exact message — shrinking legitimately changes panic text).
    fn same_class(&self, other: &FailureKind) -> bool {
        matches!(
            (self, other),
            (FailureKind::Panic(_), FailureKind::Panic(_))
                | (FailureKind::AllocCap { .. }, FailureKind::AllocCap { .. })
        )
    }
}

/// One invariant violation, with everything needed to reproduce it.
#[derive(Debug, Clone)]
pub struct Finding {
    pub target: Target,
    /// Iteration index within the target's stream.
    pub iter: u64,
    /// `SplitMix64::hash_key(&[run_seed, target.id(), iter])` — rerun
    /// any single case from just this number.
    pub case_seed: u64,
    /// Corpus seed the mutations started from.
    pub base_seed: String,
    /// Human-readable mutation trace, application order.
    pub trace: Vec<String>,
    /// The failing input as mutated.
    pub input: Vec<u8>,
    /// The shrunk input (only with `FuzzOptions::minimize`).
    pub minimized: Option<Vec<u8>>,
    pub kind: FailureKind,
    /// Where the repro frame was written (only with
    /// `FuzzOptions::repro_dir`).
    pub repro_path: Option<PathBuf>,
}

/// Per-target outcome of a run.
#[derive(Debug, Clone)]
pub struct TargetReport {
    pub target: Target,
    /// Iterations actually executed (stops at the first finding).
    pub iters_run: u64,
    pub finding: Option<Finding>,
}

/// The whole run's outcome. [`FuzzReport::lines`] is the CLI/CI
/// contract: a pure function of (options, corpus bytes) — no clocks,
/// no paths that vary between runs unless the caller passes them in.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    pub targets: Vec<TargetReport>,
}

impl FuzzReport {
    pub fn num_findings(&self) -> usize {
        self.targets.iter().filter(|t| t.finding.is_some()).count()
    }

    /// Deterministic report text, one entry per target.
    pub fn lines(&self) -> Vec<String> {
        let mut out = Vec::new();
        for tr in &self.targets {
            match &tr.finding {
                None => out.push(format!("{}: {} iters, clean", tr.target.name(), tr.iters_run)),
                Some(f) => {
                    out.push(format!(
                        "{}: FAILED at iter {} (case seed {:#018x}, base '{}')",
                        tr.target.name(),
                        f.iter,
                        f.case_seed,
                        f.base_seed
                    ));
                    out.push(format!("  {}", f.kind.describe()));
                    out.push(format!("  mutation trace: {}", f.trace.join(" -> ")));
                    out.push(format!("  input: {} bytes", f.input.len()));
                    if let Some(min) = &f.minimized {
                        out.push(format!("  minimized: {} bytes", min.len()));
                    }
                    if let Some(p) = &f.repro_path {
                        out.push(format!("  repro written: {}", p.display()));
                    }
                    out.push(format!(
                        "  reproduce: drf fuzz --target {} --seed <run-seed> --iters {}",
                        tr.target.name(),
                        f.iter + 1
                    ));
                }
            }
        }
        out.push(format!(
            "fuzz: {} targets, {} finding(s)",
            self.targets.len(),
            self.num_findings()
        ));
        out
    }
}

/// Execute one input against one target under the full invariant:
/// `catch_unwind` for panics, [`guard::measure`] for the allocation
/// cap. `Ok` covers both "decoded cleanly" and "rejected with `Err`".
pub fn run_one(target: Target, input: &[u8]) -> std::result::Result<(), FailureKind> {
    let (outcome, peak) = guard::measure(|| {
        catch_unwind(AssertUnwindSafe(|| {
            // The decoder's Err is success; only panics and the
            // allocation peak matter here.
            let _ = target.exercise(input);
        }))
    });
    match outcome {
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else {
                "non-string panic payload".to_string()
            };
            Err(FailureKind::Panic(msg))
        }
        Ok(()) => {
            let cap = alloc_cap(input.len());
            if peak > cap {
                Err(FailureKind::AllocCap { peak, cap })
            } else {
                Ok(())
            }
        }
    }
}

/// ddmin-lite: repeatedly delete chunks (halving the chunk size) while
/// the input keeps failing in the same class. `check` returns the
/// failure the candidate produces, if any.
fn minimize_with(
    input: &[u8],
    reference: &FailureKind,
    budget: usize,
    mut check: impl FnMut(&[u8]) -> Option<FailureKind>,
) -> Vec<u8> {
    let mut cur = input.to_vec();
    let mut execs = 0usize;
    let mut chunk = (cur.len() / 2).max(1);
    while !cur.is_empty() && execs < budget {
        let mut at = 0usize;
        let mut shrunk = false;
        while at < cur.len() && execs < budget {
            let end = (at + chunk).min(cur.len());
            let mut cand = Vec::with_capacity(cur.len() - (end - at));
            cand.extend_from_slice(&cur[..at]);
            cand.extend_from_slice(&cur[end..]);
            execs += 1;
            match check(&cand) {
                Some(kind) if kind.same_class(reference) => {
                    cur = cand;
                    shrunk = true;
                    // Retry the same offset: the bytes shifted left.
                }
                _ => at = end,
            }
        }
        if chunk == 1 && !shrunk {
            break;
        }
        if !shrunk {
            chunk = (chunk / 2).max(1);
        }
    }
    cur
}

/// Shrink a finding's input against the real target.
pub fn minimize(target: Target, input: &[u8], reference: &FailureKind) -> Vec<u8> {
    minimize_with(input, reference, MINIMIZE_BUDGET, |cand| {
        run_one(target, cand).err()
    })
}

fn fuzz_target(target: Target, opts: &FuzzOptions) -> Result<TargetReport> {
    let seeds: Vec<(String, Vec<u8>)> = match &opts.corpus_dir {
        Some(dir) => corpus::load_seeds(target, dir)?,
        None => corpus::builtin_seeds(target)
            .into_iter()
            .map(|s| (s.name.to_string(), s.bytes))
            .collect(),
    };
    anyhow::ensure!(!seeds.is_empty(), "{}: empty seed corpus", target.name());
    let pool: Vec<Vec<u8>> = seeds.iter().map(|(_, b)| b.clone()).collect();

    for iter in 0..opts.iters {
        let case_seed = SplitMix64::hash_key(&[opts.seed, target.id(), iter]);
        let mut rng = Xoshiro256pp::new(case_seed);
        let base = rng.next_below(seeds.len() as u64) as usize;
        let mut input = seeds[base].1.clone();
        let n_muts = 1 + rng.next_below(4);
        let trace: Vec<String> = (0..n_muts)
            .map(|_| mutate::mutate_once(&mut input, &pool, &mut rng))
            .collect();

        if let Err(kind) = run_one(target, &input) {
            let minimized = opts
                .minimize
                .then(|| minimize(target, &input, &kind))
                .filter(|m| m.len() < input.len());
            let repro_path = match &opts.repro_dir {
                Some(dir) => {
                    std::fs::create_dir_all(dir)
                        .with_context(|| format!("creating repro dir {}", dir.display()))?;
                    let path = dir.join(format!("{}_{case_seed:016x}.bin", target.name()));
                    let bytes = minimized.as_deref().unwrap_or(&input);
                    std::fs::write(&path, bytes)
                        .with_context(|| format!("writing repro {}", path.display()))?;
                    Some(path)
                }
                None => None,
            };
            return Ok(TargetReport {
                target,
                iters_run: iter + 1,
                finding: Some(Finding {
                    target,
                    iter,
                    case_seed,
                    base_seed: seeds[base].0.clone(),
                    trace,
                    input,
                    minimized,
                    kind,
                    repro_path,
                }),
            });
        }
    }
    Ok(TargetReport {
        target,
        iters_run: opts.iters,
        finding: None,
    })
}

/// Run the fuzzer. Stops each target at its first finding (the
/// remaining budget would just re-find the same bug) but always runs
/// every requested target. The returned report is a pure function of
/// the options and corpus bytes.
pub fn run(opts: &FuzzOptions) -> Result<FuzzReport> {
    let mut targets = Vec::with_capacity(opts.targets.len());
    for &target in &opts.targets {
        targets.push(fuzz_target(target, opts)?);
    }
    Ok(FuzzReport { targets })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_cap_scales_with_input() {
        assert_eq!(alloc_cap(0), 1 << 20);
        assert_eq!(alloc_cap(1024), 128 * 1024 + (1 << 20));
        assert!(alloc_cap(MAX_INPUT_LEN) < 16 * 1024 * 1024);
    }

    #[test]
    fn clean_decodes_pass_run_one() {
        for target in Target::ALL {
            for s in corpus::builtin_seeds(target) {
                assert!(
                    run_one(target, &s.bytes).is_ok(),
                    "{}/{} flagged",
                    target.name(),
                    s.name
                );
            }
        }
    }

    #[test]
    fn run_is_deterministic() {
        let opts = FuzzOptions {
            targets: vec![Target::Json, Target::Frame],
            seed: 7,
            iters: 150,
            ..FuzzOptions::default()
        };
        let a = run(&opts).unwrap();
        let b = run(&opts).unwrap();
        assert_eq!(a.lines(), b.lines());
        let other = run(&FuzzOptions {
            seed: 8,
            ..opts.clone()
        })
        .unwrap();
        // Same shape either way; a different seed explores different
        // cases (both should be clean post-hardening).
        assert_eq!(other.targets.len(), a.targets.len());
    }

    #[test]
    fn minimizer_shrinks_while_preserving_failure_class() {
        // Synthetic predicate: "fails" while it still contains 0xEE.
        let reference = FailureKind::Panic("boom".into());
        let mut input = vec![0u8; 64];
        input[37] = 0xEE;
        let min = minimize_with(&input, &reference, 10_000, |cand| {
            cand.contains(&0xEE).then(|| FailureKind::Panic("boom".into()))
        });
        assert_eq!(min, vec![0xEE]);
        // A candidate failing in a *different* class must not be kept.
        let min2 = minimize_with(&input, &reference, 10_000, |cand| {
            cand.contains(&0xEE)
                .then(|| FailureKind::AllocCap { peak: 1, cap: 0 })
        });
        assert_eq!(min2, input, "cross-class shrink accepted");
    }

    #[test]
    fn smoke_every_target_is_clean() {
        // A miniature version of the CI job: every target, a couple of
        // hundred deterministic iterations, zero findings expected.
        let report = run(&FuzzOptions {
            targets: Target::ALL.to_vec(),
            seed: 42,
            iters: 200,
            ..FuzzOptions::default()
        })
        .unwrap();
        let failures: Vec<&str> = report
            .targets
            .iter()
            .filter(|t| t.finding.is_some())
            .map(|t| t.target.name())
            .collect();
        assert!(
            failures.is_empty(),
            "fuzz smoke found failures in: {failures:?}\n{}",
            report.lines().join("\n")
        );
    }
}
