//! Deterministic byte-level and structure-aware mutators.
//!
//! Every mutation is a pure function of the input bytes and the
//! supplied [`Xoshiro256pp`] stream, so a failing case is fully
//! reproduced by its seed (see [`crate::fuzz::run`]). Each application
//! returns a compact human-readable description; the driver collects
//! these into the *mutation trace* printed with a finding.
//!
//! The menu is the classic mutational-fuzzing set plus two
//! protocol-aware entries:
//!
//! * **length-prefix forging** writes an "interesting" u32/u64 (0, 1,
//!   small, `u32::MAX`, …) at a random aligned-ish offset — the exact
//!   shape of the forged-`with_capacity` bugs these decoders must
//!   survive;
//! * **trailer corruption** appends or chops bytes around the optional
//!   16-byte trace-context trailer all three protocols share.

use crate::rng::Xoshiro256pp;

/// Hard clamp on a mutated input. Keeps the allocation invariant
/// provable (see [`crate::fuzz::alloc_cap`]) and the run fast; real
/// frames are orders of magnitude below the wire substrate's 256 MiB
/// frame cap anyway, and every length-forging bug reproduces in well
/// under 64 KiB.
pub const MAX_INPUT_LEN: usize = 64 * 1024;

/// Boundary values for forged length prefixes and scalars.
const INTERESTING: [u64; 16] = [
    0,
    1,
    2,
    7,
    8,
    63,
    64,
    255,
    256,
    0xFFFF,
    0x1_0000,
    0x10_0000,
    u32::MAX as u64 - 1,
    u32::MAX as u64,
    u64::MAX - 1,
    u64::MAX,
];

fn pos_below(rng: &mut Xoshiro256pp, len: usize) -> usize {
    rng.next_below(len.max(1) as u64) as usize
}

/// Apply one randomly chosen mutation to `data` in place and describe
/// it. `pool` supplies splice partners (the target's full seed corpus).
pub fn mutate_once(data: &mut Vec<u8>, pool: &[Vec<u8>], rng: &mut Xoshiro256pp) -> String {
    let desc = match rng.next_below(10) {
        0 => {
            // Truncate at a random point (the classic torn frame).
            if data.is_empty() {
                data.push(rng.next_u64() as u8);
                format!("append1@0={:#04x}", data[0])
            } else {
                let at = pos_below(rng, data.len());
                data.truncate(at);
                format!("truncate@{at}")
            }
        }
        1 => {
            // Flip one bit.
            if data.is_empty() {
                data.push(1);
                "append1@0=0x01".to_string()
            } else {
                let at = pos_below(rng, data.len());
                let bit = rng.next_below(8) as u8;
                data[at] ^= 1 << bit;
                format!("bitflip@{at}.{bit}")
            }
        }
        2 => {
            // Overwrite one byte with a random value.
            if data.is_empty() {
                data.push(rng.next_u64() as u8);
                format!("append1@0={:#04x}", data[0])
            } else {
                let at = pos_below(rng, data.len());
                data[at] = rng.next_u64() as u8;
                format!("byteset@{at}={:#04x}", data[at])
            }
        }
        3 => {
            // Forge a u32 length prefix / scalar (LE) somewhere.
            let v = INTERESTING[rng.next_below(INTERESTING.len() as u64) as usize] as u32;
            if data.len() < 4 {
                data.extend_from_slice(&v.to_le_bytes());
                format!("append-u32={v:#x}")
            } else {
                let at = pos_below(rng, data.len() - 3);
                data[at..at + 4].copy_from_slice(&v.to_le_bytes());
                format!("forge-u32@{at}={v:#x}")
            }
        }
        4 => {
            // Forge a u64 scalar (LE) somewhere.
            let v = INTERESTING[rng.next_below(INTERESTING.len() as u64) as usize];
            if data.len() < 8 {
                data.extend_from_slice(&v.to_le_bytes());
                format!("append-u64={v:#x}")
            } else {
                let at = pos_below(rng, data.len() - 7);
                data[at..at + 8].copy_from_slice(&v.to_le_bytes());
                format!("forge-u64@{at}={v:#x}")
            }
        }
        5 => {
            // Trailer corruption: grow or shrink the frame around the
            // optional 16-byte trace-context trailer.
            match rng.next_below(3) {
                0 => {
                    for _ in 0..16 {
                        data.push(rng.next_u64() as u8);
                    }
                    "trailer-append16".to_string()
                }
                1 => {
                    let n = (rng.next_below(16) as usize + 1).min(data.len());
                    data.truncate(data.len() - n);
                    format!("trailer-chop{n}")
                }
                _ => {
                    let n = rng.next_below(8) as usize + 1;
                    for _ in 0..n {
                        data.push(rng.next_u64() as u8);
                    }
                    format!("trailer-append{n}")
                }
            }
        }
        6 => {
            // Splice: keep a prefix of ours, graft a suffix of a pool
            // seed (crossover between valid frames).
            static EMPTY: Vec<u8> = Vec::new();
            let other = if pool.is_empty() {
                &EMPTY
            } else {
                &pool[rng.next_below(pool.len() as u64) as usize]
            };
            let keep = pos_below(rng, data.len() + 1);
            let from = pos_below(rng, other.len() + 1);
            data.truncate(keep);
            data.extend_from_slice(&other[from..]);
            format!("splice@{keep}+pool[{from}..]")
        }
        7 => {
            // Insert a short run of random bytes.
            let at = pos_below(rng, data.len() + 1);
            let n = rng.next_below(8) as usize + 1;
            let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            data.splice(at..at, bytes);
            format!("insert@{at}x{n}")
        }
        8 => {
            // Delete a byte range.
            if data.is_empty() {
                data.push(0);
                "append1@0=0x00".to_string()
            } else {
                let at = pos_below(rng, data.len());
                let n = (rng.next_below(16) as usize + 1).min(data.len() - at);
                data.drain(at..at + n);
                format!("delete@{at}x{n}")
            }
        }
        _ => {
            // Duplicate a range in place (drives nesting/repetition —
            // e.g. deep JSON arrays from a shallow seed).
            if data.is_empty() {
                data.push(b'[');
                "append1@0=0x5b".to_string()
            } else {
                let at = pos_below(rng, data.len());
                let n = (rng.next_below(32) as usize + 1).min(data.len() - at);
                let copy: Vec<u8> = data[at..at + n].to_vec();
                data.splice(at..at, copy);
                format!("dup@{at}x{n}")
            }
        }
    };
    data.truncate(MAX_INPUT_LEN);
    desc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutations_are_deterministic() {
        let pool = vec![b"hello world frame".to_vec(), b"\x01\x02\x03\x04".to_vec()];
        let run = |seed: u64| {
            let mut rng = Xoshiro256pp::new(seed);
            let mut data = pool[0].clone();
            let trace: Vec<String> = (0..32).map(|_| mutate_once(&mut data, &pool, &mut rng)).collect();
            (data, trace)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7).1, run(8).1, "different seeds, same trace");
    }

    #[test]
    fn inputs_stay_clamped() {
        let pool = vec![vec![0xAA; 1024]];
        let mut rng = Xoshiro256pp::new(99);
        let mut data = pool[0].clone();
        for _ in 0..10_000 {
            mutate_once(&mut data, &pool, &mut rng);
            assert!(data.len() <= MAX_INPUT_LEN);
        }
    }

    #[test]
    fn empty_input_survives_every_mutator() {
        let pool = vec![Vec::new()];
        let mut rng = Xoshiro256pp::new(3);
        for _ in 0..1000 {
            let mut data = Vec::new();
            mutate_once(&mut data, &pool, &mut rng);
        }
    }
}
