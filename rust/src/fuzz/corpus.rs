//! Seed corpus: one valid frame per message type of every protocol,
//! produced by the **real encoders** (never hand-rolled bytes, except
//! for the DRFC headers whose writer is file-backed).
//!
//! The seeds serve three masters:
//!
//! * the mutation engine starts every iteration from a valid frame, so
//!   mutations explore the neighborhood of real traffic instead of
//!   drowning in "bad magic" rejections;
//! * `rust/tests/corpus/<target>/*.bin` checks the exact bytes into the
//!   repo (golden files) — a codec change that silently reshapes wire
//!   traffic fails the corpus test until the files are regenerated with
//!   `DRF_UPDATE_CORPUS=1 cargo test`;
//! * the per-target coverage lists ([`required_seeds`]) assert every
//!   RPC/request variant has at least one seed, and the exhaustive
//!   matches in this module break the build when a new variant is added
//!   without one.

use super::targets::Target;
use crate::cluster::manifest::{ClusterManifest, ShardColumn, ShardEntry, ShardManifest};
use crate::coordinator::messages::{
    Bitmap, EvalQuery, EvalResult, LeafInfo, LeafOutcome, LevelUpdate, MaterializeQuery,
    MaterializedColumn, MaterializedLeaf, MaterializedLeaves, PartialSupersplit, SubtreeDone,
    SupersplitQuery,
};
use crate::coordinator::wire as coord;
use crate::coordinator::wire::{HelloConfig, HelloInfo, Request, Response};
use crate::data::column::Column;
use crate::data::objserve as obj;
use crate::data::schema::{ColumnSpec, Schema};
use crate::serve::wire as serve;
use crate::serve::wire::{ModelInfo, RowsBatch, ServeRequest, ServeResponse};
use crate::splits::SplitCandidate;
use crate::telemetry::{TimeSyncReply, TraceContext};
use crate::tree::{CategorySet, Condition};
use crate::util::wire::write_frame;
use crate::Result;
use anyhow::Context;
use std::path::{Path, PathBuf};

/// One corpus entry: a stable name plus the encoded frame.
#[derive(Debug, Clone)]
pub struct Seed {
    /// File stem under `tests/corpus/<target>/` (snake_case message
    /// name, `_traced` suffix for trailer-carrying variants).
    pub name: &'static str,
    /// The encoded frame/document bytes.
    pub bytes: Vec<u8>,
}

fn seed(name: &'static str, bytes: Vec<u8>) -> Seed {
    Seed { name, bytes }
}

fn sample_ctx() -> TraceContext {
    TraceContext {
        trace_id: 0x1122_3344_5566_7788,
        parent_span: 0x99AA_BBCC_DDEE_FF00,
    }
}

fn sample_time_sync() -> TimeSyncReply {
    TimeSyncReply {
        role: "worker".into(),
        shard: Some(1),
        pid: 4242,
        t_us: 1_234_567,
    }
}

fn sample_bitmap() -> Bitmap {
    let mut b = Bitmap::with_len(10);
    for i in [0usize, 3, 4, 9] {
        b.set(i, true);
    }
    b
}

fn sample_candidate() -> SplitCandidate {
    SplitCandidate {
        condition: Condition::CatIn {
            feature: 3,
            set: CategorySet::from_values(6, [1, 4]),
        },
        gain: 0.25,
        left_counts: vec![3, 1],
        right_counts: vec![2, 4],
    }
}

/// Snake_case name of a coordinator request variant. Exhaustive on
/// purpose: adding a `Request` variant fails the build here until the
/// corpus ([`coord_request_seeds`]) and [`required_seeds`] know it.
pub fn coord_request_variant(req: &Request) -> &'static str {
    match req {
        Request::StartTree(_) => "start_tree",
        Request::RootStats(_) => "root_stats",
        Request::FindSplits(_) => "find_splits",
        Request::EvalConditions(_) => "eval_conditions",
        Request::LevelUpdate(_) => "level_update",
        Request::FinishTree(_) => "finish_tree",
        Request::Shutdown => "shutdown",
        Request::Hello(_) => "hello",
        Request::Materialize(_) => "materialize",
        Request::SubtreeDone(_) => "subtree_done",
        Request::TimeSync => "time_sync",
    }
}

/// Snake_case name of a coordinator response variant (exhaustive; see
/// [`coord_request_variant`]).
pub fn coord_response_variant(resp: &Response) -> &'static str {
    match resp {
        Response::Ok => "ok",
        Response::RootStats(_) => "root_stats",
        Response::Splits(_) => "splits",
        Response::Evals(_) => "evals",
        Response::Err(_) => "err",
        Response::Hello(_) => "hello",
        Response::Materialized(_) => "materialized",
        Response::TimeSync(_) => "time_sync",
    }
}

/// Snake_case name of a serving request variant (exhaustive).
pub fn serve_request_variant(req: &ServeRequest) -> &'static str {
    match req {
        ServeRequest::Score(_) => "score",
        ServeRequest::Classify(_) => "classify",
        ServeRequest::ModelInfo => "model_info",
        ServeRequest::Reload { .. } => "reload",
        ServeRequest::TimeSync => "time_sync",
    }
}

/// Snake_case name of a serving response variant (exhaustive).
pub fn serve_response_variant(resp: &ServeResponse) -> &'static str {
    match resp {
        ServeResponse::Scores(_) => "scores",
        ServeResponse::Classes(_) => "classes",
        ServeResponse::Info(_) => "info",
        ServeResponse::Reloaded { .. } => "reloaded",
        ServeResponse::Err(_) => "err",
        ServeResponse::TimeSync(_) => "time_sync",
    }
}

/// Snake_case name of an objstore request variant (exhaustive).
pub fn obj_request_variant(req: &obj::ObjRequest) -> &'static str {
    match req {
        obj::ObjRequest::Stat { .. } => "stat",
        obj::ObjRequest::Read { .. } => "read",
        obj::ObjRequest::TimeSync => "time_sync",
    }
}

/// Snake_case name of an objstore response variant (exhaustive).
pub fn obj_response_variant(resp: &obj::ObjResponse) -> &'static str {
    match resp {
        obj::ObjResponse::Stat { .. } => "stat",
        obj::ObjResponse::Data(_) => "data",
        obj::ObjResponse::TimeSync(_) => "time_sync",
        obj::ObjResponse::Err(_) => "err",
    }
}

fn coord_requests() -> Vec<Request> {
    vec![
        Request::StartTree(1),
        Request::RootStats(1),
        Request::FindSplits(SupersplitQuery {
            tree: 1,
            depth: 2,
            leaves: vec![
                LeafInfo {
                    node_id: 1,
                    totals: vec![5, 3],
                    detached: false,
                },
                LeafInfo {
                    node_id: 2,
                    totals: vec![2, 2],
                    detached: true,
                },
            ],
            assigned_columns: vec![0, 2],
        }),
        Request::EvalConditions(EvalQuery {
            tree: 1,
            depth: 2,
            conditions: vec![
                (
                    1,
                    Condition::NumLe {
                        feature: 0,
                        threshold: 0.5,
                    },
                ),
                (
                    2,
                    Condition::CatIn {
                        feature: 3,
                        set: CategorySet::from_values(6, [1, 4]),
                    },
                ),
            ],
        }),
        Request::LevelUpdate(LevelUpdate {
            tree: 1,
            depth: 2,
            outcomes: vec![
                LeafOutcome::Closed,
                LeafOutcome::Split {
                    bitmap: sample_bitmap(),
                    left_open: true,
                    right_open: false,
                },
                LeafOutcome::Detached,
            ],
        }),
        Request::FinishTree(1),
        Request::Shutdown,
        Request::Hello(HelloConfig {
            protocol: coord::PROTOCOL_VERSION,
            shard: 0,
            num_splitters: 2,
            redundancy: 1,
            seed: 42,
            bagging: "poisson".into(),
            sampling: "sqrt".into(),
            num_candidates: 8,
            score_kind: "gini".into(),
            prune_threshold: Some(0.01),
            split_search: "exact".into(),
            depth_next_rows: 65_536,
            topology_version: 3,
        }),
        Request::Materialize(MaterializeQuery {
            tree: 1,
            depth: 3,
            ranks: vec![1, 2],
            columns: vec![0, 1],
            want_meta: true,
        }),
        Request::SubtreeDone(SubtreeDone {
            tree: 1,
            root: 5,
            rows: 100,
            nodes: 7,
        }),
        Request::TimeSync,
    ]
}

fn coord_responses() -> Vec<Response> {
    vec![
        Response::Ok,
        Response::RootStats(vec![60, 40]),
        Response::Splits(PartialSupersplit {
            splits: vec![None, Some(sample_candidate())],
        }),
        Response::Evals(EvalResult {
            bitmaps: vec![(1, sample_bitmap())],
        }),
        Response::Err("boom".into()),
        Response::Hello(HelloInfo {
            protocol: coord::PROTOCOL_VERSION,
            shard: 0,
            rows: 120,
            num_classes: 2,
            columns: vec![0, 2, 4],
        }),
        Response::Materialized(MaterializedLeaves {
            leaves: vec![MaterializedLeaf {
                rows: 3,
                labels: vec![0, 1, 1],
                bags: vec![1, 1, 2],
                columns: vec![
                    MaterializedColumn::Num(vec![0.5, 1.5, 2.5]),
                    MaterializedColumn::Cat {
                        arity: 4,
                        values: vec![0, 3, 1],
                    },
                ],
            }],
        }),
        Response::TimeSync(sample_time_sync()),
    ]
}

fn sample_batch() -> RowsBatch {
    RowsBatch {
        columns: vec![
            Column::Numerical(vec![0.1, 0.2, 0.3]),
            Column::Categorical {
                values: vec![0, 2, 1],
                arity: 3,
            },
        ],
    }
}

fn serve_requests() -> Vec<ServeRequest> {
    vec![
        ServeRequest::Score(sample_batch()),
        ServeRequest::Classify(sample_batch()),
        ServeRequest::ModelInfo,
        ServeRequest::Reload {
            path: Some("model.json".into()),
        },
        ServeRequest::TimeSync,
    ]
}

fn serve_responses() -> Vec<ServeResponse> {
    vec![
        ServeResponse::Scores(vec![0.25, 0.75, 0.5]),
        ServeResponse::Classes(vec![0, 1, 1]),
        ServeResponse::Info(ModelInfo {
            num_trees: 10,
            num_classes: 2,
            num_nodes: 321,
        }),
        ServeResponse::Reloaded { num_trees: 10 },
        ServeResponse::Err("nope".into()),
        ServeResponse::TimeSync(sample_time_sync()),
    ]
}

fn obj_requests() -> Vec<obj::ObjRequest> {
    vec![
        obj::ObjRequest::Stat {
            path: "shard_0/col_0.drfc".into(),
        },
        obj::ObjRequest::Read {
            path: "shard_0/col_0.drfc".into(),
            offset: 20,
            len: 4096,
        },
        obj::ObjRequest::TimeSync,
    ]
}

fn obj_responses() -> Vec<obj::ObjResponse> {
    vec![
        obj::ObjResponse::Stat { len: 81_920 },
        obj::ObjResponse::Data(vec![0xAB; 32]),
        obj::ObjResponse::TimeSync(sample_time_sync()),
        obj::ObjResponse::Err("no such object".into()),
    ]
}

fn sample_shard_manifest() -> ShardManifest {
    ShardManifest {
        shard: 0,
        num_splitters: 2,
        redundancy: 1,
        rows: 120,
        schema: Schema::new(
            vec![
                ColumnSpec::numerical("f0"),
                ColumnSpec::categorical("f1", 5),
            ],
            2,
        ),
        columns: vec![
            ShardColumn {
                index: 0,
                file: "col_0.drfc".into(),
                checksum: 0x1234_5678_9ABC_DEF0,
                sorted_file: Some("col_0.sorted.drfc".into()),
                sorted_checksum: Some(0x0FED_CBA9_8765_4321),
            },
            ShardColumn {
                index: 1,
                file: "col_1.drfc".into(),
                checksum: 0x1111_2222_3333_4444,
                sorted_file: None,
                sorted_checksum: None,
            },
        ],
        labels_file: "labels.drfc".into(),
        labels_checksum: 0x5555_6666_7777_8888,
    }
}

fn sample_cluster_manifest() -> ClusterManifest {
    ClusterManifest {
        num_splitters: 2,
        redundancy: 1,
        rows: 120,
        num_features: 2,
        num_classes: 2,
        shards: vec![
            ShardEntry {
                shard: 0,
                dir: "shard_0".into(),
                columns: vec![0],
            },
            ShardEntry {
                shard: 1,
                dir: "shard_1".into(),
                columns: vec![1],
            },
        ],
        workers: vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()],
        version: 1,
        objstores: vec!["127.0.0.1:9001".into()],
    }
}

fn drfc_header_v1() -> Vec<u8> {
    // "DRFC", version 1, kind Numerical (1), 12 rows + the 12 records
    // (48 payload bytes) the open-time truncation check wants to see.
    let mut b = Vec::new();
    b.extend_from_slice(b"DRFC");
    b.extend_from_slice(&1u32.to_le_bytes());
    b.extend_from_slice(&1u32.to_le_bytes());
    b.extend_from_slice(&12u64.to_le_bytes());
    b.extend_from_slice(&[0u8; 48]);
    b
}

fn drfc_header_v2() -> Vec<u8> {
    // "DRFC", version 2, kind SortedNumerical (3), 10 rows in chunks
    // [6, 4] + the 80 payload bytes (10 × 8-byte sorted records).
    let mut b = Vec::new();
    b.extend_from_slice(b"DRFC");
    b.extend_from_slice(&2u32.to_le_bytes());
    b.extend_from_slice(&3u32.to_le_bytes());
    b.extend_from_slice(&10u64.to_le_bytes());
    b.extend_from_slice(&2u32.to_le_bytes());
    b.extend_from_slice(&6u32.to_le_bytes());
    b.extend_from_slice(&4u32.to_le_bytes());
    b.extend_from_slice(&[0u8; 80]);
    b
}

/// The built-in seeds of one target, in stable order.
pub fn builtin_seeds(target: Target) -> Vec<Seed> {
    match target {
        Target::Frame => {
            let mut framed = Vec::new();
            write_frame(&mut framed, b"hello frame body").unwrap();
            let mut empty = Vec::new();
            write_frame(&mut empty, b"").unwrap();
            vec![seed("short", framed), seed("empty", empty)]
        }
        Target::CoordRequest => {
            let mut seeds: Vec<Seed> = coord_requests()
                .iter()
                .map(|req| seed(coord_request_variant(req), coord::encode_request(req)))
                .collect();
            seeds.push(seed(
                "hello_traced",
                coord::encode_request_traced(&coord_requests()[7], Some(&sample_ctx())),
            ));
            seeds
        }
        Target::CoordResponse => coord_responses()
            .iter()
            .map(|resp| seed(coord_response_variant(resp), coord::encode_response(resp)))
            .collect(),
        Target::ServeRequest => {
            let mut seeds: Vec<Seed> = serve_requests()
                .iter()
                .map(|req| seed(serve_request_variant(req), serve::encode_request(7, req)))
                .collect();
            seeds.push(seed(
                "score_traced",
                serve::encode_request_traced(7, &serve_requests()[0], Some(&sample_ctx())),
            ));
            seeds
        }
        Target::ServeResponse => serve_responses()
            .iter()
            .map(|resp| seed(serve_response_variant(resp), serve::encode_response(7, resp)))
            .collect(),
        Target::ObjRequest => {
            let mut seeds: Vec<Seed> = obj_requests()
                .iter()
                .map(|req| seed(obj_request_variant(req), obj::encode_request(req)))
                .collect();
            seeds.push(seed(
                "read_traced",
                obj::encode_request_traced(&obj_requests()[1], Some(&sample_ctx())),
            ));
            seeds
        }
        Target::ObjResponse => obj_responses()
            .iter()
            .map(|resp| seed(obj_response_variant(resp), obj::encode_response(resp)))
            .collect(),
        Target::Json => vec![
            seed(
                "nested",
                br#"{"name":"drf","nums":[1,2.5,-3e-2],"flags":{"a":true,"b":null},"deep":[[1],[2,[3]]]}"#
                    .to_vec(),
            ),
            seed("escapes", r#"{"s":"he\"llo\nA wörld\\"}"#.as_bytes().to_vec()),
            seed("scalar", b"1234567890.5".to_vec()),
        ],
        Target::ShardManifest => vec![seed(
            "shard_manifest",
            sample_shard_manifest().to_json().to_string().into_bytes(),
        )],
        Target::ClusterManifest => vec![seed(
            "cluster_manifest",
            sample_cluster_manifest().to_json().to_string().into_bytes(),
        )],
        Target::DrfcHeader => vec![
            seed("v1_numerical", drfc_header_v1()),
            seed("v2_sorted_chunked", drfc_header_v2()),
        ],
    }
}

/// Seed names each target must carry — at least one per message type of
/// its protocol. Keep in sync with the exhaustive `*_variant` matches
/// above (the compiler flags new variants there, this list then makes
/// the corpus test demand a seed for them).
pub fn required_seeds(target: Target) -> &'static [&'static str] {
    match target {
        Target::Frame => &["short", "empty"],
        Target::CoordRequest => &[
            "start_tree",
            "root_stats",
            "find_splits",
            "eval_conditions",
            "level_update",
            "finish_tree",
            "shutdown",
            "hello",
            "materialize",
            "subtree_done",
            "time_sync",
            "hello_traced",
        ],
        Target::CoordResponse => &[
            "ok",
            "root_stats",
            "splits",
            "evals",
            "err",
            "hello",
            "materialized",
            "time_sync",
        ],
        Target::ServeRequest => &[
            "score",
            "classify",
            "model_info",
            "reload",
            "time_sync",
            "score_traced",
        ],
        Target::ServeResponse => &[
            "scores",
            "classes",
            "info",
            "reloaded",
            "err",
            "time_sync",
        ],
        Target::ObjRequest => &["stat", "read", "time_sync", "read_traced"],
        Target::ObjResponse => &["stat", "data", "time_sync", "err"],
        Target::Json => &["nested", "escapes", "scalar"],
        Target::ShardManifest => &["shard_manifest"],
        Target::ClusterManifest => &["cluster_manifest"],
        Target::DrfcHeader => &["v1_numerical", "v2_sorted_chunked"],
    }
}

/// The checked-in corpus root (`rust/tests/corpus`).
pub fn corpus_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("corpus")
}

/// Load a target's seeds for a fuzz run: every `*.bin` under
/// `<dir>/<target>/` in filename order, falling back to the built-in
/// seeds when the directory is absent or empty. Filename order (not
/// readdir order) keeps runs deterministic across filesystems.
pub fn load_seeds(target: Target, dir: &Path) -> Result<Vec<(String, Vec<u8>)>> {
    let sub = dir.join(target.name());
    let mut found: Vec<(String, Vec<u8>)> = Vec::new();
    if sub.is_dir() {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(&sub)
            .with_context(|| format!("reading corpus dir {}", sub.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|e| e == "bin"))
            .collect();
        paths.sort();
        for p in paths {
            let name = p
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            let bytes = std::fs::read(&p)
                .with_context(|| format!("reading corpus seed {}", p.display()))?;
            found.push((name, bytes));
        }
    }
    if found.is_empty() {
        found = builtin_seeds(target)
            .into_iter()
            .map(|s| (s.name.to_string(), s.bytes))
            .collect();
    }
    Ok(found)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_message_type_has_a_seed() {
        for target in Target::ALL {
            let names: Vec<&str> = builtin_seeds(target).iter().map(|s| s.name).collect();
            for required in required_seeds(target) {
                assert!(
                    names.contains(required),
                    "{}: missing required seed '{required}'",
                    target.name()
                );
            }
            let mut unique = names.clone();
            unique.sort_unstable();
            unique.dedup();
            assert_eq!(unique.len(), names.len(), "{}: duplicate seed names", target.name());
        }
    }

    #[test]
    fn every_builtin_seed_exercises_clean() {
        for target in Target::ALL {
            for s in builtin_seeds(target) {
                if let Err(e) = target.exercise(&s.bytes) {
                    panic!("{}/{} does not decode: {e:#}", target.name(), s.name);
                }
            }
        }
    }

    #[test]
    fn request_seed_names_match_decoded_variants() {
        for req in coord_requests() {
            let frame = coord::encode_request(&req);
            let back = coord::decode_request(&frame).unwrap();
            assert_eq!(coord_request_variant(&back), coord_request_variant(&req));
        }
        for resp in coord_responses() {
            let frame = coord::encode_response(&resp);
            let back = coord::decode_response(&frame).unwrap();
            assert_eq!(coord_response_variant(&back), coord_response_variant(&resp));
        }
    }

    #[test]
    fn golden_corpus_files_match_builtin_seeds() {
        // The on-disk corpus must byte-match the encoders. Regenerate
        // with: DRF_UPDATE_CORPUS=1 cargo test -q golden_corpus
        let update = std::env::var_os("DRF_UPDATE_CORPUS").is_some();
        let root = corpus_root();
        for target in Target::ALL {
            let dir = root.join(target.name());
            for s in builtin_seeds(target) {
                let path = dir.join(format!("{}.bin", s.name));
                if update {
                    std::fs::create_dir_all(&dir).unwrap();
                    std::fs::write(&path, &s.bytes).unwrap();
                    continue;
                }
                let disk = std::fs::read(&path).unwrap_or_else(|e| {
                    panic!(
                        "{}: cannot read checked-in seed ({e}); regenerate with \
                         DRF_UPDATE_CORPUS=1 cargo test",
                        path.display()
                    )
                });
                assert_eq!(
                    disk,
                    s.bytes,
                    "{}: checked-in seed differs from the encoder output; regenerate \
                     with DRF_UPDATE_CORPUS=1 cargo test",
                    path.display()
                );
            }
        }
    }

    #[test]
    fn load_seeds_prefers_disk_and_falls_back() {
        let tmp = crate::util::tempdir().unwrap();
        // Absent dir -> builtins.
        let fallback = load_seeds(Target::Json, tmp.path()).unwrap();
        assert_eq!(fallback.len(), builtin_seeds(Target::Json).len());
        // Populated dir -> exactly the files, in name order.
        let sub = tmp.path().join("json");
        std::fs::create_dir_all(&sub).unwrap();
        std::fs::write(sub.join("b.bin"), b"2").unwrap();
        std::fs::write(sub.join("a.bin"), b"1").unwrap();
        std::fs::write(sub.join("ignored.txt"), b"x").unwrap();
        let disk = load_seeds(Target::Json, tmp.path()).unwrap();
        assert_eq!(
            disk.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["a", "b"]
        );
        assert_eq!(disk[0].1, b"1");
    }
}
