//! Allocation-cap guard: a counting [`GlobalAlloc`] wrapper that lets
//! the fuzz harness measure the **peak live heap** a decoder reaches
//! while chewing on one input.
//!
//! "No panic" alone is not the invariant the cluster needs — a forged
//! length prefix that drives a multi-GiB `with_capacity` takes a worker
//! down just as surely as an index-out-of-bounds. The harness therefore
//! runs every decode inside [`measure`] and compares the observed peak
//! against [`crate::fuzz::alloc_cap`].
//!
//! Design constraints:
//!
//! * **Near-zero cost when idle.** Every allocation in the process pays
//!   exactly one relaxed atomic load while no measurement window is
//!   open (the common case: production binaries, non-fuzz tests).
//! * **Thread-local accounting.** A window only counts allocations made
//!   by the thread that opened it, so parallel test threads (or server
//!   threads in the same process) do not pollute each other's peaks.
//! * **Never panics, never allocates.** The hooks run inside the
//!   allocator; they use `Cell` state only and tolerate TLS teardown
//!   (`try_with`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of measurement windows currently open across all threads.
/// The fast path: when zero (the overwhelmingly common case) the
/// allocator hooks return after a single relaxed load.
static WINDOWS_OPEN: AtomicUsize = AtomicUsize::new(0);

/// Per-thread accounting window. `live` saturates (a forged length that
/// overflows usize must clamp, not wrap into a small peak).
#[derive(Clone, Copy)]
struct Window {
    active: bool,
    live: usize,
    peak: usize,
}

thread_local! {
    static WINDOW: Cell<Window> = const {
        Cell::new(Window { active: false, live: 0, peak: 0 })
    };
}

#[inline]
fn charge(n: usize) {
    if WINDOWS_OPEN.load(Ordering::Relaxed) == 0 {
        return;
    }
    // try_with: during TLS teardown the slot may be gone — skip rather
    // than abort (the allocator must never panic).
    let _ = WINDOW.try_with(|w| {
        let mut win = w.get();
        if win.active {
            win.live = win.live.saturating_add(n);
            win.peak = win.peak.max(win.live);
            w.set(win);
        }
    });
}

#[inline]
fn release(n: usize) {
    if WINDOWS_OPEN.load(Ordering::Relaxed) == 0 {
        return;
    }
    let _ = WINDOW.try_with(|w| {
        let mut win = w.get();
        if win.active {
            win.live = win.live.saturating_sub(n);
            w.set(win);
        }
    });
}

/// [`System`] allocator wrapped with per-thread live/peak accounting.
/// Installed crate-wide (see the `#[global_allocator]` below) so fuzz
/// targets measure real decoder allocations, not estimates.
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the accounting hooks touch only
// `Cell`/atomic state and never allocate or unwind.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        charge(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        charge(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        release(layout.size());
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size >= layout.size() {
            charge(new_size - layout.size());
        } else {
            release(layout.size() - new_size);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` in a fresh measurement window on this thread and return its
/// result plus the **peak live bytes** allocated (by this thread) while
/// it ran. Windows do not nest — `measure` inside `f` would reset the
/// accounting; the fuzz driver never does this.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, usize) {
    WINDOW.with(|w| {
        w.set(Window {
            active: true,
            live: 0,
            peak: 0,
        })
    });
    WINDOWS_OPEN.fetch_add(1, Ordering::Relaxed);
    let result = f();
    WINDOWS_OPEN.fetch_sub(1, Ordering::Relaxed);
    let peak = WINDOW.with(|w| {
        let win = w.get();
        w.set(Window {
            active: false,
            ..win
        });
        win.peak
    });
    (result, peak)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_peak_not_total() {
        // Two sequential 64 KiB buffers: total allocated is ~128 KiB but
        // the peak live is ~64 KiB because the first is dropped before
        // the second exists.
        let (_, peak) = measure(|| {
            let a = vec![1u8; 64 * 1024];
            drop(a);
            let b = vec![2u8; 64 * 1024];
            drop(b);
        });
        assert!(peak >= 64 * 1024, "peak {peak} misses the buffers");
        assert!(peak < 120 * 1024, "peak {peak} double-counts sequential buffers");
    }

    #[test]
    fn concurrent_buffers_accumulate() {
        let (_, peak) = measure(|| {
            let a = vec![1u8; 32 * 1024];
            let b = vec![2u8; 32 * 1024];
            (a.len(), b.len())
        });
        assert!(peak >= 64 * 1024, "peak {peak} misses concurrent buffers");
    }

    #[test]
    fn other_threads_do_not_pollute_the_window() {
        let (_, peak) = measure(|| {
            std::thread::spawn(|| {
                let big = vec![0u8; 4 * 1024 * 1024];
                big.len()
            })
            .join()
            .unwrap()
        });
        // The 4 MiB belongs to the spawned thread, not our window.
        assert!(peak < 1024 * 1024, "foreign thread charged to window: {peak}");
    }

    #[test]
    fn windows_reset_between_measurements() {
        let (_, first) = measure(|| vec![0u8; 16 * 1024].len());
        let (_, second) = measure(|| 0usize);
        assert!(first >= 16 * 1024);
        assert!(second < 4096, "second window inherited {second} bytes");
    }
}
