//! The standalone splitter worker: `drf worker --shard DIR --addr A:P`.
//!
//! A worker is a shard pack brought to life: it loads (and, by default,
//! checksums) the pack written by `drf shard`, opens the columns
//! through the existing [`ColumnStore`] backends — streaming from
//! disk, zero-copy memory-mapped with `--preload`, or fetched over the
//! wire from a `drf objstore` with `--object-store HOST:PORT`
//! ([`load_shard_remote`]: the worker never downloads the pack, it
//! range-reads it chunk by chunk) — and serves the splitter wire
//! protocol on a TCP listener.
//!
//! `--preload` serves the pack through [`MmapStore`]: the presorted
//! DRFC v2 files are mapped once and every training scan borrows chunk
//! slices straight from the mapping (no syscalls, no copies after the
//! first-touch pass; on non-unix the store falls back to one buffered
//! whole-file read, which is the old materialize-into-RAM behavior).
//! Manifest checksum verification still runs unless `--no-verify` is
//! given — for a preloaded pack it runs against the **mapped bytes**
//! training will actually scan (also warming the page cache), so
//! `--preload` never weakens integrity checking; `--no-verify` skips
//! the checksums in both modes but header/truncation validation at
//! open always happens. It starts with **no training
//! configuration**: the leader's Hello handshake carries the seed,
//! bagging/sampling modes, and scorer, and the worker builds its
//! [`SplitterCore`] from them (validating that the pack's topology
//! matches what the leader is training). A worker that is killed and
//! restarted comes back empty; the leader's recovery layer replays the
//! level-update log to rebuild its per-tree state.

use super::manifest::{checksum_bytes, checksum_file, ShardManifest};
use crate::config::PruneMode;
use crate::coordinator::splitter::{SplitterConfig, SplitterCore};
use crate::coordinator::tcp::{handle_request, hello_info_for};
use crate::coordinator::wire::{
    decode_request_traced, encode_response, read_frame, write_frame, HelloConfig, HelloInfo,
    Request, Response, PROTOCOL_VERSION,
};
use crate::data::disk::{self, ColumnReader};
use crate::data::io_stats::IoStats;
use crate::data::mmap::MmapStore;
use crate::data::remote::{RemoteClient, RemoteColumnSpec, RemoteOptions, RemoteStore};
use crate::data::store::{ColumnFiles, ColumnStore, DiskStore};
use crate::rng::{Bagger, BaggingMode, FeatureSampling};
use crate::splits::scorer::ScoreKind;
use crate::Result;
use anyhow::{ensure, Context};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// How a worker loads and serves its shard pack.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Concurrent column scans inside the splitter (wall clock only).
    pub scan_threads: usize,
    /// Serve the pack zero-copy through [`MmapStore`] instead of
    /// streaming every pass from disk (see module docs for the
    /// interaction with `verify`).
    pub preload: bool,
    /// Checksum every file against the manifest before serving. With
    /// `preload` the checksums run over the mapped bytes.
    pub verify: bool,
    /// Streaming-mode disk-scan prefetch depth (chunks a background
    /// reader may run ahead; 0 = synchronous; ignored with `preload`).
    pub prefetch_chunks: usize,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        Self {
            scan_threads: 1,
            preload: false,
            verify: true,
            prefetch_chunks: 0,
        }
    }
}

/// A shard pack opened and ready to serve.
pub struct LoadedShard {
    pub manifest: ShardManifest,
    pub storage: Arc<dyn ColumnStore>,
    pub labels: Arc<Vec<u32>>,
    /// Disk I/O counters of this worker (header validation, loading,
    /// and every subsequent training scan).
    pub stats: IoStats,
}

/// Open (and optionally verify) the shard pack in `dir`.
pub fn load_shard(dir: &std::path::Path, opts: &WorkerOptions) -> Result<LoadedShard> {
    let manifest = ShardManifest::load(dir)?;
    // The label column is always materialized (it is replicated per
    // splitter and read constantly); checksum it from the file either
    // way.
    if opts.verify {
        let lc = checksum_file(&dir.join(&manifest.labels_file))?;
        ensure!(
            lc == manifest.labels_checksum,
            "label column {} failed its checksum",
            manifest.labels_file
        );
    }

    let stats = IoStats::new();
    let labels = ColumnReader::open(&dir.join(&manifest.labels_file), stats.clone())?
        .read_all_u32()?;
    ensure!(
        labels.len() == manifest.rows,
        "label column has {} rows, manifest declares {}",
        labels.len(),
        manifest.rows
    );

    let mut files = BTreeMap::new();
    for c in &manifest.columns {
        let spec = manifest
            .schema
            .columns
            .get(c.index)
            .with_context(|| format!("column {} is not in the schema", c.index))?;
        ensure!(
            c.sorted_file.is_some() == spec.ctype.is_numerical(),
            "column {}: presorted file presence does not match its type",
            c.index
        );
        files.insert(
            c.index,
            ColumnFiles {
                raw: dir.join(&c.file),
                sorted: c.sorted_file.as_ref().map(|s| dir.join(s)),
                ctype: spec.ctype,
            },
        );
    }

    let storage: Arc<dyn ColumnStore> = if opts.preload {
        // Zero-copy: map the pack once; every scan borrows from the
        // mapping (the presorted views come from the pack — nothing is
        // re-sorted, nothing is copied). Checksums run over the mapped
        // bytes — the exact bytes training will scan — which also
        // faults the pages in up front.
        let m = MmapStore::open(files, stats.clone())?;
        if opts.verify {
            verify_columns(&manifest, |c, sorted| {
                Ok(checksum_bytes(if sorted {
                    m.sorted_file_bytes(c.index)?
                        .expect("presorted mapping exists (validated above)")
                } else {
                    m.raw_file_bytes(c.index)?
                }))
            })?;
        }
        Arc::new(m)
    } else {
        if opts.verify {
            verify_columns(&manifest, |c, sorted| {
                checksum_file(&dir.join(if sorted {
                    c.sorted_file.as_ref().expect("sorted=true only for Some")
                } else {
                    &c.file
                }))
            })?;
        }
        Arc::new(DiskStore::open(files, stats.clone())?.with_prefetch(opts.prefetch_chunks))
    };

    Ok(LoadedShard {
        manifest,
        storage,
        labels: Arc::new(labels),
        stats,
    })
}

/// Open a shard pack the worker never downloaded: the manifest, the
/// label column, and every training scan come from the `drf objstore`
/// at `addr`, where the pack lives under `prefix` (e.g. `shard_0` when
/// the objstore serves a whole `drf shard` output tree; empty when it
/// serves one pack directly). Integrity still holds end to end:
///
/// * the manifest is fetched and parsed like a local one;
/// * the label column is fetched in full and (with `opts.verify`)
///   checked against the manifest checksum before it is decoded;
/// * column files keep their manifest checksums **armed inside the
///   store**: every complete training pass re-folds the fetched bytes
///   through the same FNV-1a and refuses a mismatch — remote
///   corruption cannot silently train, even though the worker never
///   holds a whole file.
///
/// `--preload` is refused (there is nothing local to map); transient
/// fetch failures retry with bounded backoff and resume at the chunk
/// boundary they had reached (see [`crate::data::remote`]).
pub fn load_shard_remote(addr: &str, prefix: &str, opts: &WorkerOptions) -> Result<LoadedShard> {
    ensure!(
        !opts.preload,
        "--preload needs a local shard pack; remote packs stream by range reads"
    );
    let join = |f: &str| {
        if prefix.is_empty() {
            f.to_string()
        } else {
            format!("{prefix}/{f}")
        }
    };
    let stats = IoStats::new();
    let client = RemoteClient::new(addr, RemoteOptions::default(), stats.clone());
    let mut sess = client.session();

    let mbytes = sess.fetch_all(&join(ShardManifest::FILE))?;
    let manifest = ShardManifest::from_json(&crate::util::Json::parse(
        std::str::from_utf8(&mbytes).context("remote manifest is not UTF-8")?,
    )?)
    .with_context(|| format!("parsing remote manifest {}", join(ShardManifest::FILE)))?;

    // The label column is always materialized (it is replicated per
    // splitter and read constantly): fetch it whole, verify, decode.
    let lbytes = sess.fetch_all(&join(&manifest.labels_file))?;
    if opts.verify {
        ensure!(
            checksum_bytes(&lbytes) == manifest.labels_checksum,
            "label column {} failed its checksum",
            manifest.labels_file
        );
    }
    let lheader = disk::Header::parse(&lbytes)
        .with_context(|| format!("parsing remote label column {}", manifest.labels_file))?;
    ensure!(
        lheader.kind == disk::FileKind::Categorical,
        "label file holds {:?} records",
        lheader.kind
    );
    lheader.ensure_untruncated(
        lbytes.len() as u64,
        std::path::Path::new(&manifest.labels_file),
    )?;
    let mut labels = Vec::new();
    let payload = lheader.nbytes() as usize;
    disk::decode_u32(&lbytes[payload..payload + lheader.rows as usize * 4], &mut labels);
    ensure!(
        labels.len() == manifest.rows,
        "label column has {} rows, manifest declares {}",
        labels.len(),
        manifest.rows
    );
    stats.add_disk_read(lbytes.len() as u64);
    stats.add_read_pass();

    let specs = manifest
        .columns
        .iter()
        .map(|c| {
            let spec = manifest
                .schema
                .columns
                .get(c.index)
                .with_context(|| format!("column {} is not in the schema", c.index))?;
            ensure!(
                c.sorted_file.is_some() == spec.ctype.is_numerical(),
                "column {}: presorted file presence does not match its type",
                c.index
            );
            Ok(RemoteColumnSpec {
                index: c.index,
                raw: join(&c.file),
                sorted: c.sorted_file.as_deref().map(&join),
                ctype: spec.ctype,
                raw_checksum: opts.verify.then_some(c.checksum),
                sorted_checksum: if opts.verify { c.sorted_checksum } else { None },
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let storage: Arc<dyn ColumnStore> = Arc::new(
        RemoteStore::open(client, specs, stats.clone())?.with_prefetch(opts.prefetch_chunks),
    );

    Ok(LoadedShard {
        manifest,
        storage,
        labels: Arc::new(labels),
        stats,
    })
}

/// Where a worker's pack came from — retained by the server so a
/// re-handshake carrying a *newer* topology version can reload the
/// (possibly re-cut) pack instead of serving stale columns. An elastic
/// re-shard (`drf supervise --drain`) rewrites shard manifests on disk
/// (or on the objstore) and bumps the cluster manifest version; the
/// leader's next Hello carries the new version and the worker re-reads
/// its source before answering.
#[derive(Debug, Clone)]
pub enum ShardSource {
    /// Local pack directory (`drf worker --shard DIR`).
    Dir(std::path::PathBuf),
    /// Remote pack on an objstore replica set
    /// (`--object-store ADDR[,ADDR...]` + the pack's prefix).
    Remote { addr: String, prefix: String },
}

impl ShardSource {
    /// (Re)load the pack from this source.
    pub fn load(&self, opts: &WorkerOptions) -> Result<LoadedShard> {
        match self {
            ShardSource::Dir(dir) => load_shard(dir, opts),
            ShardSource::Remote { addr, prefix } => load_shard_remote(addr, prefix, opts),
        }
    }
}

/// Check every column of `manifest` against its recorded checksums.
/// `checksum_of(column, sorted)` produces the hash of the raw
/// (`sorted = false`) or presorted (`sorted = true`, only called when
/// the column has one) file — from disk for the streaming store, or
/// from the mapped bytes for the preloaded one. (The remote shard
/// source does not use this eager check: [`load_shard_remote`] arms
/// the manifest checksums inside the store, which re-verifies every
/// complete pass.)
fn verify_columns(
    manifest: &ShardManifest,
    mut checksum_of: impl FnMut(&super::manifest::ShardColumn, bool) -> Result<u64>,
) -> Result<()> {
    for c in &manifest.columns {
        ensure!(
            checksum_of(c, false)? == c.checksum,
            "column {} file {} failed its checksum",
            c.index,
            c.file
        );
        if let (Some(sf), Some(sc)) = (&c.sorted_file, c.sorted_checksum) {
            ensure!(
                checksum_of(c, true)? == sc,
                "column {} presorted file {sf} failed its checksum",
                c.index
            );
        }
    }
    Ok(())
}

/// Shared worker state: the loaded pack plus the splitter core the
/// leader's Hello configures (all connections see the same core, so a
/// reconnect does not wipe per-tree state).
struct WorkerState {
    /// The pack being served. Swapped wholesale when a re-handshake
    /// with a newer topology version reloads from `source`.
    shard: Mutex<Arc<LoadedShard>>,
    /// Where the pack came from (reload seam); `None` for callers that
    /// handed over a [`LoadedShard`] with no way back to its origin.
    source: Option<(ShardSource, WorkerOptions)>,
    scan_threads: usize,
    core: Mutex<Option<(HelloConfig, Arc<SplitterCore>)>>,
}

impl WorkerState {
    fn shard(&self) -> Arc<LoadedShard> {
        self.shard.lock().unwrap().clone()
    }

    /// Handle the Hello handshake: validate identity/topology, build
    /// (or keep) the splitter core, report the inventory. A Hello with
    /// a *newer* topology version than the one currently served
    /// reloads the pack from its source (an elastic re-shard may have
    /// re-cut it); a Hello with an *older* version is refused — a
    /// stale leader must not drive a re-sharded fleet.
    fn configure(&self, h: &HelloConfig) -> Result<HelloInfo> {
        ensure!(
            h.protocol == PROTOCOL_VERSION,
            "protocol mismatch: leader speaks v{}, this worker v{PROTOCOL_VERSION}",
            h.protocol
        );
        let mut guard = self.core.lock().unwrap();
        if let Some((cfg, _)) = guard.as_ref() {
            ensure!(
                h.topology_version >= cfg.topology_version,
                "stale topology: leader trains topology v{}, this worker already serves v{}",
                h.topology_version,
                cfg.topology_version
            );
            if h.topology_version > cfg.topology_version {
                if let Some((source, opts)) = &self.source {
                    let fresh = source.load(opts).with_context(|| {
                        format!(
                            "reloading shard pack for topology v{}",
                            h.topology_version
                        )
                    })?;
                    *self.shard.lock().unwrap() = Arc::new(fresh);
                    crate::telemetry::counter("drf_worker_reshards_total").inc();
                }
            }
        }
        let shard = self.shard();
        let m = &shard.manifest;
        ensure!(
            h.shard as usize == m.shard,
            "shard mismatch: leader expects shard {}, this pack is shard {}",
            h.shard,
            m.shard
        );
        ensure!(
            h.num_splitters as usize == m.num_splitters
                && h.redundancy as usize == m.redundancy,
            "topology mismatch: leader trains {} splitters x redundancy {}, \
             pack was cut for {} x {}",
            h.num_splitters,
            h.redundancy,
            m.num_splitters,
            m.redundancy
        );

        let rebuild = match guard.as_ref() {
            Some((cfg, _)) => cfg != h,
            None => true,
        };
        if rebuild {
            let scfg = SplitterConfig {
                seed: h.seed,
                bagger: Bagger::new(h.seed, BaggingMode::parse(&h.bagging)?),
                feature_sampling: FeatureSampling::parse(&h.sampling)?,
                num_candidates: h.num_candidates as usize,
                score_kind: ScoreKind::parse(&h.score_kind)?,
                prune: match h.prune_threshold {
                    None => PruneMode::Never,
                    Some(threshold) => PruneMode::Adaptive { threshold },
                },
                scan_threads: self.scan_threads,
                split_search: crate::config::SplitSearch::parse(&h.split_search)?,
            };
            let core = SplitterCore::new(
                m.shard,
                m.schema.clone(),
                shard.storage.clone(),
                shard.labels.clone(),
                scfg,
                shard.stats.clone(),
            );
            *guard = Some((h.clone(), Arc::new(core)));
        }
        Ok(hello_info_for(&guard.as_ref().unwrap().1))
    }

    fn core(&self) -> Option<Arc<SplitterCore>> {
        self.core.lock().unwrap().as_ref().map(|(_, c)| c.clone())
    }
}

/// A running worker: the TCP listener serving one shard pack. Dropping
/// it stops accepting new connections.
pub struct WorkerServer {
    addr: std::net::SocketAddr,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl WorkerServer {
    /// Bind `addr` (`host:0` picks an ephemeral port — see
    /// [`WorkerServer::addr`]) and serve the shard. With no retained
    /// [`ShardSource`], a re-handshake carrying a newer topology
    /// version is accepted but cannot reload the pack — use
    /// [`WorkerServer::spawn_with_source`] for deployment workers.
    pub fn spawn(shard: LoadedShard, addr: &str, scan_threads: usize) -> Result<WorkerServer> {
        Self::spawn_with_source(shard, None, addr, scan_threads)
    }

    /// [`WorkerServer::spawn`] plus the pack's origin, so an elastic
    /// re-shard (newer topology version in the Hello) reloads the
    /// re-cut pack before answering.
    pub fn spawn_with_source(
        shard: LoadedShard,
        source: Option<(ShardSource, WorkerOptions)>,
        addr: &str,
        scan_threads: usize,
    ) -> Result<WorkerServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding worker to {addr}"))?;
        let addr = listener.local_addr()?;
        let shard_id = shard.manifest.shard;
        let state = Arc::new(WorkerState {
            shard: Mutex::new(Arc::new(shard)),
            source,
            scan_threads,
            core: Mutex::new(None),
        });
        // The pack's IoStats is shared with every store scan; mirror it
        // into the registry so `--metrics-addr` scrapes see the
        // worker's disk/net totals move mid-train. Resolved through the
        // state at scrape time so a reloaded pack keeps reporting.
        let gauge_state = state.clone();
        crate::telemetry::register_io_gauges_with("drf_worker_io", move || {
            gauge_state.shard().stats.clone()
        });
        crate::telemetry::gauge("drf_worker_shard").set(shard_id as u64);
        let shutdown = Arc::new(AtomicBool::new(false));
        let shutdown2 = shutdown.clone();
        let accept_handle = std::thread::Builder::new()
            .name(format!("drf-worker-{shard_id}"))
            .spawn(move || {
                for conn in listener.incoming() {
                    if shutdown2.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => {
                            // Transient accept failures (ECONNABORTED,
                            // fd pressure) must not take down a
                            // deployment worker's listener for good.
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            continue;
                        }
                    };
                    let state = state.clone();
                    let _ = std::thread::Builder::new()
                        .name("drf-worker-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(&state, stream);
                        });
                }
            })?;
        Ok(WorkerServer {
            addr,
            accept_handle: Some(accept_handle),
            shutdown,
        })
    }

    /// The actually bound address (resolves `:0` bindings).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Poke the listener so the accept loop wakes and exits.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// One connection's request loop.
fn serve_connection(state: &WorkerState, stream: TcpStream) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        let response = match decode_request_traced(&frame) {
            Err(e) => Response::Err(format!("bad request: {e}")),
            Ok((Request::Shutdown, _)) => {
                write_frame(&mut writer, &encode_response(&Response::Ok))?;
                return Ok(());
            }
            Ok((Request::Hello(h), _)) => match state.configure(&h) {
                Ok(info) => Response::Hello(info),
                Err(e) => Response::Err(format!("{e:#}")),
            },
            // TimeSync is answered pre-handshake (the leader syncs
            // clocks right after Hello, but a probe must also work).
            Ok((Request::TimeSync, _)) => {
                Response::TimeSync(crate::telemetry::time_sync_reply())
            }
            Ok((req, ctx)) => match state.core() {
                None => Response::Err("no handshake: send Hello before other requests".into()),
                Some(core) => {
                    // Serve under the leader's span so this worker's
                    // spans (find_splits, materialize, …) parent into
                    // the leader's round in the merged trace.
                    let _trace = crate::telemetry::adopt_remote_context(ctx.as_ref());
                    handle_request(&core, req)
                }
            },
        };
        write_frame(&mut writer, &encode_response(&response))?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::shard::{write_shards, ShardOptions};
    use crate::config::TopologyParams;
    use crate::coordinator::wire::{decode_response, encode_request};
    use crate::data::synthetic::{Family, SyntheticSpec};

    fn shard_a_dataset(dir: &std::path::Path, splitters: usize) -> crate::data::Dataset {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 200, 6, 11).generate();
        write_shards(
            &ds,
            &TopologyParams {
                num_splitters: Some(splitters),
                ..Default::default()
            },
            dir,
            &ShardOptions {
                chunk_rows: 48,
                ..Default::default()
            },
            IoStats::new(),
        )
        .unwrap();
        ds
    }

    fn hello(shard: u32, splitters: u32) -> HelloConfig {
        HelloConfig {
            protocol: PROTOCOL_VERSION,
            shard,
            num_splitters: splitters,
            redundancy: 1,
            seed: 9,
            bagging: "poisson".into(),
            sampling: "per_node".into(),
            num_candidates: 3,
            score_kind: "gini".into(),
            prune_threshold: None,
            split_search: "exact".into(),
            depth_next_rows: 0,
            topology_version: 0,
        }
    }

    fn roundtrip(stream: &TcpStream, req: &Request) -> Response {
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        write_frame(&mut w, &encode_request(req)).unwrap();
        decode_response(&read_frame(&mut r).unwrap()).unwrap()
    }

    #[test]
    fn worker_serves_after_handshake_only() {
        let dir = crate::util::tempdir().unwrap();
        let ds = shard_a_dataset(dir.path(), 2);
        let shard = load_shard(&dir.path().join("shard_0"), &WorkerOptions::default()).unwrap();
        let server = WorkerServer::spawn(shard, "127.0.0.1:0", 1).unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();

        // Before Hello: refused.
        match roundtrip(&stream, &Request::StartTree(0)) {
            Response::Err(msg) => assert!(msg.contains("no handshake"), "{msg}"),
            r => panic!("expected Err, got {r:?}"),
        }
        // Wrong shard id: refused.
        match roundtrip(&stream, &Request::Hello(hello(1, 2))) {
            Response::Err(msg) => assert!(msg.contains("shard mismatch"), "{msg}"),
            r => panic!("expected Err, got {r:?}"),
        }
        // Wrong topology: refused.
        match roundtrip(&stream, &Request::Hello(hello(0, 3))) {
            Response::Err(msg) => assert!(msg.contains("topology mismatch"), "{msg}"),
            r => panic!("expected Err, got {r:?}"),
        }
        // Correct Hello: inventory comes back.
        match roundtrip(&stream, &Request::Hello(hello(0, 2))) {
            Response::Hello(info) => {
                assert_eq!(info.shard, 0);
                assert_eq!(info.rows, 200);
                assert_eq!(info.num_classes, ds.num_classes());
                let cols: Vec<usize> = info.columns.iter().map(|&c| c as usize).collect();
                assert_eq!(cols, vec![0, 2, 4], "round-robin shard 0 of 2");
            }
            r => panic!("expected Hello, got {r:?}"),
        }
        // Now real RPCs flow and root stats match the dataset's bagged
        // histogram (computable locally because bagging is seeded).
        match roundtrip(&stream, &Request::StartTree(0)) {
            Response::Ok => {}
            r => panic!("expected Ok, got {r:?}"),
        }
        match roundtrip(&stream, &Request::RootStats(0)) {
            Response::RootStats(v) => assert_eq!(v.len(), ds.num_classes() as usize),
            r => panic!("expected RootStats, got {r:?}"),
        }
    }

    #[test]
    fn rehandshake_reloads_newer_topology_and_refuses_stale() {
        let dir = crate::util::tempdir().unwrap();
        shard_a_dataset(dir.path(), 2);
        let sdir = dir.path().join("shard_0");
        let shard = load_shard(&sdir, &WorkerOptions::default()).unwrap();
        let server = WorkerServer::spawn_with_source(
            shard,
            Some((ShardSource::Dir(sdir.clone()), WorkerOptions::default())),
            "127.0.0.1:0",
            1,
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();

        let h0 = hello(0, 2);
        match roundtrip(&stream, &Request::Hello(h0.clone())) {
            Response::Hello(info) => {
                let cols: Vec<usize> = info.columns.iter().map(|&c| c as usize).collect();
                assert_eq!(cols, vec![0, 2, 4]);
            }
            r => panic!("expected Hello, got {r:?}"),
        }

        // An elastic drain re-cuts shard 0 to nothing and bumps the
        // cluster version; a Hello carrying the newer version makes
        // the worker reload its pack before answering.
        crate::cluster::supervise::drain_worker(dir.path(), 0).unwrap();
        let mut h1 = hello(0, 2);
        h1.topology_version = 1;
        match roundtrip(&stream, &Request::Hello(h1)) {
            Response::Hello(info) => {
                assert!(info.columns.is_empty(), "re-cut pack is empty: {info:?}")
            }
            r => panic!("expected Hello, got {r:?}"),
        }

        // A stale leader (older topology version) must be refused — it
        // would train against columns this worker no longer serves.
        match roundtrip(&stream, &Request::Hello(h0)) {
            Response::Err(msg) => assert!(msg.contains("stale topology"), "{msg}"),
            r => panic!("expected Err, got {r:?}"),
        }
    }

    #[test]
    fn preloaded_worker_matches_streaming() {
        let dir = crate::util::tempdir().unwrap();
        shard_a_dataset(dir.path(), 2);
        let sdir = dir.path().join("shard_1");
        let streaming = load_shard(&sdir, &WorkerOptions::default()).unwrap();
        let preloaded = load_shard(
            &sdir,
            &WorkerOptions {
                preload: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(streaming.storage.columns(), preloaded.storage.columns());
        for j in streaming.storage.columns() {
            assert_eq!(
                streaming.storage.read_raw(j).unwrap(),
                preloaded.storage.read_raw(j).unwrap(),
                "column {j}"
            );
        }
        assert_eq!(streaming.labels, preloaded.labels);
    }

    #[test]
    fn remote_shard_matches_local() {
        use crate::data::objserve::{ObjStoreOptions, ObjStoreServer};

        let dir = crate::util::tempdir().unwrap();
        shard_a_dataset(dir.path(), 2);
        // One objstore serves the whole shard tree; each worker loads
        // its pack under its `shard_<i>` prefix, downloading nothing
        // but the manifest and the labels.
        let server = ObjStoreServer::spawn(
            dir.path(),
            "127.0.0.1:0",
            IoStats::new(),
            ObjStoreOptions::default(),
        )
        .unwrap();
        let addr = server.addr().to_string();
        let local = load_shard(&dir.path().join("shard_0"), &WorkerOptions::default()).unwrap();
        let remote = load_shard_remote(&addr, "shard_0", &WorkerOptions::default()).unwrap();
        assert_eq!(local.manifest, remote.manifest);
        assert_eq!(local.labels, remote.labels);
        assert_eq!(local.storage.columns(), remote.storage.columns());
        for j in local.storage.columns() {
            assert_eq!(
                local.storage.read_raw(j).unwrap(),
                remote.storage.read_raw(j).unwrap(),
                "column {j}"
            );
        }
        // Preload is meaningless without local files.
        let err = load_shard_remote(
            &addr,
            "shard_0",
            &WorkerOptions {
                preload: true,
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("preload"), "{err:#}");

        // Corrupt one column file server-side: the load still succeeds
        // (columns stream lazily), but the first complete pass over
        // that column refuses the checksum.
        let m = ShardManifest::load(&dir.path().join("shard_0")).unwrap();
        let target = dir.path().join("shard_0").join(&m.columns[0].file);
        let mut bytes = std::fs::read(&target).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&target, &bytes).unwrap();
        let tampered = load_shard_remote(&addr, "shard_0", &WorkerOptions::default()).unwrap();
        let j = m.columns[0].index;
        let err = tampered.storage.read_raw(j).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        // --no-verify disarms the checksums (header validation stays).
        let unverified = load_shard_remote(
            &addr,
            "shard_0",
            &WorkerOptions {
                verify: false,
                ..Default::default()
            },
        )
        .unwrap();
        unverified.storage.read_raw(j).unwrap();
    }

    #[test]
    fn corrupt_pack_refused() {
        let dir = crate::util::tempdir().unwrap();
        shard_a_dataset(dir.path(), 2);
        let sdir = dir.path().join("shard_0");
        // Flip one payload byte in a column file.
        let m = ShardManifest::load(&sdir).unwrap();
        let target = sdir.join(&m.columns[0].file);
        let mut bytes = std::fs::read(&target).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&target, &bytes).unwrap();
        let err = load_shard(&sdir, &WorkerOptions::default()).unwrap_err();
        assert!(
            format!("{err:#}").contains("checksum"),
            "unexpected error: {err:#}"
        );
        // The preloaded (mmap) path verifies against the mapped bytes
        // and must catch the same corruption.
        let err = load_shard(
            &sdir,
            &WorkerOptions {
                preload: true,
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("checksum"),
            "mapped-byte verification missed the corruption: {err:#}"
        );
        // --no-verify skips the check and still opens (header intact),
        // in both modes.
        for preload in [false, true] {
            load_shard(
                &sdir,
                &WorkerOptions {
                    preload,
                    verify: false,
                    ..Default::default()
                },
            )
            .unwrap();
        }
    }
}
