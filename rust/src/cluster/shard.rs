//! The shard writer: `drf shard` partitions a dataset by the
//! [`Topology`] ownership map into per-splitter shard packs.
//!
//! One pack per splitter, each a directory of chunk-tabled DRFC v2
//! column files (raw + presorted for numerical columns), the replicated
//! label column, and a [`ShardManifest`]. This is the paper's
//! dataset-preparation phase (§2.1) made deployable: prepare and
//! presort once, then hand each directory to a `drf worker` on any
//! machine — workers never re-sort and never see columns they don't
//! own.

use super::manifest::{checksum_file, ClusterManifest, ShardColumn, ShardEntry, ShardManifest};
use crate::config::TopologyParams;
use crate::coordinator::topology::Topology;
use crate::data::disk::{self, Layout};
use crate::data::io_stats::IoStats;
use crate::data::{Column, Dataset};
use crate::Result;
use std::path::Path;

/// Knobs of the shard writer.
#[derive(Debug, Clone)]
pub struct ShardOptions {
    /// Records per DRFC v2 chunk in the written column files.
    pub chunk_rows: u32,
    /// Worker addresses to record in the cluster manifest (one per
    /// shard, in shard order); empty = fill in at deploy time.
    pub workers: Vec<String>,
    /// Number of complete pack copies to emit (`--replicas N`). The
    /// canonical tree lands under `out_dir` as always; each extra
    /// replica is a byte-identical copy under `out_dir/replica_<r>`,
    /// ready to hand to its own `drf objstore` so remote-pack workers
    /// can fail over between stores serving the same bytes. 1 (the
    /// default) writes no copies.
    pub replicas: usize,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            chunk_rows: disk::DEFAULT_CHUNK_ROWS as u32,
            workers: Vec::new(),
            replicas: 1,
        }
    }
}

/// Cut `ds` into shard packs under `out_dir` (one `shard_<s>/` per
/// splitter plus `cluster.json`) and return the cluster manifest.
pub fn write_shards(
    ds: &Dataset,
    params: &TopologyParams,
    out_dir: &Path,
    opts: &ShardOptions,
    stats: IoStats,
) -> Result<ClusterManifest> {
    let topo = Topology::new(ds.num_features(), params);
    anyhow::ensure!(
        opts.workers.is_empty() || opts.workers.len() == topo.num_splitters(),
        "{} worker addresses for {} shards",
        opts.workers.len(),
        topo.num_splitters()
    );
    std::fs::create_dir_all(out_dir)?;
    let layout = Layout::V2 {
        chunk_rows: opts.chunk_rows,
    };

    let mut shards = Vec::with_capacity(topo.num_splitters());
    for s in 0..topo.num_splitters() {
        let dir_name = format!("shard_{s}");
        let dir = out_dir.join(&dir_name);
        std::fs::create_dir_all(&dir)?;

        // The label column is replicated on every splitter (§2.1).
        let labels_file = "labels.drfc".to_string();
        disk::write_categorical_with(&dir.join(&labels_file), ds.labels(), layout, stats.clone())?;
        let labels_checksum = checksum_file(&dir.join(&labels_file))?;

        let owned = topo.columns_of(s);
        let mut columns = Vec::with_capacity(owned.len());
        for &j in &owned {
            let file = format!("col_{j}.drfc");
            let raw = dir.join(&file);
            let (sorted_file, sorted_checksum) = match ds.column(j) {
                Column::Numerical(vals) => {
                    disk::write_numerical_with(&raw, vals, layout, stats.clone())?;
                    let sf = format!("col_{j}.sorted.drfc");
                    disk::write_sorted_with(
                        &dir.join(&sf),
                        &ds.column(j).presort(),
                        layout,
                        stats.clone(),
                    )?;
                    let sc = checksum_file(&dir.join(&sf))?;
                    (Some(sf), Some(sc))
                }
                Column::Categorical { values, .. } => {
                    disk::write_categorical_with(&raw, values, layout, stats.clone())?;
                    (None, None)
                }
            };
            columns.push(ShardColumn {
                index: j,
                checksum: checksum_file(&raw)?,
                file,
                sorted_file,
                sorted_checksum,
            });
        }

        ShardManifest {
            shard: s,
            num_splitters: topo.num_splitters(),
            redundancy: topo.redundancy(),
            rows: ds.num_rows(),
            schema: ds.schema().clone(),
            columns,
            labels_file,
            labels_checksum,
        }
        .save(&dir)?;
        shards.push(ShardEntry {
            shard: s,
            dir: dir_name,
            columns: owned,
        });
    }

    let cluster = ClusterManifest {
        num_splitters: topo.num_splitters(),
        redundancy: topo.redundancy(),
        rows: ds.num_rows(),
        num_features: ds.num_features(),
        num_classes: ds.num_classes(),
        shards,
        workers: opts.workers.clone(),
        version: 0,
        objstores: Vec::new(),
    };
    cluster.save(&out_dir.join(ClusterManifest::FILE))?;

    // Replicated packs: byte-identical copies of the whole tree, one
    // per extra replica, each servable by its own objstore.
    for r in 1..opts.replicas.max(1) {
        let replica_root = out_dir.join(format!("replica_{r}"));
        for s in 0..topo.num_splitters() {
            copy_dir(
                &out_dir.join(format!("shard_{s}")),
                &replica_root.join(format!("shard_{s}")),
            )?;
        }
        std::fs::copy(
            out_dir.join(ClusterManifest::FILE),
            replica_root.join(ClusterManifest::FILE),
        )?;
    }
    Ok(cluster)
}

/// Copy every regular file of `src` into `dst` (one level deep — shard
/// pack directories are flat).
fn copy_dir(src: &Path, dst: &Path) -> Result<()> {
    std::fs::create_dir_all(dst)?;
    for entry in std::fs::read_dir(src)? {
        let entry = entry?;
        if entry.file_type()?.is_file() {
            std::fs::copy(entry.path(), dst.join(entry.file_name()))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::LeoLikeSpec;

    #[test]
    fn shards_cover_every_column_with_valid_checksums() {
        // Leo-like: mixed numerical + categorical columns.
        let ds = LeoLikeSpec::new(300, 5).generate();
        let dir = crate::util::tempdir().unwrap();
        let params = TopologyParams {
            num_splitters: Some(3),
            redundancy: 2,
            ..Default::default()
        };
        let cluster = write_shards(
            &ds,
            &params,
            dir.path(),
            &ShardOptions {
                chunk_rows: 64,
                ..Default::default()
            },
            IoStats::new(),
        )
        .unwrap();
        assert_eq!(cluster.num_splitters, 3);
        assert_eq!(cluster.rows, 300);
        cluster.topology().unwrap();

        // With redundancy 2 every column appears in exactly 2 shards.
        let mut owners = vec![0usize; ds.num_features()];
        for e in &cluster.shards {
            let m = ShardManifest::load(&dir.path().join(&e.dir)).unwrap();
            assert_eq!(m.shard, e.shard);
            assert_eq!(m.column_indices(), e.columns);
            assert_eq!(m.rows, 300);
            let shard_dir = dir.path().join(&e.dir);
            assert_eq!(
                checksum_file(&shard_dir.join(&m.labels_file)).unwrap(),
                m.labels_checksum
            );
            for c in &m.columns {
                owners[c.index] += 1;
                assert_eq!(
                    checksum_file(&shard_dir.join(&c.file)).unwrap(),
                    c.checksum,
                    "column {} checksum",
                    c.index
                );
                let numerical = ds.schema().columns[c.index].ctype.is_numerical();
                assert_eq!(c.sorted_file.is_some(), numerical);
                if let (Some(sf), Some(sc)) = (&c.sorted_file, c.sorted_checksum) {
                    assert_eq!(checksum_file(&shard_dir.join(sf)).unwrap(), sc);
                }
            }
        }
        assert!(owners.iter().all(|&n| n == 2), "redundancy 2: {owners:?}");

        // The cluster manifest reloads from disk.
        let back = ClusterManifest::load(&dir.path().join(ClusterManifest::FILE)).unwrap();
        assert_eq!(back, cluster);
    }

    #[test]
    fn replicated_packs_are_byte_identical() {
        let ds = LeoLikeSpec::new(120, 3).generate();
        let dir = crate::util::tempdir().unwrap();
        write_shards(
            &ds,
            &TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
            dir.path(),
            &ShardOptions {
                chunk_rows: 64,
                replicas: 2,
                ..Default::default()
            },
            IoStats::new(),
        )
        .unwrap();
        // The replica tree carries the same manifests and the same
        // checksummed bytes — a worker can load either one.
        let replica = dir.path().join("replica_1");
        let back = ClusterManifest::load(&replica.join(ClusterManifest::FILE)).unwrap();
        for e in &back.shards {
            let orig = ShardManifest::load(&dir.path().join(&e.dir)).unwrap();
            let copy = ShardManifest::load(&replica.join(&e.dir)).unwrap();
            assert_eq!(orig, copy);
            for c in &copy.columns {
                assert_eq!(
                    checksum_file(&replica.join(&e.dir).join(&c.file)).unwrap(),
                    c.checksum
                );
            }
        }
    }

    #[test]
    fn worker_count_mismatch_rejected() {
        let ds = LeoLikeSpec::new(50, 1).generate();
        let dir = crate::util::tempdir().unwrap();
        let err = write_shards(
            &ds,
            &TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
            dir.path(),
            &ShardOptions {
                workers: vec!["127.0.0.1:1".into()],
                ..Default::default()
            },
            IoStats::new(),
        );
        assert!(err.is_err());
    }
}
