//! Shard and cluster manifests: the JSON metadata that makes a shard
//! pack self-describing and lets a leader validate a worker fleet.
//!
//! `drf shard` writes one [`ShardManifest`] per shard directory (schema,
//! topology parameters, per-column file names + FNV-1a checksums) and a
//! top-level [`ClusterManifest`] (the ownership map plus, optionally,
//! the worker addresses a deployment filled in). A worker refuses to
//! serve a pack whose files fail their checksums or whose topology does
//! not match the leader's handshake; the leader refuses a fleet whose
//! inventory does not match the manifest. Checksums travel as 16-digit
//! hex strings — JSON numbers are f64 and cannot hold a full u64.

use crate::config::TopologyParams;
use crate::coordinator::topology::Topology;
use crate::coordinator::wire::PROTOCOL_VERSION;
use crate::data::schema::Schema;
use crate::data::store::{schema_from_json, schema_to_json};
use crate::util::Json;
use crate::Result;
use anyhow::{ensure, Context};
use std::io::Read;
use std::path::Path;

/// Format tag of a shard manifest (fail fast on foreign JSON).
pub const SHARD_FORMAT: &str = "drf-shard-v1";
/// Format tag of a cluster manifest.
pub const CLUSTER_FORMAT: &str = "drf-cluster-v1";

/// Streaming FNV-1a 64 of a file's bytes (constant memory).
pub fn checksum_file(path: &Path) -> Result<u64> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("checksumming {}", path.display()))?;
    let mut r = std::io::BufReader::with_capacity(1 << 16, f);
    let mut hash: u64 = FNV_OFFSET;
    let mut buf = [0u8; 1 << 16];
    loop {
        let n = r.read(&mut buf)?;
        if n == 0 {
            break;
        }
        hash = fnv_update(hash, &buf[..n]);
    }
    Ok(hash)
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn fnv_update(mut hash: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x1_0000_0000_01b3);
    }
    hash
}

/// FNV-1a over an in-memory byte slice — same hash as
/// [`checksum_file`]; used to verify a shard pack against the *mapped*
/// bytes an [`crate::data::MmapStore`]-backed worker will actually
/// scan (warming the pages on the way).
pub fn checksum_bytes(bytes: &[u8]) -> u64 {
    fnv_update(FNV_OFFSET, bytes)
}

/// Start value for an *incremental* FNV-1a checksum (see
/// [`checksum_update`]).
pub const CHECKSUM_INIT: u64 = FNV_OFFSET;

/// Fold `bytes` into a running FNV-1a hash. Because FNV-1a is a
/// byte-at-a-time stream,
/// `checksum_update(CHECKSUM_INIT, all_bytes)` equals folding the same
/// bytes in any chunking — this is how a
/// [`RemoteStore`](crate::data::remote::RemoteStore) pass verifies a
/// column it never holds in one piece against the manifest's
/// [`checksum_file`] value.
pub fn checksum_update(hash: u64, bytes: &[u8]) -> u64 {
    fnv_update(hash, bytes)
}

fn hex_u64(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn parse_hex_u64(v: &Json) -> Result<u64> {
    let s = v.as_str()?;
    // [`hex_u64`] always writes exactly 16 digits; a different width
    // means the manifest was hand-edited or corrupted, not merely
    // unpadded — reject rather than guess (fuzzer-found: short strings
    // parsed as truncated checksums and round-tripped differently).
    ensure!(
        s.len() == 16,
        "checksum '{s}' is {} chars, expected 16 hex digits",
        s.len()
    );
    Ok(u64::from_str_radix(s, 16)?)
}

/// One column of a shard pack.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardColumn {
    /// Global column index (the schema's numbering).
    pub index: usize,
    /// Raw column file, relative to the shard directory.
    pub file: String,
    pub checksum: u64,
    /// Presorted file (numerical columns only).
    pub sorted_file: Option<String>,
    pub sorted_checksum: Option<u64>,
}

/// The self-describing metadata of one shard pack (`manifest.json`
/// inside the shard directory).
///
/// # Examples
///
/// Shard a small dataset and read back one pack's manifest — the
/// checksums it records are what local workers verify at load time and
/// what a remote ([`crate::data::remote::RemoteStore`]-backed) worker
/// re-folds on every complete training pass:
///
/// ```
/// use drf::cluster::manifest::checksum_file;
/// use drf::cluster::{write_shards, ShardManifest, ShardOptions};
/// use drf::config::TopologyParams;
/// use drf::data::io_stats::IoStats;
/// use drf::data::synthetic::{Family, SyntheticSpec};
///
/// let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 120, 6, 5).generate();
/// let dir = drf::util::tempdir()?;
/// let params = TopologyParams { num_splitters: Some(2), ..Default::default() };
/// write_shards(&ds, &params, dir.path(), &ShardOptions::default(), IoStats::new())?;
///
/// let m = ShardManifest::load(&dir.path().join("shard_0"))?;
/// assert_eq!((m.shard, m.rows), (0, 120));
/// assert_eq!(m.column_indices(), vec![0, 2, 4]); // round-robin ownership
/// for c in &m.columns {
///     // Every recorded checksum matches the file on disk.
///     assert_eq!(checksum_file(&dir.path().join("shard_0").join(&c.file))?, c.checksum);
/// }
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ShardManifest {
    pub shard: usize,
    /// Topology the pack was cut for: the ownership map is a function
    /// of (columns, splitters, redundancy), so a pack is only valid
    /// against a leader using the same parameters.
    pub num_splitters: usize,
    pub redundancy: usize,
    pub rows: usize,
    pub schema: Schema,
    pub columns: Vec<ShardColumn>,
    /// The replicated label column (every shard carries it — §2.1).
    pub labels_file: String,
    pub labels_checksum: u64,
}

impl ShardManifest {
    pub const FILE: &'static str = "manifest.json";

    /// Ascending global indices of the columns this shard holds.
    pub fn column_indices(&self) -> Vec<usize> {
        self.columns.iter().map(|c| c.index).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("format", Json::Str(SHARD_FORMAT.into()))
            .set("protocol", Json::from_u64(PROTOCOL_VERSION as u64))
            .set("shard", Json::from_usize(self.shard))
            .set("num_splitters", Json::from_usize(self.num_splitters))
            .set("redundancy", Json::from_usize(self.redundancy))
            .set("schema", schema_to_json(&self.schema, self.rows))
            .set("labels_file", Json::Str(self.labels_file.clone()))
            .set("labels_checksum", hex_u64(self.labels_checksum))
            .set(
                "columns",
                Json::Arr(
                    self.columns
                        .iter()
                        .map(|c| {
                            let mut cj = Json::object();
                            cj.set("index", Json::from_usize(c.index))
                                .set("file", Json::Str(c.file.clone()))
                                .set("checksum", hex_u64(c.checksum));
                            if let (Some(sf), Some(sc)) = (&c.sorted_file, c.sorted_checksum) {
                                cj.set("sorted_file", Json::Str(sf.clone()))
                                    .set("sorted_checksum", hex_u64(sc));
                            }
                            cj
                        })
                        .collect(),
                ),
            );
        o
    }

    pub fn from_json(v: &Json) -> Result<ShardManifest> {
        ensure!(
            v.get("format")?.as_str()? == SHARD_FORMAT,
            "not a {SHARD_FORMAT} manifest"
        );
        let protocol = v.get("protocol")?.as_u32()?;
        ensure!(
            protocol == PROTOCOL_VERSION,
            "shard pack speaks protocol v{protocol}, this build v{PROTOCOL_VERSION}"
        );
        let (schema, rows) = schema_from_json(v.get("schema")?)?;
        let columns = v
            .get("columns")?
            .as_arr()?
            .iter()
            .map(|cj| {
                let sorted_file = match cj.get_opt("sorted_file") {
                    Some(x) => Some(x.as_str()?.to_string()),
                    None => None,
                };
                let sorted_checksum = match cj.get_opt("sorted_checksum") {
                    Some(x) => Some(parse_hex_u64(x)?),
                    None => None,
                };
                // to_json writes the pair atomically; half a pair means
                // a sorted file that can never be verified (or a
                // checksum with nothing to check) and would not survive
                // a re-encode round trip.
                ensure!(
                    sorted_file.is_some() == sorted_checksum.is_some(),
                    "column has {} without {}",
                    if sorted_file.is_some() { "sorted_file" } else { "sorted_checksum" },
                    if sorted_file.is_some() { "sorted_checksum" } else { "sorted_file" },
                );
                Ok(ShardColumn {
                    index: cj.get("index")?.as_usize()?,
                    file: cj.get("file")?.as_str()?.to_string(),
                    checksum: parse_hex_u64(cj.get("checksum")?)?,
                    sorted_file,
                    sorted_checksum,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        for w in columns.windows(2) {
            ensure!(
                w[0].index < w[1].index,
                "shard columns not in strictly ascending index order ({} then {})",
                w[0].index,
                w[1].index
            );
        }
        Ok(ShardManifest {
            shard: v.get("shard")?.as_usize()?,
            num_splitters: v.get("num_splitters")?.as_usize()?,
            redundancy: v.get("redundancy")?.as_usize()?,
            rows,
            schema,
            columns,
            labels_file: v.get("labels_file")?.as_str()?.to_string(),
            labels_checksum: parse_hex_u64(v.get("labels_checksum")?)?,
        })
    }

    /// Write `manifest.json` into the shard directory.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::write(dir.join(Self::FILE), self.to_json().to_string())?;
        Ok(())
    }

    /// Load `manifest.json` from a shard directory.
    pub fn load(dir: &Path) -> Result<ShardManifest> {
        let path = dir.join(Self::FILE);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

/// One shard's entry in the cluster manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardEntry {
    pub shard: usize,
    /// Shard directory, relative to the cluster manifest's directory.
    pub dir: String,
    /// Columns the shard holds (must equal the topology's ownership).
    pub columns: Vec<usize>,
}

/// The deployment map `drf shard` writes next to the shard directories
/// (`cluster.json`) and `drf train --engine cluster --manifest` reads.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterManifest {
    pub num_splitters: usize,
    pub redundancy: usize,
    pub rows: usize,
    pub num_features: usize,
    pub num_classes: u32,
    pub shards: Vec<ShardEntry>,
    /// Worker addresses (`host:port`), one per shard in shard order.
    /// May be empty at shard time — a deployment fills it in (or the
    /// leader overrides with `--workers`).
    pub workers: Vec<String>,
    /// Topology generation. `drf shard` writes 0; the supervisor bumps
    /// it on every rewrite (worker reschedule, drain/re-shard), and the
    /// leader polls the file between trees, carrying the version in its
    /// Hello so workers can tell a re-shard from a stale leader.
    pub version: u64,
    /// Object-store replica addresses (`host:port`), in failover
    /// order. Empty when packs are served from local disk. Clients
    /// accept the whole list and rotate on failure
    /// ([`crate::data::remote::RemoteClient`]).
    pub objstores: Vec<String>,
}

impl ClusterManifest {
    pub const FILE: &'static str = "cluster.json";

    /// The topology parameters the packs were cut for.
    pub fn topology_params(&self) -> TopologyParams {
        TopologyParams {
            num_splitters: Some(self.num_splitters),
            redundancy: self.redundancy,
            ..Default::default()
        }
    }

    /// Build the ownership map from the recorded shard column lists.
    /// Version 0 manifests carry exactly the stride construction of
    /// [`Topology::new`]; after an elastic re-shard the lists are the
    /// only truth, so the topology is built *from* them — validated for
    /// full column coverage and shard-entry order (a tampered or
    /// incomplete manifest must not silently train).
    pub fn topology(&self) -> Result<Topology> {
        ensure!(
            self.shards.len() == self.num_splitters,
            "manifest lists {} shards for a {}-splitter topology",
            self.shards.len(),
            self.num_splitters
        );
        for (s, entry) in self.shards.iter().enumerate() {
            ensure!(entry.shard == s, "shard entries out of order at {s}");
        }
        let columns: Vec<Vec<usize>> =
            self.shards.iter().map(|e| e.columns.clone()).collect();
        Topology::from_owners(self.num_features, self.redundancy, &columns)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("format", Json::Str(CLUSTER_FORMAT.into()))
            .set("protocol", Json::from_u64(PROTOCOL_VERSION as u64))
            .set("num_splitters", Json::from_usize(self.num_splitters))
            .set("redundancy", Json::from_usize(self.redundancy))
            .set("rows", Json::from_usize(self.rows))
            .set("num_features", Json::from_usize(self.num_features))
            .set("num_classes", Json::from_u64(self.num_classes as u64))
            .set(
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|e| {
                            let mut ej = Json::object();
                            ej.set("shard", Json::from_usize(e.shard))
                                .set("dir", Json::Str(e.dir.clone()))
                                .set(
                                    "columns",
                                    Json::Arr(
                                        e.columns.iter().map(|&c| Json::from_usize(c)).collect(),
                                    ),
                                );
                            ej
                        })
                        .collect(),
                ),
            )
            .set(
                "workers",
                Json::Arr(self.workers.iter().map(|w| Json::Str(w.clone())).collect()),
            )
            .set("version", Json::from_u64(self.version))
            .set(
                "objstores",
                Json::Arr(
                    self.objstores
                        .iter()
                        .map(|a| Json::Str(a.clone()))
                        .collect(),
                ),
            );
        o
    }

    pub fn from_json(v: &Json) -> Result<ClusterManifest> {
        ensure!(
            v.get("format")?.as_str()? == CLUSTER_FORMAT,
            "not a {CLUSTER_FORMAT} manifest"
        );
        let protocol = v.get("protocol")?.as_u32()?;
        ensure!(
            protocol == PROTOCOL_VERSION,
            "cluster manifest speaks protocol v{protocol}, this build v{PROTOCOL_VERSION}"
        );
        let shards = v
            .get("shards")?
            .as_arr()?
            .iter()
            .map(|ej| {
                Ok(ShardEntry {
                    shard: ej.get("shard")?.as_usize()?,
                    dir: ej.get("dir")?.as_str()?.to_string(),
                    columns: ej
                        .get("columns")?
                        .as_arr()?
                        .iter()
                        .map(|c| c.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // Entries are written in shard order; a duplicate or shuffled
        // id is a corrupted deployment map and must fail here, not
        // after a leader has already connected to workers.
        for (s, entry) in shards.iter().enumerate() {
            ensure!(
                entry.shard == s,
                "shard entry {s} has id {} (duplicate or out-of-order shard ids)",
                entry.shard
            );
        }
        let workers = match v.get_opt("workers") {
            None => Vec::new(),
            Some(ws) => ws
                .as_arr()?
                .iter()
                .map(|w| Ok(w.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
        };
        // Older manifests predate versioning and replica sets: absent
        // keys mean "generation 0, no objstores", not an error.
        let version = match v.get_opt("version") {
            None => 0,
            Some(x) => x.as_u64()?,
        };
        let objstores = match v.get_opt("objstores") {
            None => Vec::new(),
            Some(os) => os
                .as_arr()?
                .iter()
                .map(|a| Ok(a.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(ClusterManifest {
            num_splitters: v.get("num_splitters")?.as_usize()?,
            redundancy: v.get("redundancy")?.as_usize()?,
            rows: v.get("rows")?.as_usize()?,
            num_features: v.get("num_features")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_u32()?,
            shards,
            workers,
            version,
            objstores,
        })
    }

    /// Write the manifest to `path` (conventionally
    /// `<out_dir>/cluster.json`).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ClusterManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading cluster manifest {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::ColumnSpec;

    fn sample_shard() -> ShardManifest {
        ShardManifest {
            shard: 1,
            num_splitters: 3,
            redundancy: 2,
            rows: 1000,
            schema: Schema::new(
                vec![
                    ColumnSpec::numerical("a"),
                    ColumnSpec::categorical("b", 7),
                    ColumnSpec::numerical("c"),
                ],
                2,
            ),
            columns: vec![
                ShardColumn {
                    index: 0,
                    file: "col_0.drfc".into(),
                    checksum: u64::MAX - 3,
                    sorted_file: Some("col_0.sorted.drfc".into()),
                    sorted_checksum: Some(42),
                },
                ShardColumn {
                    index: 1,
                    file: "col_1.drfc".into(),
                    checksum: 7,
                    sorted_file: None,
                    sorted_checksum: None,
                },
            ],
            labels_file: "labels.drfc".into(),
            labels_checksum: 0x0123_4567_89ab_cdef,
        }
    }

    #[test]
    fn shard_manifest_roundtrip() {
        let m = sample_shard();
        let back = ShardManifest::from_json(&Json::parse(&m.to_json().to_string()).unwrap())
            .unwrap();
        // Full-range u64 checksums must survive the JSON trip exactly
        // (they travel as hex strings, not f64).
        assert_eq!(m, back);
        assert_eq!(back.column_indices(), vec![0, 1]);
    }

    #[test]
    fn cluster_manifest_roundtrip_and_topology() {
        let topo = Topology::new(
            6,
            &TopologyParams {
                num_splitters: Some(3),
                redundancy: 1,
                ..Default::default()
            },
        );
        let m = ClusterManifest {
            num_splitters: 3,
            redundancy: 1,
            rows: 500,
            num_features: 6,
            num_classes: 2,
            shards: (0..3)
                .map(|s| ShardEntry {
                    shard: s,
                    dir: format!("shard_{s}"),
                    columns: topo.columns_of(s),
                })
                .collect(),
            workers: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into(), "127.0.0.1:3".into()],
            version: 3,
            objstores: vec!["127.0.0.1:9000".into(), "127.0.0.1:9001".into()],
        };
        let back =
            ClusterManifest::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(m, back);
        back.topology().unwrap();

        // A tampered column list must be rejected.
        let mut bad = back.clone();
        bad.shards[0].columns = vec![1, 2, 3];
        assert!(bad.topology().is_err());
    }

    #[test]
    fn drained_manifest_topology_from_columns() {
        // After `drf supervise --drain 1` the drained entry is empty
        // and its columns live on the survivors — no stride
        // construction describes this; the column lists are the truth.
        let m = ClusterManifest {
            num_splitters: 3,
            redundancy: 1,
            rows: 500,
            num_features: 6,
            num_classes: 2,
            shards: vec![
                ShardEntry { shard: 0, dir: "shard_0".into(), columns: vec![0, 1, 3] },
                ShardEntry { shard: 1, dir: "shard_1".into(), columns: vec![] },
                ShardEntry { shard: 2, dir: "shard_2".into(), columns: vec![2, 4, 5] },
            ],
            workers: Vec::new(),
            version: 1,
            objstores: Vec::new(),
        };
        let topo = m.topology().unwrap();
        assert_eq!(topo.columns_of(1), Vec::<usize>::new());
        assert_eq!(topo.owners(1), &[0]);
        assert_eq!(topo.num_splitters(), 3);

        // A column nobody holds is rejected.
        let mut bad = m.clone();
        bad.shards[2].columns = vec![2, 4];
        assert!(bad.topology().is_err());

        // Pre-versioning manifests parse as generation 0.
        let mut j = m.to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("version");
            map.remove("objstores");
        }
        let back = ClusterManifest::from_json(&j).unwrap();
        assert_eq!(back.version, 0);
        assert!(back.objstores.is_empty());
    }

    #[test]
    fn foreign_json_rejected() {
        assert!(ShardManifest::from_json(&Json::parse("{\"format\": \"nope\"}").unwrap()).is_err());
        assert!(
            ClusterManifest::from_json(&Json::parse("{\"format\": \"nope\"}").unwrap()).is_err()
        );
    }

    #[test]
    fn checksum_is_stable_and_sensitive() {
        let dir = crate::util::tempdir().unwrap();
        let p = dir.path().join("f");
        std::fs::write(&p, b"hello drfc").unwrap();
        let a = checksum_file(&p).unwrap();
        assert_eq!(a, checksum_file(&p).unwrap(), "deterministic");
        std::fs::write(&p, b"hello drfd").unwrap();
        assert_ne!(a, checksum_file(&p).unwrap(), "one flipped byte changes it");
        assert!(checksum_file(&dir.path().join("missing")).is_err());
    }
}
