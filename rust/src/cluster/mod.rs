//! `drf::cluster` — the sharded multi-process deployment plane.
//!
//! The coordinator's engines up to here all ran splitters inside the
//! leader process; this module makes the paper's distribution *literal*
//! across OS processes and machines. Three pieces, one lifecycle:
//!
//! 1. **Shard** ([`shard`]): `drf shard` cuts a prepared dataset by the
//!    [`Topology`] ownership map into per-splitter shard packs —
//!    presorted DRFC v2 column files plus a JSON [`ShardManifest`]
//!    (schema, topology parameters, redundancy, per-column FNV-1a
//!    checksums) and a top-level [`ClusterManifest`] deployment map.
//! 2. **Worker** ([`worker`]): `drf worker --shard DIR --addr A:P`
//!    loads a pack through the existing
//!    [`ColumnStore`](crate::data::store::ColumnStore) backends —
//!    streaming from disk, `--preload`ed zero-copy through the mmap
//!    backend, or (with `--object-store HOST:PORT`) fetched over the
//!    wire from a `drf objstore` by chunk-aligned range reads
//!    ([`load_shard_remote`]), so the worker serves a shard it never
//!    downloaded in full — verifies the checksums (remote packs
//!    re-verify on every complete pass), and serves the splitter wire
//!    protocol. Training configuration arrives with the leader's Hello
//!    handshake — a worker binary is deployment-agnostic.
//! 3. **Leader** ([`engine`]): `drf train --engine cluster
//!    --manifest cluster.json` connects a [`ClusterPool`] to the fleet
//!    (connect retry/timeout, Hello validation of protocol version,
//!    shard ids, column inventories, and row counts) and trains over
//!    it. Composed with the generic
//!    [`RecoveringPool`](crate::coordinator::recovery::RecoveringPool),
//!    a worker killed and restarted mid-training is rebuilt by
//!    replaying the level-update log — trees stay bit-identical to
//!    `--engine direct` (asserted end-to-end in `tests/cluster.rs`).
//! 4. **Supervisor** ([`supervise`]): `drf supervise --dir DIR` boots
//!    the fleet, health-checks every process, restarts or reschedules
//!    the dead (pure policy core, flap-damped), re-shards a worker out
//!    of a live run (`drain`), and runs objstore replica sets — all
//!    coordinated with the leader through versioned `cluster.json`
//!    rewrites, never a new RPC.
//!
//! [`Topology`]: crate::coordinator::topology::Topology

pub mod engine;
pub mod manifest;
pub mod shard;
pub mod supervise;
pub mod worker;

pub use engine::{hello_template, ClusterOptions, ClusterPool};
pub use manifest::{
    checksum_bytes, checksum_file, ClusterManifest, ShardColumn, ShardEntry, ShardManifest,
};
pub use shard::{write_shards, ShardOptions};
pub use supervise::{
    decide, drain_worker, save_manifest_atomic, ProcHealth, SuperviseAction, SuperviseOptions,
    SupervisePolicy, Supervisor,
};
pub use worker::{
    load_shard, load_shard_remote, LoadedShard, ShardSource, WorkerOptions, WorkerServer,
};
