//! The cluster engine: a [`SplitterPool`] over remote worker processes.
//!
//! [`ClusterPool`] is what `--engine cluster` puts under the tree
//! builders instead of spawning splitter cores in-process: one
//! persistent, mutex-guarded connection per worker, each opened with a
//! bounded connect-retry loop and validated by the Hello handshake
//! (protocol version, shard id, column inventory, row count) so a
//! misdeployed fleet fails before any training traffic flows.
//!
//! Failure handling is layered. The pool owns *connections*: when a
//! round trip dies mid-call it reconnects — retrying while the worker
//! restarts — re-handshakes, and re-issues the request once. A worker
//! that came back empty then answers "unknown tree", and the *state*
//! layer ([`RecoveringPool`]) replays the level-update log to rebuild
//! it. Neither layer needs the other's knowledge: connection loss never
//! reaches the recovery layer, state loss never reaches the tree
//! builder.
//!
//! [`RecoveringPool`]: crate::coordinator::recovery::RecoveringPool

use super::manifest::ClusterManifest;
use crate::config::{PruneMode, TrainConfig};
use crate::coordinator::messages::{
    EvalQuery, EvalResult, LevelUpdate, MaterializeQuery, MaterializedLeaves, PartialSupersplit,
    SubtreeDone, SupersplitQuery,
};
use crate::coordinator::topology::Topology;
use crate::coordinator::transport::SplitterPool;
use crate::coordinator::wire::{
    decode_response, encode_request, encode_request_traced, read_frame, write_frame, HelloConfig,
    Request, Response, PROTOCOL_VERSION,
};
use crate::data::io_stats::IoStats;
use crate::telemetry::{clock_sync_exchange, current_context, record_clock_sync, trace_enabled};
use crate::Result;
use anyhow::{anyhow, bail, ensure, Context};
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Connection policy of the cluster pool.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Connection attempts per (re)connect before giving up.
    pub connect_retries: usize,
    /// Pause between attempts (covers a worker restart window of
    /// roughly `connect_retries x retry_delay`).
    pub retry_delay: Duration,
    /// Per-attempt TCP connect timeout.
    pub connect_timeout: Duration,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        Self {
            connect_retries: 50,
            retry_delay: Duration::from_millis(200),
            connect_timeout: Duration::from_secs(2),
        }
    }
}

/// Derive the Hello handshake a leader sends from its training config
/// and the cluster manifest (`shard` is filled in per connection).
pub fn hello_template(cfg: &TrainConfig, manifest: &ClusterManifest) -> HelloConfig {
    HelloConfig {
        protocol: PROTOCOL_VERSION,
        shard: 0,
        num_splitters: manifest.num_splitters as u32,
        redundancy: manifest.redundancy as u32,
        seed: cfg.forest.seed,
        bagging: cfg.forest.bagging.as_str().into(),
        sampling: cfg.forest.feature_sampling.as_str().into(),
        num_candidates: cfg.forest.candidates_for(manifest.num_features) as u32,
        score_kind: cfg.forest.score_kind.as_str().into(),
        prune_threshold: match cfg.prune {
            PruneMode::Never => None,
            PruneMode::Adaptive { threshold } => Some(threshold),
        },
        split_search: cfg.split_search.as_str().into(),
        depth_next_rows: cfg.depth_next_rows,
        topology_version: manifest.version,
    }
}

/// One worker's persistent connection (requests on a connection are
/// serialized, matching the RPC semantics).
struct Conn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

struct Slot {
    /// Where the worker lives. Behind a lock so a supervisor can
    /// redirect the leader when a worker is rescheduled elsewhere
    /// ([`ClusterPool::set_worker_addr`]). Lock order: `conn` first,
    /// then `addr` (reconnection reads the address while holding the
    /// connection lock).
    addr: Mutex<SocketAddr>,
    /// Columns this worker serves under the current topology version
    /// (rewritten by [`ClusterPool::poll_topology`] after an elastic
    /// re-shard).
    columns: Mutex<Vec<usize>>,
    /// A drained slot keeps its id (splitter ids are stable across a
    /// re-shard) but owns no columns and takes no traffic.
    active: AtomicBool,
    conn: Mutex<Option<Conn>>,
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving worker address '{addr}'"))?
        .next()
        .ok_or_else(|| anyhow!("worker address '{addr}' resolved to nothing"))
}

/// A [`SplitterPool`] backed by remote `drf worker` processes.
pub struct ClusterPool {
    slots: Vec<Slot>,
    /// The handshake template. Behind a lock because its
    /// `topology_version` advances when [`ClusterPool::poll_topology`]
    /// picks up a re-shard.
    hello: Mutex<HelloConfig>,
    /// The ownership map the leader currently trains with. Swapped
    /// wholesale on a manifest version bump; the manager snapshots it
    /// per tree so a running tree never sees the map change.
    topology: Mutex<Topology>,
    /// `cluster.json` to re-read between trees (None = static fleet).
    manifest_path: Mutex<Option<PathBuf>>,
    expected_rows: u64,
    expected_classes: u32,
    opts: ClusterOptions,
    net: IoStats,
}

impl ClusterPool {
    /// Connect to `workers[s]` for each splitter `s` and validate the
    /// whole fleet via the Hello handshake before returning.
    pub fn connect(
        workers: &[String],
        topology: &Topology,
        hello: HelloConfig,
        expected_rows: u64,
        expected_classes: u32,
        opts: ClusterOptions,
    ) -> Result<ClusterPool> {
        ensure!(
            workers.len() == topology.num_splitters(),
            "cluster lists {} workers for a {}-splitter topology",
            workers.len(),
            topology.num_splitters()
        );
        let mut slots = Vec::with_capacity(workers.len());
        for (s, w) in workers.iter().enumerate() {
            let columns = topology.columns_of(s);
            slots.push(Slot {
                addr: Mutex::new(resolve(w)?),
                active: AtomicBool::new(!columns.is_empty()),
                columns: Mutex::new(columns),
                conn: Mutex::new(None),
            });
        }
        let pool = ClusterPool {
            slots,
            hello: Mutex::new(hello),
            topology: Mutex::new(topology.clone()),
            manifest_path: Mutex::new(None),
            expected_rows,
            expected_classes,
            opts,
            net: IoStats::new(),
        };
        for s in 0..pool.slots.len() {
            if !pool.slots[s].active.load(Ordering::SeqCst) {
                continue; // already-drained slot in a restarted run
            }
            let conn = pool.open_conn(s)?;
            *pool.slots[s].conn.lock().unwrap() = Some(conn);
        }
        // Leader-side network totals, visible on the leader's /metrics.
        crate::telemetry::register_io_gauges("drf_cluster_net", &pool.net);
        crate::telemetry::gauge("drf_cluster_workers").set(pool.active_count() as u64);
        Ok(pool)
    }

    fn hello_for(&self, s: usize) -> HelloConfig {
        HelloConfig {
            shard: s as u32,
            ..self.hello.lock().unwrap().clone()
        }
    }

    /// Re-read `path` (a [`ClusterManifest`]) between trees so a
    /// supervisor's rewrites — rescheduled worker addresses, an elastic
    /// drain — reach this leader without any new RPC surface. See
    /// [`ClusterPool::poll_topology`].
    pub fn watch_manifest(&self, path: PathBuf) {
        *self.manifest_path.lock().unwrap() = Some(path);
    }

    /// Snapshot of the ownership map currently trained with. The
    /// manager takes one per tree; a re-shard picked up between trees
    /// never mutates a snapshot a builder is using.
    pub fn topology(&self) -> Topology {
        self.topology.lock().unwrap().clone()
    }

    /// The cluster-manifest generation the pool last adopted.
    pub fn topology_version(&self) -> u64 {
        self.hello.lock().unwrap().topology_version
    }

    /// Splitter slots still owning columns.
    pub fn active_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.active.load(Ordering::SeqCst))
            .count()
    }

    /// First slot that still owns columns — the stand-in target for
    /// per-splitter calls addressed at a drained slot (the tree builder
    /// reads root stats from splitter 0 unconditionally; every splitter
    /// computes identical root stats from the replicated labels, so any
    /// active one serves).
    fn route(&self, s: usize) -> usize {
        if self.slots[s].active.load(Ordering::SeqCst) {
            return s;
        }
        self.slots
            .iter()
            .position(|slot| slot.active.load(Ordering::SeqCst))
            .unwrap_or(s)
    }

    /// If a watched `cluster.json` advanced past the version last
    /// adopted, take the new topology: per-slot column lists and
    /// addresses are refreshed, emptied slots are marked drained, every
    /// connection is dropped (the next call re-handshakes carrying the
    /// new `topology_version`, which makes each worker reload its
    /// re-cut pack before answering), and the Hello template advances.
    /// Returns whether a new version was adopted. Call only between
    /// trees: the forest is topology-invariant at tree boundaries
    /// (per-level column assignment routes scans, it never changes
    /// split arithmetic), so adopting here preserves bit-identity.
    pub fn poll_topology(&self) -> Result<bool> {
        let path = match self.manifest_path.lock().unwrap().clone() {
            Some(p) => p,
            None => return Ok(false),
        };
        // A transient read/parse failure (the supervisor writes by
        // rename, but the file may live on a remote mount) skips this
        // poll rather than aborting a healthy training run.
        let manifest = match ClusterManifest::load(&path) {
            Ok(m) => m,
            Err(_) => {
                crate::telemetry::counter("drf_cluster_topology_poll_errors_total").inc();
                return Ok(false);
            }
        };
        if manifest.version <= self.topology_version() {
            return Ok(false);
        }
        ensure!(
            manifest.shards.len() == self.slots.len(),
            "watched manifest now lists {} shards, pool was built with {}",
            manifest.shards.len(),
            self.slots.len()
        );
        let topology = manifest.topology()?;
        for (s, slot) in self.slots.iter().enumerate() {
            let columns = topology.columns_of(s);
            // Drop the connection first (lock order: conn before addr);
            // stale handshakes must not serve the new topology.
            let mut conn = slot.conn.lock().unwrap();
            *conn = None;
            if let Some(addr) = manifest.workers.get(s) {
                if !addr.is_empty() {
                    *slot.addr.lock().unwrap() = resolve(addr)?;
                }
            }
            slot.active.store(!columns.is_empty(), Ordering::SeqCst);
            *slot.columns.lock().unwrap() = columns;
        }
        self.hello.lock().unwrap().topology_version = manifest.version;
        *self.topology.lock().unwrap() = topology;
        crate::telemetry::counter("drf_cluster_topology_reloads_total").inc();
        crate::telemetry::gauge("drf_cluster_topology_version").set(manifest.version);
        crate::telemetry::gauge("drf_cluster_workers").set(self.active_count() as u64);
        Ok(true)
    }

    /// Redirect worker `s` to a new address (e.g. a supervisor
    /// rescheduled it on another host/port). The stale connection is
    /// dropped; the next call reconnects and re-handshakes.
    pub fn set_worker_addr(&self, s: usize, addr: &str) -> Result<()> {
        let resolved = resolve(addr)?;
        let slot = &self.slots[s];
        let mut conn = slot.conn.lock().unwrap();
        *slot.addr.lock().unwrap() = resolved;
        *conn = None;
        Ok(())
    }

    /// Mid-tree address refresh: while a reconnect waits out a worker
    /// restart, re-read the watched manifest and take worker `s`'s
    /// address if the supervisor moved it. Only the *address* is taken
    /// here — column ownership changes adopt between trees
    /// ([`ClusterPool::poll_topology`]), where they cannot affect a
    /// tree already being built.
    fn refresh_addr(&self, s: usize) {
        let path = match self.manifest_path.lock().unwrap().clone() {
            Some(p) => p,
            None => return,
        };
        let Ok(manifest) = ClusterManifest::load(&path) else {
            return;
        };
        let Some(addr) = manifest.workers.get(s) else {
            return;
        };
        if addr.is_empty() {
            return;
        }
        let Ok(resolved) = resolve(addr) else {
            return;
        };
        let mut cur = self.slots[s].addr.lock().unwrap();
        if *cur != resolved {
            *cur = resolved;
            crate::telemetry::counter("drf_cluster_addr_refreshes_total").inc();
        }
    }

    /// Establish a validated connection to worker `s`, retrying while
    /// the worker comes (back) up. A *handshake* failure is a hard
    /// error — the fleet is wrong and retrying cannot fix it.
    fn open_conn(&self, s: usize) -> Result<Conn> {
        let attempts = self.opts.connect_retries.max(1);
        let mut last_err: Option<std::io::Error> = None;
        let mut last_addr = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(self.opts.retry_delay);
                self.refresh_addr(s);
            }
            // Re-read per attempt: the address may be redirected while
            // we wait out a restart.
            let addr = *self.slots[s].addr.lock().unwrap();
            last_addr = Some(addr);
            match TcpStream::connect_timeout(&addr, self.opts.connect_timeout) {
                Ok(stream) => return self.handshake(s, stream),
                Err(e) => last_err = Some(e),
            }
        }
        Err(anyhow!(
            "worker {s} at {} unreachable after {attempts} attempts: {}",
            last_addr.map(|a| a.to_string()).unwrap_or_default(),
            last_err.map(|e| e.to_string()).unwrap_or_default()
        ))
    }

    /// Hello over a fresh stream; validates the worker's inventory.
    fn handshake(&self, s: usize, stream: TcpStream) -> Result<Conn> {
        stream.set_nodelay(true)?;
        let mut conn = Conn {
            r: BufReader::new(stream.try_clone()?),
            w: BufWriter::new(stream),
        };
        let body = encode_request(&Request::Hello(self.hello_for(s)));
        write_frame(&mut conn.w, &body)?;
        let frame = read_frame(&mut conn.r)?;
        self.net.add_net(body.len() as u64 + 4);
        self.net.add_net(frame.len() as u64 + 4);
        let info = match decode_response(&frame)? {
            Response::Hello(i) => i,
            Response::Err(msg) => bail!("worker {s} rejected the handshake: {msg}"),
            r => bail!("unexpected handshake response {r:?}"),
        };
        ensure!(
            info.protocol == PROTOCOL_VERSION,
            "worker {s} speaks protocol v{}, leader v{PROTOCOL_VERSION}",
            info.protocol
        );
        ensure!(
            info.shard as usize == s,
            "worker {s} serves shard {}, expected {s}",
            info.shard
        );
        ensure!(
            info.rows == self.expected_rows,
            "worker {s} holds {} rows, leader expects {}",
            info.rows,
            self.expected_rows
        );
        ensure!(
            info.num_classes == self.expected_classes,
            "worker {s} reports {} classes, leader expects {}",
            info.num_classes,
            self.expected_classes
        );
        let cols: Vec<usize> = info.columns.iter().map(|&c| c as usize).collect();
        let expected = self.slots[s].columns.lock().unwrap().clone();
        ensure!(
            cols == expected,
            "worker {s} column inventory {cols:?} does not match the topology's {expected:?}"
        );
        // With tracing active, estimate this worker's clock offset via a
        // short RPC-midpoint exchange so `drf trace merge` can align its
        // timeline with ours. Runs on every (re)handshake: a restarted
        // worker has a fresh clock epoch, and the newest sync wins.
        if trace_enabled() {
            let body = encode_request(&Request::TimeSync);
            let peer = clock_sync_exchange(4, || -> Result<crate::telemetry::TimeSyncReply> {
                write_frame(&mut conn.w, &body)?;
                let frame = read_frame(&mut conn.r)?;
                self.net.add_net(body.len() as u64 + 4);
                self.net.add_net(frame.len() as u64 + 4);
                match decode_response(&frame)? {
                    Response::TimeSync(t) => Ok(t),
                    r => bail!("unexpected TimeSync response {r:?}"),
                }
            })?;
            record_clock_sync(&peer);
        }
        Ok(conn)
    }

    /// One serialized request/response round trip with transparent
    /// reconnect-and-retry on connection loss.
    fn call(&self, s: usize, req: &Request) -> Result<Response> {
        let rpc_start = std::time::Instant::now();
        let slot = &self.slots[s];
        let mut guard = slot.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(self.open_conn(s)?);
        }
        // Attach this thread's trace context so the worker's spans
        // parent under the round span issuing the RPC.
        let ctx = current_context();
        let body = encode_request_traced(req, ctx.as_ref());
        let round_trip = |conn: &mut Conn| -> Result<Vec<u8>> {
            write_frame(&mut conn.w, &body)?;
            read_frame(&mut conn.r)
        };
        let frame = match round_trip(guard.as_mut().unwrap()) {
            Ok(f) => f,
            Err(_) => {
                // The worker went away mid-call. Reconnect (waiting out
                // a restart) and re-issue once; a restarted worker then
                // answers "unknown tree", which the recovery layer
                // turns into a replay.
                *guard = None;
                let mut conn = self.open_conn(s)?;
                let f = round_trip(&mut conn)
                    .with_context(|| format!("worker {s}: retry after reconnect failed"))?;
                *guard = Some(conn);
                f
            }
        };
        self.net.add_net(body.len() as u64 + 4);
        self.net.add_net(frame.len() as u64 + 4);
        // Per-worker RPC latency, reconnect time included: a slow or
        // flapping worker shows up in its own series.
        crate::telemetry::histogram_with("drf_cluster_rpc_us", &[("worker", &s.to_string())])
            .observe(rpc_start.elapsed().as_micros() as u64);
        let resp = decode_response(&frame)?;
        if let Response::Err(msg) = &resp {
            bail!("{msg}");
        }
        Ok(resp)
    }
}

impl SplitterPool for ClusterPool {
    fn num_splitters(&self) -> usize {
        self.slots.len()
    }

    fn columns_of(&self, splitter: usize) -> Vec<usize> {
        self.slots[splitter].columns.lock().unwrap().clone()
    }

    fn start_tree(&self, tree: u32) -> Result<()> {
        for s in 0..self.slots.len() {
            if !self.slots[s].active.load(Ordering::SeqCst) {
                continue;
            }
            self.start_tree_on(s, tree)?;
        }
        Ok(())
    }

    fn root_stats(&self, splitter: usize, tree: u32) -> Result<Vec<u64>> {
        // Root stats come from the replicated label column — identical
        // on every splitter — so a drained slot's request is rerouted
        // to any active one.
        match self.call(self.route(splitter), &Request::RootStats(tree))? {
            Response::RootStats(v) => Ok(v),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn find_splits(&self, splitter: usize, q: &SupersplitQuery) -> Result<PartialSupersplit> {
        match self.call(splitter, &Request::FindSplits(q.clone()))? {
            Response::Splits(p) => Ok(p),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> Result<EvalResult> {
        match self.call(splitter, &Request::EvalConditions(q.clone()))? {
            Response::Evals(e) => Ok(e),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn broadcast_level_update(&self, u: &LevelUpdate) -> Result<()> {
        let net_before = self.net.snapshot();
        let mut min_us = u64::MAX;
        let mut max_us = 0u64;
        for s in 0..self.slots.len() {
            if !self.slots[s].active.load(Ordering::SeqCst) {
                continue;
            }
            let start = std::time::Instant::now();
            self.apply_level_update_on(s, u)?;
            let us = start.elapsed().as_micros() as u64;
            min_us = min_us.min(us);
            max_us = max_us.max(us);
        }
        // Bytes/messages were charged per peer; count the event.
        self.net.add_broadcast_event();
        // Per-round telemetry: broadcast volume and the straggler gap
        // (slowest minus fastest worker in this round's update fan-out).
        let round_bytes = self.net.snapshot().delta_since(&net_before).net_bytes;
        crate::telemetry::counter("drf_cluster_rounds_total").inc();
        crate::telemetry::histogram("drf_cluster_round_bytes").observe(round_bytes);
        if max_us >= min_us {
            crate::telemetry::histogram("drf_cluster_straggler_gap_us").observe(max_us - min_us);
        }
        Ok(())
    }

    fn materialize(&self, splitter: usize, q: &MaterializeQuery) -> Result<MaterializedLeaves> {
        match self.call(splitter, &Request::Materialize(q.clone()))? {
            Response::Materialized(m) => Ok(m),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn broadcast_subtree_done(&self, d: &SubtreeDone) -> Result<()> {
        for s in 0..self.slots.len() {
            if !self.slots[s].active.load(Ordering::SeqCst) {
                continue;
            }
            self.broadcast_subtree_done_on(s, d)?;
        }
        self.net.add_broadcast_event();
        Ok(())
    }

    fn finish_tree(&self, tree: u32) -> Result<()> {
        for s in 0..self.slots.len() {
            if !self.slots[s].active.load(Ordering::SeqCst) {
                continue;
            }
            self.finish_tree_on(s, tree)?;
        }
        Ok(())
    }

    fn net_stats(&self) -> IoStats {
        self.net.clone()
    }

    fn start_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        match self.call(splitter, &Request::StartTree(tree))? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn apply_level_update_on(&self, splitter: usize, u: &LevelUpdate) -> Result<()> {
        match self.call(splitter, &Request::LevelUpdate(u.clone()))? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn finish_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        match self.call(splitter, &Request::FinishTree(tree))? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn broadcast_subtree_done_on(&self, splitter: usize, d: &SubtreeDone) -> Result<()> {
        match self.call(splitter, &Request::SubtreeDone(*d))? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::shard::{write_shards, ShardOptions};
    use crate::cluster::worker::{load_shard, WorkerOptions, WorkerServer};
    use crate::config::{ForestParams, TopologyParams};
    use crate::coordinator::recovery::{InjectedFailure, RecoveringPool};
    use crate::coordinator::tree_builder::TreeBuilderCore;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::forest::RandomForest;

    fn quick_opts() -> ClusterOptions {
        ClusterOptions {
            connect_retries: 5,
            retry_delay: Duration::from_millis(20),
            connect_timeout: Duration::from_millis(500),
        }
    }

    fn spawn_fleet(
        dir: &std::path::Path,
        splitters: usize,
    ) -> (crate::data::Dataset, Vec<WorkerServer>, Vec<String>) {
        let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 300, 6, 13).generate();
        write_shards(
            &ds,
            &TopologyParams {
                num_splitters: Some(splitters),
                ..Default::default()
            },
            dir,
            &ShardOptions::default(),
            IoStats::new(),
        )
        .unwrap();
        let servers: Vec<WorkerServer> = (0..splitters)
            .map(|s| {
                let shard =
                    load_shard(&dir.join(format!("shard_{s}")), &WorkerOptions::default())
                        .unwrap();
                WorkerServer::spawn(shard, "127.0.0.1:0", 1).unwrap()
            })
            .collect();
        let addrs = servers.iter().map(|s| s.addr().to_string()).collect();
        (ds, servers, addrs)
    }

    fn params() -> ForestParams {
        ForestParams {
            num_trees: 1,
            max_depth: 5,
            seed: 77,
            ..Default::default()
        }
    }

    fn hello(cfg: &ForestParams, num_features: usize, splitters: u32) -> HelloConfig {
        HelloConfig {
            protocol: PROTOCOL_VERSION,
            shard: 0,
            num_splitters: splitters,
            redundancy: 1,
            seed: cfg.seed,
            bagging: cfg.bagging.as_str().into(),
            sampling: cfg.feature_sampling.as_str().into(),
            num_candidates: cfg.candidates_for(num_features) as u32,
            score_kind: cfg.score_kind.as_str().into(),
            prune_threshold: None,
            split_search: "exact".into(),
            depth_next_rows: 0,
            topology_version: 0,
        }
    }

    #[test]
    fn cluster_training_matches_in_process() {
        let dir = crate::util::tempdir().unwrap();
        let (ds, _servers, addrs) = spawn_fleet(dir.path(), 2);
        let p = params();
        let topo = Topology::new(
            ds.num_features(),
            &TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
        );

        // Reference: plain in-process training, same seed/config.
        let mut cfg = crate::config::TrainConfig::default();
        cfg.forest = p;
        cfg.topology.num_splitters = Some(2);
        let (reference, _) = RandomForest::train_with_config(&ds, &cfg).unwrap();

        let pool = ClusterPool::connect(
            &addrs,
            &topo,
            hello(&p, ds.num_features(), 2),
            ds.num_rows() as u64,
            ds.num_classes(),
            quick_opts(),
        )
        .unwrap();
        let builder = TreeBuilderCore::new(&pool, &topo, &p, ds.num_features());
        let (tree, _) = builder.build_tree(0).unwrap();
        assert_eq!(reference.trees[0], tree, "cluster engine must be exact");
        assert!(pool.net_stats().net_bytes() > 0);
    }

    #[test]
    fn fleet_validation_rejects_swapped_workers() {
        let dir = crate::util::tempdir().unwrap();
        let (ds, _servers, mut addrs) = spawn_fleet(dir.path(), 2);
        addrs.swap(0, 1);
        let p = params();
        let topo = Topology::new(
            ds.num_features(),
            &TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
        );
        let err = ClusterPool::connect(
            &addrs,
            &topo,
            hello(&p, ds.num_features(), 2),
            ds.num_rows() as u64,
            ds.num_classes(),
            quick_opts(),
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("shard"),
            "swapped fleet must fail the handshake: {err:#}"
        );
    }

    #[test]
    fn unreachable_worker_fails_after_retries() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 50, 4, 3).generate();
        let p = params();
        let topo = Topology::new(
            ds.num_features(),
            &TopologyParams {
                num_splitters: Some(1),
                ..Default::default()
            },
        );
        // A port nobody listens on (bind-then-drop reserves a dead one).
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = ClusterOptions {
            connect_retries: 2,
            retry_delay: Duration::from_millis(10),
            connect_timeout: Duration::from_millis(200),
        };
        let err = ClusterPool::connect(
            &[dead],
            &topo,
            hello(&p, ds.num_features(), 1),
            ds.num_rows() as u64,
            ds.num_classes(),
            opts,
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("unreachable"),
            "expected a retry-exhausted error: {err:#}"
        );
    }

    #[test]
    fn recovery_replays_over_cluster_transport() {
        // Injected state loss (FinishTree mid-run) on real worker
        // processes' cores; the generic recovery layer must replay and
        // keep the tree bit-identical.
        let dir = crate::util::tempdir().unwrap();
        let (ds, _servers, addrs) = spawn_fleet(dir.path(), 3);
        let p = params();
        let topo = Topology::new(
            ds.num_features(),
            &TopologyParams {
                num_splitters: Some(3),
                ..Default::default()
            },
        );
        let connect = || {
            ClusterPool::connect(
                &addrs,
                &topo,
                hello(&p, ds.num_features(), 3),
                ds.num_rows() as u64,
                ds.num_classes(),
                quick_opts(),
            )
            .unwrap()
        };

        let clean = connect();
        let builder = TreeBuilderCore::new(&clean, &topo, &p, ds.num_features());
        let (reference, _) = builder.build_tree(0).unwrap();

        // Injection points cover every splitter at the chosen indices,
        // so whichever splitter the 2nd/9th RPC targets loses its state
        // — the kill is guaranteed to fire.
        let failures: Vec<InjectedFailure> = (0..3)
            .flat_map(|s| {
                [2u64, 9].map(|rpc_index| InjectedFailure {
                    splitter: s,
                    rpc_index,
                })
            })
            .collect();
        let failing = RecoveringPool::with_failures(connect(), failures);
        let builder = TreeBuilderCore::new(&failing, &topo, &p, ds.num_features());
        let (recovered, _) = builder.build_tree(1).unwrap();
        let builder = TreeBuilderCore::new(&clean, &topo, &p, ds.num_features());
        let (reference1, _) = builder.build_tree(1).unwrap();
        assert!(failing.recoveries() >= 1);
        assert_eq!(reference1, recovered);
        // Different trees of the same forest still differ (sanity).
        assert_ne!(reference, recovered);
    }
}
